"""HLO roofline parser: exactness on controlled programs (subprocess with
fake devices, like tests/test_distributed.py)."""
from tests.test_distributed import run_devices


def test_scan_matmul_flops_exact():
    run_devices("""
        from repro.launch.hlo_analysis import module_stats
        from jax.sharding import PartitionSpec as P, NamedSharding
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        s = lambda *sp: NamedSharding(mesh, P(*sp))
        w = jax.ShapeDtypeStruct((8, 256, 512), jnp.bfloat16)
        x = jax.ShapeDtypeStruct((4, 256), jnp.bfloat16)
        def f(w, x):
            def body(c, wl):
                y = c @ wl
                return y[:, :256] + y[:, 256:], None
            return jax.lax.scan(body, x, w)[0]
        c = jax.jit(f, in_shardings=(s(None, None, "model"), s("data", None)),
                    out_shardings=s("data", None)).lower(w, x).compile()
        st = module_stats(c.as_text())
        expect = 8 * 2 * 2 * 256 * (512 // 4)   # layers x 2MNK per device
        assert abs(st.flops - expect) / expect < 0.01, (st.flops, expect)
        print("OK")
    """)


def test_collective_bytes_counted_with_trip_count():
    run_devices("""
        from repro.launch.hlo_analysis import module_stats
        from jax.sharding import PartitionSpec as P, NamedSharding
        mesh = jax.make_mesh((4,), ("model",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        s = lambda *sp: NamedSharding(mesh, P(*sp))
        w = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
        def f(w, x):
            def body(c, wl):
                return c @ wl, None     # row-parallel: AR per layer
            return jax.lax.scan(body, x, w)[0]
        c = jax.jit(f, in_shardings=(s(None, "model", None), s(None, None)),
                    out_shardings=s(None, None)).lower(w, x).compile()
        st = module_stats(c.as_text())
        ar = st.coll["all-reduce"]
        # 6 scan steps x (8x128 f32) = 6 x 4096B = 24576B min
        assert ar >= 6 * 8 * 128 * 4, st.coll
        print("OK")
    """, n=4)


def test_fused_scope_zeroes_bytes_not_flops():
    run_devices("""
        from repro.launch.hlo_analysis import module_stats
        a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        def f(x):
            with jax.named_scope("vmem_fused:test"):
                y = x @ x
                y = jax.nn.softmax(y, axis=-1)
            return y @ x
        c = jax.jit(f).lower(a).compile()
        full = module_stats(c.as_text(), fused_kernels=False)
        fused = module_stats(c.as_text(), fused_kernels=True)
        assert fused.flops == full.flops          # flops untouched
        assert fused.bytes < full.bytes           # scoped bytes removed
        print("OK")
    """, n=1)
