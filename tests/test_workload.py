"""Workload generators: distribution + determinism properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property-based invariants need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.request import TaskType
from repro.data.workload import WorkloadSpec, generate


def test_alpaca_short_longbench_long():
    a = generate(WorkloadSpec(dataset="alpaca", n_requests=2000, seed=1))
    l = generate(WorkloadSpec(dataset="longbench", n_requests=2000, seed=1,
                              max_model_len=65536))
    am = np.mean([r.prompt_len for r in a])
    lm = np.median([r.prompt_len for r in l])
    assert 50 < am < 130          # paper: mean ~83
    assert lm > 20000             # paper: median ~41k (truncated)


def test_mixed_is_bimodal():
    m = generate(WorkloadSpec(dataset="mixed", n_requests=2000, seed=2,
                              max_model_len=32768))
    lens = np.array([r.prompt_len for r in m])
    short = (lens < 512).mean()
    assert 0.35 < short < 0.65


@settings(max_examples=20, deadline=None)
@given(st.floats(0.5, 64.0), st.integers(10, 300), st.integers(0, 99))
def test_workload_invariants(rps, n, seed):
    spec = WorkloadSpec(dataset="mixed", rps=rps, n_requests=n, seed=seed,
                        max_model_len=4096)
    reqs = generate(spec)
    assert len(reqs) == n
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr)                       # Poisson cumulative
    for r in reqs:
        assert 1 <= r.prompt_len < 4096
        assert r.max_new_tokens >= 1
        assert r.prompt_len + r.max_new_tokens <= 4096
    # deterministic given the seed
    again = generate(spec)
    assert [r.prompt_len for r in reqs] == [r.prompt_len for r in again]


def test_poisson_rate_roughly_matches():
    spec = WorkloadSpec(dataset="alpaca", rps=10.0, n_requests=2000, seed=3)
    reqs = generate(spec)
    measured = len(reqs) / reqs[-1].arrival
    assert measured == pytest.approx(10.0, rel=0.15)
