"""SLO-class goodput scheduling (PR 9, DESIGN.md §8).

The tentpole claims under test:

* the slack model anchors every deadline on the ledger's FIRST arrival
  (``Request.t0``) — OOM-restart and restore-hold requeues overwrite
  ``Request.arrival`` and must not silently extend a deadline;
* the GoodputScheduler orders the queue by budget-normalized urgency
  (+ short-job bonus), force-includes winnable nearly-late requests,
  and demotes past-deadline ones that can no longer earn goodput;
* slice-boundary preemption (arXiv 2406.13511): a mid-generation yield
  at a multiple of K decode iterations preserves the generated prefix —
  the resumed request's token ids are BIT-IDENTICAL to an uncontended
  run, on BOTH execution backends;
* engine/sim parity extends to the new decision surfaces: formed
  batches, preemption victims (the requeue order), and slice-yield
  decisions are identical across backends under the GoodputScheduler.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (BucketServeScheduler, GoodputScheduler, GlobalMonitor,
                        MemoryBudget, SchedulerConfig, TaskType)
from repro.core.batcher import DynamicBatchController
from repro.core.engine import ServingEngine
from repro.core.request import Request
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.core.telemetry import LatencyLedger
from repro.models import transformer as tfm

BUDGET = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                      weight_bytes=0)
CFG = get_smoke_config("qwen3-14b", max_seq_len=128)


# ------------------------------------------------------ slack model ------
class TestSlackModel:
    def _started(self, **kw) -> Request:
        r = Request(rid=0, prompt_len=16, max_new_tokens=8, arrival=1.0, **kw)
        r.ledger = LatencyLedger()
        r.ledger.start(1.0)
        return r

    def test_t0_survives_requeue_arrival_overwrite(self):
        r = self._started()
        r.arrival = 7.5                      # OOM restart penalty path
        assert r.t0() == 1.0
        assert r.ttft_slack(2.0) == pytest.approx(r.slo_ttft - 1.0)
        r.first_token = 2.0
        r.finished = 4.0
        assert r.ttft() == pytest.approx(1.0)      # NOT 2.0 - 7.5
        assert r.e2e() == pytest.approx(3.0)

    def test_slack_switches_phase_at_first_token(self):
        r = self._started(slo_ttft=2.0, slo_tpot=0.1)
        assert r.slack(2.0) == pytest.approx(1.0)        # TTFT phase
        r.first_token = 2.0
        r.generated = 5
        # 4 post-first tokens allowed 0.1 s each, 1 s elapsed since first
        assert r.slack(3.0) == pytest.approx(0.4 - 1.0)

    def test_sacrifice_slack_is_clock_free(self):
        r = self._started(slo_ttft=2.0, slo_tpot=0.1)
        assert r.sacrifice_slack() == pytest.approx(2.0)
        r.first_token = 2.0
        r.generated = 6
        assert r.sacrifice_slack() == pytest.approx(0.1 * 2)
        # depends only on budgets and token counts — no ``now`` argument


# ----------------------------------------------- queue ordering ----------
def _sched(cls=GoodputScheduler, **kw):
    return cls(CFG, BUDGET, SchedulerConfig(**kw))


def _req(rid, arrival, *, cls="chat", slo_ttft=2.0, slo_tpot=0.2,
         prompt=64, new=32):
    return Request(rid=rid, prompt_len=prompt, max_new_tokens=new,
                   arrival=arrival, task_type=TaskType.ONLINE, cls=cls,
                   slo_ttft=slo_ttft, slo_tpot=slo_tpot)


class TestGoodputOrdering:
    def test_urgency_is_budget_normalized(self):
        """A chat request 1 s into its 2 s budget outranks a batch job
        30 s into its 120 s budget — arrival order would invert this."""
        s = _sched()
        batch = _req(0, 0.0, cls="batch", slo_ttft=120.0, slo_tpot=2.0)
        chat = _req(1, 29.0)
        s.on_arrival(batch, 0.0)
        s.on_arrival(chat, 29.0)
        b = s.next_prefill_batch(30.0)
        assert [r.rid for r in b.requests] == [1, 0]

    def test_short_job_bonus_breaks_ties(self):
        s = _sched()
        long = _req(0, 0.0, new=512)
        short = _req(1, 0.0, new=4)
        s.on_arrival(long, 0.0)
        s.on_arrival(short, 0.0)
        assert [r.rid for r in s.next_prefill_batch(0.5).requests] == [1, 0]

    def test_forced_tier_overrides_score(self):
        """A winnable nearly-late request (slack under force_frac of its
        budget) jumps a higher-scoring fresh one."""
        s = _sched()
        fresh = _req(0, 1.4, new=4)          # short-job bonus, young
        late = _req(1, 0.0, new=512)         # slack 0.5 s = 0.25 * budget
        s.on_arrival(fresh, 1.4)
        s.on_arrival(late, 0.0)
        now = 1.5
        assert s._tier(late, now) == 1 and s._tier(fresh, now) == 0
        assert [r.rid for r in s.next_prefill_batch(now).requests] == [1, 0]

    def test_past_deadline_demotes_below_winnable(self):
        """A request that can no longer meet its TTFT earns no goodput:
        it yields the front of the queue to winnable work (but is still
        served — demoted, never dropped)."""
        s = _sched()
        hopeless = _req(0, 0.0)              # 3 s old on a 2 s budget
        fresh = _req(1, 2.9)
        s.on_arrival(hopeless, 0.0)
        s.on_arrival(fresh, 2.9)
        now = 3.0
        assert s._tier(hopeless, now) == -1
        batch = s.next_prefill_batch(now)
        assert [r.rid for r in batch.requests] == [1, 0]

    def test_min_slack_gauge_feeds_monitor(self):
        s = _sched()
        s.on_arrival(_req(0, 0.0), 0.0)
        s.on_arrival(_req(1, 0.5), 0.5)
        assert s.monitor.min_slack_s == math.inf
        s.next_prefill_batch(1.0)            # chat: 2.0 - (1.0 - 0.0)
        assert s.monitor.min_slack_s == pytest.approx(1.0)
        assert s.monitor.snapshot(1.0).min_slack_s == pytest.approx(1.0)

    def test_class_goodput_rolling_window(self):
        m = GlobalMonitor()
        for ok in (True, True, False):
            m.on_retire("chat", {"queue": 0.1}, slo_met=ok)
        m.on_retire("batch", {"queue": 0.1}, slo_met=True)
        snap = m.snapshot(1.0)
        assert snap.class_goodput["chat"] == pytest.approx(2 / 3)
        assert snap.class_goodput["batch"] == pytest.approx(1.0)

    def test_low_min_slack_relieves_admission_backpressure(self):
        """The controller's restore-backlog throttle relaxes when the
        queue's minimum slack is tight — holding admissions back is how
        deadlines get missed under pressure."""
        ctl = DynamicBatchController(CFG, BUDGET)
        args = dict(restore_pages=8, restore_backlog_bytes=1 << 24)
        full = ctl.admission_pressure_tokens(**args)
        assert ctl.admission_pressure_tokens(
            **args, min_slack=math.inf) == full
        relieved = ctl.admission_pressure_tokens(**args, min_slack=0.0)
        assert relieved <= full


# -------------------------------------------- t0 across requeues ---------
class _FirstArrivalRecorder(GoodputScheduler):
    """Records the clock at each rid's FIRST on_arrival and every
    requeue — the ground truth t0() must agree with."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.first_seen = {}
        self.requeued = []

    def on_arrival(self, r, now, requeue=False):
        if requeue:
            self.requeued.append(r.rid)
        else:
            self.first_seen.setdefault(r.rid, now)
        super().on_arrival(r, now, requeue=requeue)


class TestT0AcrossRequeues:
    def test_oom_preempt_requeue_keeps_deadline_anchor(self):
        """Tight paged pool forces mid-decode preemptions; the restart
        penalty overwrites ``arrival`` but every deadline stays anchored
        on the first arrival."""
        sched = _FirstArrivalRecorder(CFG, BUDGET, SchedulerConfig(
            max_batch=4, memory_model="paged", page_size=32))
        sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                        decode_slot_cap=4, paged=True, page_size=32,
                        kv_pool_tokens=5 * 32, cache_len=128)
        rng = np.random.default_rng(3)
        reqs = [Request(rid=i, prompt_len=int(rng.integers(20, 40)),
                        max_new_tokens=int(rng.integers(20, 40)),
                        arrival=0.0, task_type=TaskType.OFFLINE)
                for i in range(6)]
        res = sim.run(reqs)
        assert len(res.finished()) == 6
        assert res.preempt_events > 0 and sched.requeued
        moved = [r for r in reqs if r.arrival != 0.0]
        assert moved, "restart penalty never shifted an arrival"
        for r in reqs:
            assert r.t0() == pytest.approx(sched.first_seen[r.rid])
            assert r.ttft() == pytest.approx(
                r.first_token - sched.first_seen[r.rid])

    def test_restore_hold_keeps_deadline_anchor(self):
        """Session turns parked on a host->device restore re-enter the
        queue through the same funnel; the hold lands on TTFT (anchored
        at first arrival), never resets it."""
        from repro.data.workload import WorkloadSpec, generate
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        sched = _FirstArrivalRecorder(cfg, BUDGET, SchedulerConfig(
            max_batch=4, memory_model="paged", page_size=128))
        sim = Simulator(sched, CostModel(cfg, A100X4), mode="disagg",
                        decode_slot_cap=4, paged=True, page_size=128,
                        kv_pool_tokens=12 * 128, cache_len=1024,
                        session_ttl=1000.0, host_pool_tokens=64 * 128)
        spec = WorkloadSpec(dataset="alpaca", rps=1e6, sessions=3, turns=4,
                            utterance_tokens=200, max_new_tokens=8, seed=7,
                            task_type=TaskType.OFFLINE,
                            max_model_len=cfg.max_seq_len,
                            vocab_size=cfg.vocab_size)
        reqs = generate(spec)
        res = sim.run(reqs)
        assert len(res.finished()) == len(reqs)
        assert res.spill_hold_events > 0
        for r in reqs:
            assert r.t0() == pytest.approx(sched.first_seen[r.rid])
            assert r.first_token >= sched.first_seen[r.rid]
            assert r.ttft() < math.inf


# --------------------------------------- slice-boundary preemption -------
def _preempt_workload(n=6, seed=3, new_lo=20, new_hi=40):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt_len=int(rng.integers(20, 40)),
                    max_new_tokens=int(rng.integers(new_lo, new_hi)),
                    arrival=0.0, task_type=TaskType.OFFLINE)
            for i in range(n)]


class TestSlicePreemption:
    def _engine(self, params, *, pool_tokens, slice_tokens=None):
        sched = BucketServeScheduler(CFG, BUDGET, SchedulerConfig(
            max_batch=4, memory_model="paged", page_size=32))
        return ServingEngine(CFG, params, sched, max_slots=4,
                             cache_len=128, paged=True, page_size=32,
                             kv_pool_tokens=pool_tokens,
                             slice_tokens=slice_tokens)

    def test_engine_yield_resume_bit_identical(self):
        """Pool exhaustion forces mid-generation yields at slice
        boundaries; every resumed request's output stream equals the
        uncontended reference bit for bit — generated work survives."""
        params = tfm.init_params(CFG, jax.random.PRNGKey(0))
        eng = self._engine(params, pool_tokens=5 * 32, slice_tokens=4)
        reqs = _preempt_workload()
        eng.submit(reqs)
        assert len(eng.run(max_wall_s=600)) == 6
        assert eng.result.slice_yields > 0
        sliced = [r for r in reqs if r.sliced_tokens > 0]
        assert sliced, "no request ever yielded at a slice boundary"
        for r in sliced:
            assert r.sliced_tokens % 4 == 0
            assert r.first_token >= 0          # first token NOT reset

        ref = self._engine(params, pool_tokens=None)
        ref.submit([dataclasses.replace(r, arrival=0.0, generated=0,
                                        prompt_len=r.prompt_len
                                        - r.sliced_tokens,
                                        tokens=None if r.tokens is None
                                        else r.tokens[:r.prompt_len
                                                      - r.sliced_tokens],
                                        sliced_tokens=0, first_token=-1.0,
                                        prefill_start=-1.0, finished=-1.0)
                    for r in reqs])
        ref.run(max_wall_s=600)
        for r in reqs:
            assert len(eng.outputs[r.rid]) == r.max_new_tokens
            assert eng.outputs[r.rid] == ref.outputs[r.rid], f"rid={r.rid}"

    def test_sim_slice_yield_promotes_generated_prefix(self):
        """Cost-model backend: a slice yield promotes the generated
        prefix into the prompt (same contract as the engine) and the
        stream continues bit-identically from the kept boundary."""
        sched = BucketServeScheduler(CFG, BUDGET, SchedulerConfig(
            max_batch=4, memory_model="paged", page_size=32))
        sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                        decode_slot_cap=4, paged=True, page_size=32,
                        kv_pool_tokens=5 * 32, cache_len=128,
                        slice_tokens=4)
        reqs = _preempt_workload()
        for r in reqs:
            r.materialize_tokens(CFG.vocab_size)
        orig_prompt = {r.rid: r.prompt_len for r in reqs}
        res = sim.run(reqs)
        assert len(res.finished()) == 6
        assert res.slice_yields > 0
        sliced = [r for r in reqs if r.sliced_tokens > 0]
        assert sliced
        for r in sliced:
            assert r.prompt_len == orig_prompt[r.rid] + r.sliced_tokens
            # the promoted prompt suffix IS the generated stream prefix
            stream = np.asarray(sim.backend.generated_tokens(r), np.int32)
            np.testing.assert_array_equal(
                r.tokens[orig_prompt[r.rid]:r.prompt_len],
                stream[:r.sliced_tokens])
        for r in reqs:
            assert r.generated == r.max_new_tokens

    def test_session_turns_never_sliced(self):
        """Slice yields promote generated ids into the prompt, which
        would corrupt a session transcript — session turns always take
        the reset path."""
        from repro.data.workload import WorkloadSpec, generate
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        sched = BucketServeScheduler(cfg, BUDGET, SchedulerConfig(
            max_batch=4, memory_model="paged", page_size=128))
        sim = Simulator(sched, CostModel(cfg, A100X4), mode="disagg",
                        decode_slot_cap=4, paged=True, page_size=128,
                        kv_pool_tokens=12 * 128, cache_len=1024,
                        session_ttl=1000.0, slice_tokens=4)
        spec = WorkloadSpec(dataset="alpaca", rps=1e6, sessions=3, turns=4,
                            utterance_tokens=200, max_new_tokens=16, seed=7,
                            task_type=TaskType.OFFLINE,
                            max_model_len=cfg.max_seq_len,
                            vocab_size=cfg.vocab_size)
        reqs = generate(spec)
        res = sim.run(reqs)
        assert len(res.finished()) == len(reqs)
        for r in reqs:
            assert r.sliced_tokens == 0


# ----------------------------------------------- backend parity ----------
def _record_dispatched(backend, log):
    """Batch compositions that actually DISPATCH (survive admission) —
    same parity surface as tests/test_kv_spill.py."""
    orig = backend.prefill_chunk

    def rec(job, idx, _orig=orig, _log=log):
        if idx == 0:
            _log.append(tuple(r.rid for r in job.batch.requests))
        return _orig(job, idx)

    backend.prefill_chunk = rec


def _record_victims(backend, log):
    """Preemption victims, at the decision point.  (Requeue order as
    seen by the scheduler is NOT parity-comparable: slot/page clamp
    requeues recur every tick while pages are short, and tick cadence
    is a clock property.)"""
    orig = backend.decode_preempt

    def rec(pool, _orig=orig, _log=log):
        victims = _orig(pool)
        if victims:
            _log.append(tuple(v.rid for v in victims))
        return victims

    backend.decode_preempt = rec


class TestGoodputBackendParity:
    """Engine vs cost model under the GoodputScheduler with a pool tight
    enough to preempt: identical dispatched batches, identical requeue
    (victim) order, identical slice-yield outcomes."""

    def _sched(self):
        return GoodputScheduler(CFG, BUDGET, SchedulerConfig(
            max_batch=4, memory_model="paged", page_size=32))

    def _workload(self):
        # uniform max_new keeps tier/score ordering clock-independent
        rng = np.random.default_rng(11)
        return [Request(rid=i, prompt_len=int(rng.integers(20, 40)),
                        max_new_tokens=24, arrival=0.0,
                        task_type=TaskType.ONLINE) for i in range(6)]

    def test_batches_victims_and_slices_match(self):
        sched_sim = self._sched()
        sim = Simulator(sched_sim, CostModel(CFG, A100X4), mode="disagg",
                        decode_slot_cap=4, paged=True, page_size=32,
                        kv_pool_tokens=5 * 32, cache_len=128,
                        slice_tokens=4)
        disp_sim, vic_sim = [], []
        _record_dispatched(sim.backend, disp_sim)
        _record_victims(sim.backend, vic_sim)
        res_sim = sim.run(self._workload())
        assert len(res_sim.finished()) == 6
        assert res_sim.preempt_events > 0

        params = tfm.init_params(CFG, jax.random.PRNGKey(0))
        sched_eng = self._sched()
        eng = ServingEngine(CFG, params, sched_eng, max_slots=4,
                            cache_len=128, paged=True, page_size=32,
                            kv_pool_tokens=5 * 32, slice_tokens=4)
        disp_eng, vic_eng = [], []
        _record_dispatched(eng.backend, disp_eng)
        _record_victims(eng.backend, vic_eng)
        eng.submit(self._workload())
        assert len(eng.run(max_wall_s=600)) == 6
        res_eng = eng.result

        assert disp_sim == disp_eng
        assert vic_sim == vic_eng and vic_sim
        assert res_sim.preempt_events == res_eng.preempt_events
        assert res_sim.slice_yields == res_eng.slice_yields > 0
        assert {r.rid: (r.sliced_tokens, r.generated)
                for r in res_sim.requests} == \
               {r.rid: (r.sliced_tokens, r.generated)
                for r in res_eng.requests}
