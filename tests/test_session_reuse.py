"""Unified KV retention + multi-turn session resume (DESIGN.md §3
"Session retention").

The tentpole claims under test:

* release is a RETENTION policy, not a free: a finished request's full
  transcript (prompt + generated[:-1] — the last token's KV is never
  written) extends the radix path, and the partial tail page stays
  pinned privately under the session key with a TTL;
* the next turn of a session re-sends the transcript as its prompt
  prefix and resumes past ALL of it — full pages by radix reference,
  the unaligned tail by pin hand-over — with token ids BIT-IDENTICAL
  to a cold re-prefill (acceptance: multi-turn workload, page 128,
  same HBM budget, >= 60% fewer prefilled prompt tokens on turns >= 2);
* eviction walks ONE ordered policy: expired sessions -> LRU cold
  prefixes -> live sessions -> (only then) preemption, so a pinned
  session is always unpinned before any live request loses work;
* engine and cost-model backends form identical batches AND identical
  session hit counts (backend parity extends to the session table);
* satellites: the scheduler's earliest-online bucket pick no longer
  rescans every queued request per tick (timing-free regression vs the
  quadratic reference); `_live_tokens` window capping lives in
  SchedulerBase (baselines included); workload generation is
  seed-stable across calls for every family.
"""
import numpy as np
import pytest

from repro.core.bucket import BucketManager
from repro.core.paging import BlockAllocator, admit_blocks, extend_for_decode
from repro.core.request import Request, TaskType
from repro.core.retention import KvRetention
from repro.data.workload import WorkloadSpec, generate

PAGE = 8


def _req(rid, plen=10, mnt=4, arrival=0.0, sid=None, turn=0):
    return Request(rid=rid, prompt_len=plen, max_new_tokens=mnt,
                   arrival=arrival, session_id=sid, turn=turn)


def _toks(seed, n):
    return np.random.default_rng(seed).integers(0, 1000, n).astype(np.int32)


def _release(rt, a, req, path, now=0.0):
    """Finish ``req`` whose pool KV covers ``path`` tokens."""
    req.generated = max(req.generated, 1)
    rt.on_release(a, req, path, now)


# ------------------------------------------------------- retention unit ---
class TestRetentionRelease:
    def test_release_registers_full_transcript_and_pins_tail(self):
        """Release with sessions on: full pages (prompt AND generated)
        join the radix, the partial tail stays pinned, everything else
        frees."""
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=10.0)
        r = _req(0, plen=2 * PAGE - 2, sid=7)
        path = _toks(0, 3 * PAGE + 3)       # prompt + generated KV path
        a.alloc(0, len(path) + 1)           # table spans the transcript
        t = a.table(0)
        _release(rt, a, r, path, now=1.0)
        assert not a.holds(0)
        assert len(rt.prefix) == 3          # 3 full transcript pages
        assert rt.prefix.pinned_pages() == t[:3]
        e = rt.sessions[7]
        assert e.tail_page == t[3] and a.refs(t[3]) == 1   # session pin
        assert e.expires_at == pytest.approx(11.0)
        assert rt.stats.sessions_retained == 1
        # free + unique-live == total with exactly the 4 retained pages
        assert a.live_pages() == 4
        assert a.free_pages() + a.live_pages() == a.n_pages

    def test_sessions_disabled_keeps_free_on_release(self):
        """session_ttl=None: the retention layer degenerates to the PR 3
        behaviour — release frees, nothing new enters the radix."""
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=None)
        r = _req(0, plen=PAGE, sid=7)
        a.alloc(0, 3 * PAGE)
        _release(rt, a, r, _toks(0, 3 * PAGE), now=1.0)
        assert len(rt.prefix) == 0 and not rt.sessions
        assert a.free_pages() == a.n_pages

    def test_next_turn_resumes_full_transcript_with_tail(self):
        """The resumed turn's hit covers the UNALIGNED transcript: radix
        pages by reference, the pinned tail transferred into its table
        at the right index."""
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=10.0)
        r0 = _req(0, sid=3, turn=0)
        path = _toks(1, 2 * PAGE + 5)
        a.alloc(0, len(path) + 1)
        t0 = a.table(0)
        _release(rt, a, r0, path)

        r1 = _req(1, plen=len(path) + 6, sid=3, turn=1)
        r1.tokens = np.concatenate([path, _toks(2, 6)])
        n = admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                         cache=rt, tokens_of=lambda r: r.tokens)
        assert n == 1
        assert r1.prefix_hit_tokens == len(path)        # NOT page-aligned
        assert r1.session_hit_tokens == len(path)
        assert a.table(1)[:3] == t0[:3]                 # radix + tail pages
        assert a.refs(t0[2]) == 1                       # tail now private
        assert 3 not in rt.sessions                     # entry consumed
        assert rt.stats.session_hits == 1
        assert rt.stats.tail_reuses == 1
        assert rt.stats.session_hit_tokens == len(path)

    def test_diverging_prompt_gets_radix_only(self):
        """A next 'turn' whose ids diverge inside the tail must NOT get
        the tail page (its KV is only valid for the exact path) — the
        radix full-page run still serves."""
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=10.0)
        r0 = _req(0, sid=3)
        path = _toks(3, 2 * PAGE + 5)
        a.alloc(0, len(path) + 1)
        _release(rt, a, r0, path)
        diverged = np.concatenate([path, _toks(4, 6)])
        diverged[2 * PAGE + 2] += 1                     # inside the tail
        r1 = _req(1, plen=len(diverged), sid=3, turn=1)
        r1.tokens = diverged
        assert admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                            cache=rt, tokens_of=lambda r: r.tokens) == 1
        assert r1.prefix_hit_tokens == 2 * PAGE         # page-aligned only
        assert r1.session_hit_tokens == 0
        assert 3 in rt.sessions                         # entry survives
        assert rt.stats.session_hits == 0

    def test_wrong_session_never_gets_anothers_tail(self):
        """Same token path, different session id: radix sharing yes,
        tail hand-over no."""
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=10.0)
        r0 = _req(0, sid=3)
        path = _toks(5, 2 * PAGE + 5)
        a.alloc(0, len(path) + 1)
        _release(rt, a, r0, path)
        r1 = _req(1, plen=len(path) + 4, sid=99, turn=1)
        r1.tokens = np.concatenate([path, _toks(6, 4)])
        assert admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                            cache=rt, tokens_of=lambda r: r.tokens) == 1
        assert r1.prefix_hit_tokens == 2 * PAGE
        assert r1.session_hit_tokens == 0
        assert 3 in rt.sessions


class TestRetentionTtlAndPressure:
    def test_ttl_tick_unpins_expired_sessions(self):
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=5.0)
        r0 = _req(0, sid=1)
        path = _toks(7, PAGE + 3)
        a.alloc(0, len(path) + 1)
        _release(rt, a, r0, path, now=0.0)
        assert rt.live_sessions() == 1
        assert rt.tick(a, 4.9) == 0                     # not yet
        assert rt.live_sessions() == 1
        freed = rt.tick(a, 5.0)                         # expired
        assert freed == 1 and rt.live_sessions() == 0
        assert rt.stats.sessions_expired == 1
        # the radix full page stays (it is independent LRU state)
        assert len(rt.prefix) == 1

    def test_expired_session_not_resumable(self):
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=5.0)
        r0 = _req(0, sid=1)
        path = _toks(8, PAGE + 3)
        a.alloc(0, len(path) + 1)
        _release(rt, a, r0, path, now=0.0)
        rt.tick(a, 100.0)
        r1 = _req(1, plen=len(path) + 2, sid=1, turn=1)
        r1.tokens = np.concatenate([path, _toks(9, 2)])
        assert admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                            cache=rt, tokens_of=lambda r: r.tokens) == 1
        assert r1.session_hit_tokens == 0
        assert r1.prefix_hit_tokens == PAGE             # radix survives TTL

    def test_eviction_order_expired_then_prefix_then_live_sessions(self):
        """The ONE ordered policy: expired session tails first, then
        LRU cold radix prefixes, then live session tails."""
        a = BlockAllocator(n_pages=6, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=5.0)
        # session 1 (will expire): 1 full page + tail
        r0 = _req(0, sid=1)
        p0 = _toks(10, PAGE + 2)
        a.alloc(0, len(p0) + 1)
        _release(rt, a, r0, p0, now=0.0)
        # session 2 (stays live): 1 full page + tail
        r1 = _req(1, sid=2)
        p1 = _toks(11, PAGE + 2)
        a.alloc(1, len(p1) + 1)
        _release(rt, a, r1, p1, now=4.0)
        rt.tick(a, 6.0)                     # sid 1 expired but NOT ticked
        assert rt.live_sessions() == 1      # ... tick already dropped it
        assert rt.stats.sessions_expired == 1
        # 2 radix pages + live tail pinned; evict 1: the LRU radix page
        # goes before the live session tail
        live_tail = rt.sessions[2].tail_page
        assert rt.evict(a, 1) == 1
        assert rt.live_sessions() == 1
        assert a.refs(live_tail) == 1
        assert rt.prefix.stats.evictions == 1
        # keep evicting: second radix page, THEN the live session tail
        assert rt.evict(a, 2) == 2
        assert rt.live_sessions() == 0
        assert rt.stats.sessions_evicted == 1
        assert a.free_pages() == a.n_pages

    def test_pressure_unpins_session_before_preempting_live_request(self):
        """Acceptance: under page pressure the retained session is
        sacrificed before ANY live request is preempted."""
        a = BlockAllocator(n_pages=4, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=1000.0)   # far from expiry
        r0 = _req(0, sid=1)
        p0 = _toks(12, PAGE + 2)
        a.alloc(0, len(p0) + 1)
        _release(rt, a, r0, p0, now=0.0)             # 2 pages retained
        # two live requests fill the rest
        old = _req(1, plen=PAGE - 1, arrival=0.0)
        yng = _req(2, plen=PAGE - 1, arrival=1.0)
        a.alloc(1, PAGE)
        a.alloc(2, PAGE)
        assert a.free_pages() == 0
        old.generated = PAGE
        yng.generated = PAGE
        victims = extend_for_decode(
            a, [old, yng], lambda r: r.prompt_len + 1 + r.generated,
            cache=rt)
        assert victims == []                         # NOBODY preempted
        assert rt.live_sessions() == 0               # session paid instead
        assert rt.stats.sessions_evicted + rt.stats.sessions_expired >= 1
        assert len(rt.prefix) <= 1

    def test_admission_pressure_also_unpins_sessions(self):
        a = BlockAllocator(n_pages=4, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=1000.0)
        r0 = _req(0, sid=1)
        p0 = _toks(13, 3 * PAGE + 2)
        a.alloc(0, 4 * PAGE)
        _release(rt, a, r0, p0, now=0.0)             # all 4 pages retained
        cold = _req(1, plen=2 * PAGE - 1)
        cold.tokens = _toks(14, cold.prompt_len)
        assert admit_blocks(a, [cold], lambda r: r.prompt_len + 1,
                            cache=rt, tokens_of=lambda r: r.tokens) == 1
        assert a.holds(1)
        assert rt.live_sessions() == 0 or len(rt.prefix) < 3

    def test_failed_admission_aborts_claim(self):
        """If allocation fails after the session was claimed, the entry
        must stay resumable (claim rolled back, nothing unpinned)."""
        a = BlockAllocator(n_pages=4, page_size=PAGE)
        rt = KvRetention(PAGE, session_ttl=1000.0)
        r0 = _req(0, sid=1)
        p0 = _toks(15, PAGE + 2)
        a.alloc(0, 2 * PAGE)
        _release(rt, a, r0, p0, now=0.0)
        # a fat live request leaves too little room for the next turn
        a.alloc(5, 2 * PAGE)
        r1 = _req(1, plen=6 * PAGE, sid=1, turn=1)
        r1.tokens = np.concatenate([p0, _toks(16, 6 * PAGE - len(p0))])
        assert admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                            cache=rt, tokens_of=lambda r: r.tokens) == 0
        e = rt.sessions[1]
        assert e.claimed_by is None                  # rolled back
        assert r1.session_hit_tokens == 0
        assert a.refs(e.tail_page) >= 1              # still pinned


# --------------------------------------------------- engine end to end ----
import jax                                                    # noqa: E402

from repro.configs import get_smoke_config                    # noqa: E402
from repro.core import (BucketServeScheduler, MemoryBudget,   # noqa: E402
                        SchedulerConfig)
from repro.core.engine import ServingEngine                   # noqa: E402
from repro.core.simulator import (A100X4, CostModel,          # noqa: E402
                                  Simulator)
from repro.models import transformer as tfm                   # noqa: E402

BUDGET = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                      weight_bytes=0)
PAGE_E = 128


def _session_workload(cfg, *, sessions=2, turns=4, utter=250, out=6,
                      seed=7):
    spec = WorkloadSpec(dataset="alpaca", rps=1e6, sessions=sessions,
                        turns=turns, utterance_tokens=utter,
                        max_new_tokens=out, seed=seed,
                        task_type=TaskType.OFFLINE,
                        max_model_len=cfg.max_seq_len,
                        vocab_size=cfg.vocab_size)
    return generate(spec)


def _engine(cfg, params, *, session_ttl, prefix_cache=False, slots=4,
            pool_tokens=64 * PAGE_E, chunk_tokens=None):
    sched = BucketServeScheduler(cfg, BUDGET, SchedulerConfig(
        max_batch=slots, memory_model="paged", page_size=PAGE_E))
    return ServingEngine(cfg, params, sched, max_slots=slots,
                         cache_len=cfg.max_seq_len, paged=True,
                         page_size=PAGE_E, kv_pool_tokens=pool_tokens,
                         chunk_tokens=chunk_tokens,
                         prefix_cache=prefix_cache, session_ttl=session_ttl)


class TestSessionResumeEngine:
    """Acceptance (ISSUE 4): multi-turn workload, page 128, same HBM
    budget — every turn's token ids bit-identical to a cold run with
    >= 60% fewer prefilled prompt tokens across turns >= 2."""

    def _run(self, cfg, params, session_ttl, **kw):
        reqs = _session_workload(cfg, **{k: v for k, v in kw.items()
                                         if k in ("sessions", "turns",
                                                  "utter", "out", "seed")})
        eng = _engine(cfg, params, session_ttl=session_ttl,
                      **{k: v for k, v in kw.items()
                         if k in ("prefix_cache", "slots", "pool_tokens",
                                  "chunk_tokens")})
        eng.submit(reqs)
        done = eng.run(max_wall_s=600)
        assert len(done) == len(reqs)
        return eng, reqs

    def test_resumed_turns_bit_identical_and_60pct_fewer_prefill(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs, pre, res = {}, {}, {}
        for ttl in (None, 1000.0):
            eng, reqs = self._run(cfg, params, ttl)
            outs[ttl] = {r.rid: eng.outputs[r.rid] for r in reqs}
            # prompts are composed at runtime from actual outputs —
            # record them too, the cold/resumed transcripts must agree
            outs[ttl].update({(r.rid, "p"): r.tokens.tolist()
                              for r in reqs})
            pre[ttl] = {r.rid: (r.turn, r.prefilled_tokens) for r in reqs}
            res[ttl] = eng.result
            for r in reqs:
                assert len(eng.outputs[r.rid]) == r.max_new_tokens
            be = eng.backend
            # allocator invariant: free + unique-live == total; at run
            # end only the retention layer's pins remain live
            assert be.alloc.free_pages() + be.alloc.live_pages() \
                == be.alloc.n_pages
            if ttl is not None:
                assert be.alloc.live_pages() > 0
                assert be.retention.clear(be.alloc) > 0
                assert be.alloc.free_pages() == be.alloc.n_pages
            else:
                assert be.alloc.live_pages() == 0

        assert outs[1000.0] == outs[None]     # bit-identical token ids
        cold_t2 = sum(p for t, p in pre[None].values() if t >= 2)
        warm_t2 = sum(p for t, p in pre[1000.0].values() if t >= 2)
        assert warm_t2 <= 0.4 * cold_t2, (warm_t2, cold_t2)
        r = res[1000.0]
        # 3 resumable turns per session, all resumed (incl. the tail)
        assert r.session_hits == 6 and r.session_lookups == 8
        assert r.tail_pages_reused == 6
        assert r.sessions_retained == 8
        assert r.session_hit_tokens > 0
        assert res[None].session_lookups == 0

    def test_composes_with_chunked_prefill(self):
        """Resumed spans at non-page-aligned offsets must stay
        positionally exact under chunking too."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs = {}
        for ttl in (None, 1000.0):
            eng, reqs = self._run(cfg, params, ttl, chunk_tokens=96,
                                  sessions=1, turns=3, utter=200, out=5)
            outs[ttl] = {r.rid: eng.outputs[r.rid] for r in reqs}
        assert outs[1000.0] == outs[None]

    def test_eviction_under_pressure_stays_correct(self):
        """A pool tight enough to force session/prefix eviction and
        preemption: outputs still match the ample-pool resumed run."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs = {}
        for pool in (64 * PAGE_E, 18 * PAGE_E):
            eng, reqs = self._run(cfg, params, 1000.0, pool_tokens=pool,
                                  sessions=2, turns=3, utter=220, out=8)
            outs[pool] = {r.rid: eng.outputs[r.rid] for r in reqs}
            for r in reqs:
                assert len(eng.outputs[r.rid]) == r.max_new_tokens
        assert outs[64 * PAGE_E] == outs[18 * PAGE_E]

    def test_first_token_only_turns_never_retained(self):
        """Regression: a max_new_tokens=1 row is never scattered into
        the pool — retaining it would index pages holding NO transcript
        KV into the radix, and the next turn would resume onto garbage.
        Such turns must stay cold (and bit-identical) in both runs."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs = {}
        for ttl in (None, 1000.0):
            eng, reqs = self._run(cfg, params, ttl, sessions=2, turns=2,
                                  utter=250, out=1)
            outs[ttl] = {r.rid: eng.outputs[r.rid] for r in reqs}
            if ttl is not None:
                assert eng.result.sessions_retained == 0
                assert eng.result.session_hits == 0
                assert eng.backend.alloc.live_pages() == 0
        assert outs[1000.0] == outs[None]

    def test_ttl_zero_disables_resume_but_not_radix(self):
        """session_ttl=0: every entry expires before the next turn —
        no session hits, but transcript full pages still serve via the
        plain radix (page-aligned only)."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        eng, reqs = self._run(cfg, params, 0.0, sessions=1, turns=3)
        r = eng.result
        assert r.session_hits == 0 and r.tail_pages_reused == 0
        assert r.sessions_expired > 0
        assert r.prefix_hit_tokens > 0        # radix reuse survives TTL
        for q in reqs:
            assert q.prefix_hit_tokens % PAGE_E == 0


class _RecordingScheduler(BucketServeScheduler):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.formed = []

    def next_prefill_batch(self, now):
        batch = super().next_prefill_batch(now)
        if batch is not None:
            self.formed.append(tuple(r.rid for r in batch.requests))
        return batch


class TestSessionBackendParity:
    """CostModelBackend mirrors the engine's session retention:
    identical formed batches AND identical session hit counts on the
    same multi-turn workload (each backend composes transcripts from
    its OWN generated ids — the structure, lengths and therefore every
    admission decision must still agree)."""

    SLOTS = 4

    def _sched(self, cfg):
        return _RecordingScheduler(cfg, BUDGET, SchedulerConfig(
            max_batch=self.SLOTS, memory_model="paged",
            page_size=PAGE_E))

    def _workload(self, cfg):
        reqs = _session_workload(cfg, sessions=2, turns=3, utter=220,
                                 out=4)
        for r in reqs:      # session starts queued up-front: identical
            r.arrival = 0.0  # first ticks on wall and virtual clocks
        return reqs

    def test_same_batches_and_session_hit_counts(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        pool_tokens = 64 * PAGE_E
        n = 6                                 # 2 sessions x 3 turns

        sched_sim = self._sched(cfg)
        sim = Simulator(sched_sim, CostModel(cfg, A100X4), mode="disagg",
                        decode_slot_cap=self.SLOTS, paged=True,
                        page_size=PAGE_E, kv_pool_tokens=pool_tokens,
                        cache_len=cfg.max_seq_len, session_ttl=1000.0)
        res_sim = sim.run(self._workload(cfg))
        assert len(res_sim.finished()) == n

        sched_eng = self._sched(cfg)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, sched_eng, max_slots=self.SLOTS,
                            cache_len=cfg.max_seq_len, paged=True,
                            page_size=PAGE_E, kv_pool_tokens=pool_tokens,
                            session_ttl=1000.0)
        eng.submit(self._workload(cfg))
        assert len(eng.run(max_wall_s=300)) == n
        res_eng = eng.result

        assert sched_sim.formed == sched_eng.formed
        assert res_sim.session_lookups == res_eng.session_lookups > 0
        assert res_sim.session_hits == res_eng.session_hits > 0
        assert res_sim.session_hit_tokens == res_eng.session_hit_tokens
        assert res_sim.tail_pages_reused == res_eng.tail_pages_reused > 0
        assert res_sim.sessions_retained == res_eng.sessions_retained
        assert res_sim.prefix_hit_tokens == res_eng.prefix_hit_tokens
        assert res_sim.prefill_tokens_skipped \
            == res_eng.prefill_tokens_skipped > 0


# ------------------------------------------------ earliest-online pick ----
def _quadratic_pick(manager, offline_policy="sjf"):
    """The pre-PR-4 formulation: rescan every request in every bucket."""
    nonempty = manager.nonempty()
    if not nonempty:
        return None
    online = [b for b in nonempty
              if any(r.task_type == TaskType.ONLINE for r in b.requests)]
    if online:
        return min(online, key=lambda b: min(
            r.arrival for r in b.requests
            if r.task_type == TaskType.ONLINE))
    if offline_policy == "sjf":
        return min(nonempty, key=lambda b: b.low)
    return max(nonempty, key=lambda b: b.up)


def _incremental_pick(manager, offline_policy="sjf"):
    """What BucketServeScheduler._pick_bucket now does (cached mins)."""
    nonempty = manager.nonempty()
    if not nonempty:
        return None
    online = [b for b in nonempty if b.earliest_online() is not None]
    if online:
        return min(online, key=lambda b: b.earliest_online())
    if offline_policy == "sjf":
        return min(nonempty, key=lambda b: b.low)
    return max(nonempty, key=lambda b: b.up)


class TestEarliestOnlineIncremental:
    def test_pick_matches_quadratic_reference_through_churn(self):
        """Timing-free regression: over a random add/adjust/pop churn
        the cached earliest-online pick equals the full-rescan pick at
        EVERY tick (including after splits and merges)."""
        rng = np.random.default_rng(0)
        bm = BucketManager(l_max=4096)
        live = []
        rid = 0
        for step in range(300):
            for _ in range(int(rng.integers(1, 5))):      # arrivals
                r = Request(rid=rid, prompt_len=int(rng.integers(1, 4095)),
                            max_new_tokens=4,
                            arrival=float(rng.integers(0, 1000)),
                            task_type=TaskType.ONLINE if rng.random() < 0.5
                            else TaskType.OFFLINE)
                bm.add(r)
                live.append(r)
                rid += 1
            bm.adjust(n_max=int(rng.integers(1, 12)))     # split/merge
            got = _incremental_pick(bm)
            ref = _quadratic_pick(bm)
            assert (got is None) == (ref is None)
            if got is not None:
                assert (got.low, got.up) == (ref.low, ref.up)
                assert got.earliest_online() == (
                    min((r.arrival for r in got.requests
                         if r.task_type == TaskType.ONLINE), default=None))
            if live and rng.random() < 0.7:               # dispatch (pop)
                k = int(rng.integers(1, min(len(live), 8) + 1))
                idx = rng.choice(len(live), size=k, replace=False)
                batch = [live[i] for i in idx]
                bm.pop(batch)
                live = [r for i, r in enumerate(live) if i not in set(idx)]
        assert bm.total() == len(live)

    def test_requeue_with_new_arrival_reflected(self):
        """A popped request re-added with a mutated (penalised) arrival
        must update the cached min."""
        bm = BucketManager(l_max=1024)
        r = Request(rid=0, prompt_len=10, max_new_tokens=4, arrival=1.0,
                    task_type=TaskType.ONLINE)
        bm.add(r)
        assert bm.buckets[0].earliest_online() == 1.0
        bm.pop([r])
        assert bm.buckets[0].earliest_online() is None
        r.arrival = 9.0
        bm.add(r)
        assert bm.buckets[0].earliest_online() == 9.0


# --------------------------------------------------- _live_tokens dedupe --
class TestLiveTokensWindowCap:
    def test_baseline_scheduler_window_caps_in_flight_charge(self):
        """Satellite: the sliding-window cap moved into SchedulerBase —
        a windowed config through a BASELINE scheduler must charge
        min(window, prompt+output), not the uncapped sum."""
        from repro.configs import get_smoke_config
        from repro.core.baselines import StaticBatchScheduler
        from repro.core.batcher import MemoryBudget
        from repro.core.scheduler import BucketServeScheduler, \
            SchedulerConfig
        cfg = get_smoke_config("qwen3-14b", max_seq_len=4096,
                               sliding_window=64)
        budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                              weight_bytes=0)
        r = Request(rid=0, prompt_len=1000, max_new_tokens=200, arrival=0.0)
        base = StaticBatchScheduler(cfg, budget)
        base.admit_decode(r)
        assert base.monitor.in_flight_tokens == 64
        base.release_decode(r)
        assert base.monitor.in_flight_tokens == 0
        # and it matches BucketServe's charge exactly (one rule, hoisted)
        bs = BucketServeScheduler(cfg, budget, SchedulerConfig())
        assert bs._live_tokens(r) == base._live_tokens(r) == 64

    def test_unwindowed_charge_unchanged(self):
        from repro.configs import get_smoke_config
        from repro.core.baselines import StaticBatchScheduler
        from repro.core.batcher import MemoryBudget
        cfg = get_smoke_config("qwen3-14b", max_seq_len=4096)
        budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                              weight_bytes=0)
        r = Request(rid=0, prompt_len=1000, max_new_tokens=200, arrival=0.0)
        s = StaticBatchScheduler(cfg, budget)
        s.admit_decode(r)
        assert s.monitor.in_flight_tokens == 1200


# ------------------------------------------------ workload determinism ----
class TestWorkloadDeterminism:
    """Satellite: the SAME spec must regenerate identical requests and
    token ids across calls — parity tests regenerate workloads per
    backend and rely on it."""

    def _assert_identical(self, a, b):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert (x.rid, x.prompt_len, x.max_new_tokens, x.arrival,
                    x.session_id, x.turn, x.history_tokens,
                    x.think_gap) == \
                   (y.rid, y.prompt_len, y.max_new_tokens, y.arrival,
                    y.session_id, y.turn, y.history_tokens, y.think_gap)
            for f in ("tokens", "utterance"):
                xa, ya = getattr(x, f), getattr(y, f)
                assert (xa is None) == (ya is None)
                if xa is not None:
                    assert np.array_equal(xa, ya)

    def test_classic_family_seed_stable(self):
        spec = WorkloadSpec(dataset="mixed", n_requests=64, seed=11,
                            max_model_len=4096)
        self._assert_identical(generate(spec), generate(spec))

    def test_prefix_family_seed_stable(self):
        spec = WorkloadSpec(dataset="alpaca", n_requests=48, seed=12,
                            max_model_len=2048, prefix_groups=3,
                            prefix_tokens=128, vocab_size=1000)
        self._assert_identical(generate(spec), generate(spec))

    def test_session_family_seed_stable(self):
        spec = WorkloadSpec(dataset="alpaca", sessions=5, turns=4,
                            seed=13, max_model_len=4096, rps=2.0,
                            think_time_s=3.0, vocab_size=1000)
        self._assert_identical(generate(spec), generate(spec))

    def test_window_exhausted_session_truncates(self):
        """Regression: a transcript that exactly fills the window must
        END the session, not emit a turn with prompt_len >
        max_model_len (the engine would silently clamp its KV)."""
        spec = WorkloadSpec(dataset="alpaca", sessions=1, turns=3,
                            seed=0, max_model_len=64,
                            utterance_tokens=40, max_new_tokens=24,
                            vocab_size=1000)
        reqs = generate(spec)
        assert 1 <= len(reqs) < 3                # truncated, not oversized
        for r in reqs:
            assert r.prompt_len + r.max_new_tokens <= 64

    def test_session_family_shape(self):
        spec = WorkloadSpec(dataset="alpaca", sessions=3, turns=4,
                            seed=14, max_model_len=8192,
                            utterance_tokens=100, max_new_tokens=20,
                            vocab_size=1000)
        reqs = generate(spec)
        assert len(reqs) == 12
        by_sid = {}
        for r in reqs:
            by_sid.setdefault(r.session_id, []).append(r)
        for sid, turns in by_sid.items():
            turns.sort(key=lambda r: r.turn)
            transcript = 0
            for t, r in enumerate(turns):
                assert r.turn == t
                assert r.history_tokens == transcript
                assert r.prompt_len == transcript + len(r.utterance)
                if t == 0:
                    assert np.array_equal(r.tokens, r.utterance)
                    assert r.think_gap == 0.0
                else:
                    assert r.tokens is None      # composed by the loop
                assert r.prompt_len + r.max_new_tokens <= 8192
                transcript = r.prompt_len + r.max_new_tokens
