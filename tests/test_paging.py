"""Paged KV accounting: BlockAllocator invariants (unit + hypothesis
property tests) and the shared admission/extension/preemption policies
both execution backends drive (core/paging.py, DESIGN.md §3).

Invariants (generalized for refcounted prefix sharing, PR 3, and the
host spill tier, PR 5):
  * a page's refcount always equals (#live tables holding it) + (#pins)
    — no page is freed while referenced;
  * free + unique-live + spilled == total: device pages satisfy
    free + unique-live == n_pages (a spilled page's HBM genuinely
    frees) and host slots satisfy free-host + spilled == host_pages,
    across any alloc/share/extend/pin/unpin/release/spill/restore
    interleaving;
  * a SHARED page never spills (refused unless the caller's pin is the
    last reference); restore is idempotent (begin returns the same
    reserved page, a second commit is a no-op);
  * a live request's table covers exactly ceil(tokens / page_size)
    pages;
  * alloc/extend are all-or-nothing (failed calls change nothing);
  * release is idempotent per rid.
"""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core.paging import (BlockAllocator, admit_blocks,
                               device_pool_pages, extend_for_decode,
                               host_tier_geometry)
from repro.core.request import Request

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # unit tests below still run without it
    HAVE_HYPOTHESIS = False


def _req(rid, plen=10, mnt=4, arrival=0.0):
    return Request(rid=rid, prompt_len=plen, max_new_tokens=mnt,
                   arrival=arrival)


# ------------------------------------------------------------ unit tests --
class TestBlockAllocator:
    def test_alloc_covers_ceil_pages(self):
        a = BlockAllocator(n_pages=10, page_size=16)
        assert len(a.alloc(0, 1)) == 1
        assert len(a.alloc(1, 16)) == 1
        assert len(a.alloc(2, 17)) == 2
        assert a.free_pages() == 6
        assert a.live_pages() == 4

    def test_exhaustion_is_all_or_nothing(self):
        a = BlockAllocator(n_pages=3, page_size=8)
        assert a.alloc(0, 16) is not None            # 2 pages
        free_before = a.free_pages()
        assert a.alloc(1, 17) is None                # needs 3, has 1
        assert a.free_pages() == free_before         # state unchanged
        assert not a.holds(1)

    def test_extend_grows_by_pages(self):
        a = BlockAllocator(n_pages=4, page_size=8)
        t0 = a.alloc(0, 8)
        assert a.extend(0, 8) == []                  # still 1 page
        new = a.extend(0, 9)                         # crosses the boundary
        assert len(new) == 1 and new[0] not in t0
        assert a.table(0) == t0 + new
        assert a.extend(0, 5) == []                  # tables never shrink

    def test_extend_exhaustion_unchanged(self):
        a = BlockAllocator(n_pages=2, page_size=8)
        a.alloc(0, 8)
        a.alloc(1, 8)
        before = a.table(0)
        assert a.extend(0, 9) is None
        assert a.table(0) == before

    def test_release_idempotent_and_recycles(self):
        a = BlockAllocator(n_pages=2, page_size=8)
        pages = a.alloc(0, 16)
        assert a.release(0) == 2
        assert a.release(0) == 0                     # idempotent
        assert sorted(a.alloc(1, 16)) == sorted(pages)

    def test_no_double_assignment(self):
        a = BlockAllocator(n_pages=8, page_size=4)
        seen = set()
        for rid in range(4):
            for p in a.alloc(rid, 8):
                assert p not in seen
                seen.add(p)

    def test_shared_alloc_refcounts(self):
        """A shared prefix page lives in BOTH tables, is counted once in
        live_pages, and is freed only when the LAST reference drops."""
        a = BlockAllocator(n_pages=4, page_size=8)
        t0 = a.alloc(0, 16)                          # 2 pages
        t1 = a.alloc(1, 17, shared=t0[:2])           # shares both + 1 new
        assert t1[:2] == t0[:2] and len(t1) == 3
        assert a.live_pages() == 3                   # unique pages
        assert a.free_pages() + a.live_pages() == 4
        assert a.refs(t0[0]) == 2 and a.shared_pages() == 2
        assert a.release(0) == 0                     # nothing freed: shared
        assert a.refs(t0[0]) == 1
        assert a.release(1) == 3                     # last refs drop
        assert a.free_pages() == 4 and a.live_pages() == 0

    def test_shared_alloc_all_or_nothing_keeps_refs(self):
        """A failed shared alloc must not leave refcount bumps behind."""
        a = BlockAllocator(n_pages=3, page_size=8)
        t0 = a.alloc(0, 16)
        a.alloc(1, 8)                                # pool now full
        before = a.refs(t0[0])
        assert a.alloc(2, 32, shared=t0) is None     # needs 2 free, has 0
        assert a.refs(t0[0]) == before
        assert not a.holds(2)

    def test_pin_unpin_survives_release(self):
        """A cache pin keeps a page alive past its writer's release
        (the prefix-cache lifetime rule)."""
        a = BlockAllocator(n_pages=2, page_size=8)
        t = a.alloc(0, 8)
        a.pin(t[0])
        assert a.release(0) == 0                     # pinned: not freed
        assert a.refs(t[0]) == 1 and a.free_pages() == 1
        assert a.unpin(t[0]) is True                 # now it frees
        assert a.free_pages() == 2

    def test_reclaimable_counts_only_sole_refs(self):
        a = BlockAllocator(n_pages=4, page_size=8)
        t0 = a.alloc(0, 16)
        a.alloc(1, 24, shared=t0[:2])                # 2 shared + 1 private
        assert a.reclaimable(0) == 0                 # both pages shared
        assert a.reclaimable(1) == 1                 # only its private page

    def test_byte_denominated_tier_accounting(self):
        """Device and host occupancy are priced in each tier's OWN
        bytes: a spilled page stops costing device bytes and starts
        costing (smaller, compressed) host-slot bytes."""
        a = BlockAllocator(n_pages=4, page_size=8, host_pages=2,
                           page_bytes=1024, host_slot_bytes=288)
        a.alloc(0, 17)                               # 3 pages
        assert a.device_bytes_in_use() == 3 * 1024
        assert a.host_bytes_in_use() == 0
        t = a.alloc(1, 8)
        a.pin(t[0])
        a.release(1)
        h = a.spill(t[0])
        assert h is not None
        assert a.device_bytes_in_use() == 3 * 1024   # page's HBM freed
        assert a.host_bytes_in_use() == 288          # compressed slot
        p = a.restore_begin(h)
        a.restore_commit(h)
        assert a.host_bytes_in_use() == 0
        assert a.device_bytes_in_use() == 4 * 1024
        a.unpin(p)


class TestTierByteDenomination:
    """Tentpole: pool sizing is byte-denominated per tier.  The token
    budgets (``kv_pool_tokens`` / ``host_pool_tokens``) are
    bf16-REFERENCE byte quantities, so a compressed tier fits more
    pages under the SAME budget — and the bf16 tier is bit-compatible
    with the old token-denominated sizing."""

    def test_bf16_pool_backcompat_exact(self):
        cfg = get_config("llama2-13b")
        assert device_pool_pages(cfg, 64 * 128, 128) == 64
        n, slot = host_tier_geometry(cfg, 64 * 128, 128, "")
        assert n == 64
        assert slot == 128 * cfg.cache_bytes_per_token()

    def test_int8_pool_nearly_doubles_pages(self):
        pages = device_pool_pages(get_config("llama2-13b"), 64 * 128, 128)
        pages8 = device_pool_pages(get_config("llama2-13b", variant="int8"),
                                   64 * 128, 128)
        assert pages8 >= int(1.8 * pages)

    def test_host_geometry_compression_ladder(self):
        cfg = get_config("llama2-13b")
        budget_tokens = 64 * 128
        slots = {}
        for dt in ("", "int8", "int4"):
            n, slot = host_tier_geometry(cfg, budget_tokens, 128, dt)
            assert slot == 128 * cfg.spill_bytes_per_token(dt)
            # never oversubscribes the byte budget
            assert n * slot <= budget_tokens * cfg.kv_bytes_per_token(2)
            slots[dt] = n
        assert slots["int8"] >= int(1.8 * slots[""])
        assert slots["int4"] >= 2 * slots[""]

    def test_no_budget_means_no_host_tier(self):
        cfg = get_config("llama2-13b")
        for dt in ("", "int8", "int4"):
            n, _ = host_tier_geometry(cfg, None, 128, dt)
            assert n == 0


class TestSharedPolicies:
    def test_admit_blocks_prefix(self):
        a = BlockAllocator(n_pages=3, page_size=8)
        reqs = [_req(0, 8), _req(1, 8), _req(2, 8), _req(3, 8)]
        n = admit_blocks(a, reqs, lambda r: r.prompt_len + 1)  # 2 pages each
        assert n == 1                                # second one doesn't fit
        assert a.holds(0) and not a.holds(1)

    def test_extend_preempts_youngest(self):
        a = BlockAllocator(n_pages=4, page_size=8)
        old = _req(0, plen=7, arrival=0.0)           # 1 page
        mid = _req(1, plen=7, arrival=1.0)
        yng = _req(2, plen=7, arrival=2.0)
        for r in (old, mid, yng):
            assert a.alloc(r.rid, r.prompt_len + 1) is not None
        # every request now needs a 2nd page; only 1 is free -> the
        # youngest loses its page so the older two can grow
        for r in (old, mid, yng):
            r.generated = 3                          # next write crosses
        victims = extend_for_decode(a, [old, mid, yng],
                                    lambda r: r.prompt_len + r.generated)
        assert victims == [yng]
        assert not a.holds(yng.rid)
        assert len(a.table(old.rid)) == 2
        assert len(a.table(mid.rid)) == 2

    def test_extend_no_preempt_when_pages_free(self):
        a = BlockAllocator(n_pages=8, page_size=8)
        r = _req(0, plen=7)
        a.alloc(0, 8)
        r.generated = 4
        assert extend_for_decode(a, [r], lambda q: q.prompt_len
                                 + q.generated) == []
        assert len(a.table(0)) == 2

    def test_starving_youngest_preempts_itself_not_an_elder(self):
        """Regression: when only the YOUNGEST request crosses a page
        boundary and no pages are free, it must evict itself — never an
        older request (which is closer to finishing)."""
        a = BlockAllocator(n_pages=2, page_size=8)
        old = _req(0, plen=7, arrival=0.0)           # 1 page, no growth
        yng = _req(1, plen=7, arrival=5.0)           # 1 page, will grow
        a.alloc(old.rid, 8)
        a.alloc(yng.rid, 8)
        yng.generated = 3                            # crosses the boundary
        old.generated = 0
        victims = extend_for_decode(
            a, [old, yng],
            lambda r: r.prompt_len + max(r.generated, 1))
        assert victims == [yng]
        assert a.holds(old.rid) and not a.holds(yng.rid)


# ----------------------------------------------------- property tests -----
if HAVE_HYPOTHESIS:
    ops = st.lists(
        st.one_of(
            st.tuples(st.just("alloc"), st.integers(0, 7),
                      st.integers(1, 200)),
            st.tuples(st.just("extend"), st.integers(0, 7),
                      st.integers(1, 200)),
            st.tuples(st.just("release"), st.integers(0, 7),
                      st.just(0)),
        ),
        min_size=1, max_size=60)

    class TestAllocatorProperties:
        @settings(deadline=None, max_examples=200)
        @given(ops=ops, n_pages=st.integers(1, 12),
               page=st.sampled_from([1, 8, 16, 128]))
        def test_random_interleavings_hold_invariants(self, ops, n_pages,
                                                      page):
            a = BlockAllocator(n_pages, page)
            tokens = {}
            for op, rid, tok in ops:
                if op == "alloc":
                    if a.holds(rid):
                        continue
                    if a.alloc(rid, tok) is not None:
                        tokens[rid] = tok
                elif op == "extend":
                    if not a.holds(rid):
                        continue
                    if a.extend(rid, tok) is not None:
                        tokens[rid] = max(tokens[rid], tok)
                else:
                    a.release(rid)
                    tokens.pop(rid, None)
                # never double-assign a page
                assigned = [p for r in tokens for p in a.table(r)]
                assert len(assigned) == len(set(assigned))
                # no leaks: free + live == total
                assert a.free_pages() + a.live_pages() == n_pages
                # tables cover exactly ceil(tokens / page) pages
                for r, tk in tokens.items():
                    assert len(a.table(r)) == -(-tk // page)

    shared_ops = st.lists(
        st.one_of(
            st.tuples(st.just("alloc"), st.integers(0, 7),
                      st.integers(1, 200)),
            # share the longest live prefix of a donor's table
            st.tuples(st.just("salloc"), st.integers(0, 7),
                      st.integers(1, 200), st.integers(0, 7)),
            st.tuples(st.just("extend"), st.integers(0, 7),
                      st.integers(1, 200)),
            st.tuples(st.just("release"), st.integers(0, 7)),
            st.tuples(st.just("rerelease"), st.integers(0, 7)),
            st.tuples(st.just("pin"), st.integers(0, 7)),
            st.tuples(st.just("unpin"), st.integers(0, 30)),
        ),
        min_size=1, max_size=80)

    class TestRefcountedAllocatorProperties:
        """Satellite (PR 3): the PR 2 invariants generalized to
        refcounted alloc/share/pin/release interleavings — no page is
        freed while referenced, free + unique-live == total, release is
        idempotent per rid.  A host-side refcount mirror is maintained
        independently and compared against the allocator every step."""

        @settings(deadline=None, max_examples=200)
        @given(ops=shared_ops, n_pages=st.integers(2, 14),
               page=st.sampled_from([1, 8, 128]))
        def test_refcounted_interleavings_hold_invariants(self, ops,
                                                          n_pages, page):
            a = BlockAllocator(n_pages, page)
            tables = {}                       # rid -> expected table
            pins = []                         # pages we pinned (with dups)
            for op in ops:
                kind, rid = op[0], op[1]
                if kind == "alloc" and not a.holds(rid):
                    t = a.alloc(rid, op[2])
                    if t is not None:
                        tables[rid] = t
                elif kind == "salloc" and not a.holds(rid):
                    donor = tables.get(op[3])
                    need = a.pages_for(op[2])
                    shared = (donor or [])[:need]
                    t = a.alloc(rid, op[2], shared=shared)
                    if t is not None:
                        assert t[:len(shared)] == list(shared)
                        tables[rid] = t
                elif kind == "extend" and a.holds(rid):
                    new = a.extend(rid, op[2])
                    if new is not None:
                        tables[rid].extend(new)
                elif kind == "release":
                    freed = a.release(rid)
                    t = tables.pop(rid, None)
                    assert (freed > 0) <= (t is not None)
                elif kind == "rerelease":
                    a.release(rid)
                    tables.pop(rid, None)
                    assert a.release(rid) == 0       # idempotent per rid
                elif kind == "pin" and a.holds(rid) and a.table(rid):
                    p = a.table(rid)[0]
                    a.pin(p)
                    pins.append(p)
                elif kind == "unpin" and pins:
                    a.unpin(pins.pop(op[1] % len(pins)))

                # refcount == (#tables holding the page) + (#pins)
                expect = {}
                for t in tables.values():
                    for p in t:
                        expect[p] = expect.get(p, 0) + 1
                for p in pins:
                    expect[p] = expect.get(p, 0) + 1
                for p in range(n_pages):
                    assert a.refs(p) == expect.get(p, 0)
                # no page freed while referenced; shared counted once
                assert a.free_pages() + a.live_pages() == n_pages
                assert a.live_pages() == len(expect)
                # tables still cover their spans exactly
                for rid2, t in tables.items():
                    assert a.table(rid2) == t


if HAVE_HYPOTHESIS:
    spill_ops = st.lists(
        st.one_of(
            st.tuples(st.just("alloc"), st.integers(0, 7),
                      st.integers(1, 200)),
            st.tuples(st.just("salloc"), st.integers(0, 7),
                      st.integers(1, 200), st.integers(0, 7)),
            st.tuples(st.just("extend"), st.integers(0, 7),
                      st.integers(1, 200)),
            st.tuples(st.just("release"), st.integers(0, 7)),
            st.tuples(st.just("pin"), st.integers(0, 7)),
            st.tuples(st.just("unpin"), st.integers(0, 30)),
            # host tier transitions (PR 5)
            st.tuples(st.just("spill"), st.integers(0, 30)),
            st.tuples(st.just("spill_shared"), st.integers(0, 7)),
            st.tuples(st.just("rbegin"), st.integers(0, 30)),
            st.tuples(st.just("rcommit"), st.integers(0, 30)),
            st.tuples(st.just("rdrop"), st.integers(0, 30)),
        ),
        min_size=1, max_size=100)

    class TestSpillRestoreProperties:
        """Satellite (PR 5): spill -> release -> restore -> pin
        orderings hold the extended invariants — a shared radix page's
        spill is refused while referenced, restore is idempotent, and
        free + unique-live + spilled == total across both tiers.  A
        host-side mirror (pins / spilled slots / restores in flight) is
        maintained independently and compared every step."""

        @settings(deadline=None, max_examples=200)
        @given(ops=spill_ops, n_pages=st.integers(2, 12),
               host_pages=st.integers(0, 6),
               page=st.sampled_from([1, 8, 128]))
        def test_spill_restore_interleavings_hold_invariants(
                self, ops, n_pages, host_pages, page):
            # deliberately asymmetric byte prices: device pages cost
            # 4x what a (compressed) host slot costs
            a = BlockAllocator(n_pages, page, host_pages=host_pages,
                               page_bytes=page * 4,
                               host_slot_bytes=page + 1)
            tables = {}                  # rid -> expected table
            pins = []                    # caller-held page pins (dups ok)
            spilled = []                 # caller-owned host slots at rest
            restoring = {}               # hslot -> reserved device page
            for op in ops:
                kind = op[0]
                if kind == "alloc" and not a.holds(op[1]):
                    t = a.alloc(op[1], op[2])
                    if t is not None:
                        tables[op[1]] = t
                elif kind == "salloc" and not a.holds(op[1]):
                    donor = tables.get(op[3])
                    shared = (donor or [])[:a.pages_for(op[2])]
                    t = a.alloc(op[1], op[2], shared=shared)
                    if t is not None:
                        tables[op[1]] = t
                elif kind == "extend" and a.holds(op[1]):
                    new = a.extend(op[1], op[2])
                    if new is not None:
                        tables[op[1]].extend(new)
                elif kind == "release":
                    a.release(op[1])
                    tables.pop(op[1], None)
                elif kind == "pin" and a.holds(op[1]) and a.table(op[1]):
                    p = a.table(op[1])[0]
                    a.pin(p)
                    pins.append(p)
                elif kind == "unpin" and pins:
                    a.unpin(pins.pop(op[1] % len(pins)))
                elif kind == "spill" and pins:
                    p = pins[op[1] % len(pins)]
                    h = a.spill(p)
                    in_table = any(p in t for t in tables.values())
                    if h is not None:
                        # only a sole-pin page with no table sharer spills
                        assert not in_table and pins.count(p) == 1
                        pins.remove(p)       # pin moved to the host slot
                        assert h not in spilled and h not in restoring
                        spilled.append(h)
                    else:
                        assert (in_table or pins.count(p) > 1
                                or not a.free_host_slots())
                elif kind == "spill_shared" and a.holds(op[1]):
                    # a page in a live table must NEVER spill
                    p = a.table(op[1])[0]
                    before = a.refs(p)
                    assert a.spill(p) is None
                    assert a.refs(p) == before
                elif kind == "rbegin" and spilled:
                    h = spilled[op[1] % len(spilled)]
                    pg = a.restore_begin(h)
                    if pg is not None:
                        assert a.restore_begin(h) == pg   # idempotent
                        spilled.remove(h)
                        restoring[h] = pg
                elif kind == "rcommit" and restoring:
                    h = list(restoring)[op[1] % len(restoring)]
                    pg = restoring.pop(h)
                    assert a.restore_commit(h) is True
                    assert a.restore_commit(h) is False   # idempotent
                    pins.append(pg)          # reserved page is ours now
                elif kind == "rdrop" and spilled:
                    h = spilled[op[1] % len(spilled)]
                    assert a.drop_spilled(h) is True
                    spilled.remove(h)

                # refcount == tables + pins + restore reservations
                expect = {}
                for t in tables.values():
                    for p in t:
                        expect[p] = expect.get(p, 0) + 1
                for p in pins:
                    expect[p] = expect.get(p, 0) + 1
                for p in restoring.values():
                    expect[p] = expect.get(p, 0) + 1
                for p in range(n_pages):
                    assert a.refs(p) == expect.get(p, 0)
                # two-tier accounting: no leaks on either side
                assert a.free_pages() + a.live_pages() == n_pages
                assert a.free_host_slots() + a.spilled_slots() \
                    == host_pages
                assert a.spilled_slots() == len(spilled) + len(restoring)
                # no host slot double-assigned
                assert len(set(spilled) | set(restoring)) \
                    == len(spilled) + len(restoring)
                # byte denomination follows the page/slot counts in
                # each tier's OWN prices (quantized spill accounting)
                assert a.device_bytes_in_use() \
                    == a.live_pages() * page * 4
                assert a.host_bytes_in_use() \
                    == (len(spilled) + len(restoring)) * (page + 1)
