"""Distributed correctness: sharded execution == single-device oracle.

jax locks the device count at first init, so these tests run their
bodies in a fresh subprocess with --xla_force_host_platform_device_count
(the dry-run pattern), keeping the main pytest process single-device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n: int = 8, timeout: int = 560):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_forward_matches_single_device():
    """Dense GQA forward under TP+DP sharding == unsharded result."""
    run_devices("""
        from repro.configs import get_smoke_config
        from repro.models import transformer as tfm
        from repro.sharding import partition
        from repro.launch.mesh import make_host_mesh

        cfg = get_smoke_config("yi-6b", n_kv_heads=2, n_heads=4)
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key)
        tok = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
        want = tfm.forward(cfg, params, tokens=tok)

        mesh = make_host_mesh(2, 4)
        specs = partition.param_specs(cfg, params, mesh)
        sparams = jax.device_put(params, partition.to_shardings(mesh, specs))
        stok = jax.device_put(tok, NamedSharding(mesh, P("data", None)))
        got = jax.jit(lambda p, t: tfm.forward(cfg, p, tokens=t))(sparams, stok)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-3)
        print("OK")
    """)


def test_moe_ep_train_step_grads_match():
    """EP shard_map MoE train step == local train step (params + loss)."""
    run_devices("""
        from repro.configs import get_smoke_config
        from repro.models import transformer as tfm
        from repro.train import optimizer, train_loop
        from repro.launch.mesh import make_host_mesh

        cfg = get_smoke_config("qwen3-moe-235b-a22b", n_experts=4, top_k=2,
                               capacity_factor=4.0)
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key)
        opt_cfg = optimizer.AdamWConfig(lr=1e-3, total_steps=4)
        batch = {"tokens": jax.random.randint(key, (4, 24), 0,
                                              cfg.vocab_size)}
        p_ref, _, m_ref = jax.jit(train_loop.make_train_step(
            cfg, opt_cfg, moe_impl="local"))(params, optimizer.init(params),
                                             batch)
        mesh = make_host_mesh(2, 4)
        p_ep, _, m_ep = jax.jit(train_loop.make_train_step(
            cfg, opt_cfg, moe_impl="ep", mesh=mesh))(
                params, optimizer.init(params), batch)
        np.testing.assert_allclose(float(m_ep["loss"]), float(m_ref["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_ep)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-4, rtol=5e-3)
        print("OK")
    """)


def test_decode_with_sharded_cache_matches():
    """Decode step with a model/data-sharded KV cache == unsharded."""
    run_devices("""
        from repro.configs import get_smoke_config
        from repro.models import transformer as tfm
        from repro.sharding import partition
        from repro.launch.mesh import make_host_mesh

        cfg = get_smoke_config("yi-6b", n_kv_heads=4, n_heads=4,
                               max_seq_len=64)
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key)
        tok = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
        logits, cache = tfm.prefill(cfg, params, tokens=tok, cache_len=64)
        nt = logits.argmax(-1).astype(jnp.int32)
        want, _ = tfm.decode_step(cfg, params, nt, cache)

        mesh = make_host_mesh(2, 4)
        pspec = partition.param_specs(cfg, params, mesh)
        cspec = partition.cache_specs(cfg, cache, mesh, 4)
        sp = jax.device_put(params, partition.to_shardings(mesh, pspec))
        sc = jax.device_put(cache, partition.to_shardings(mesh, cspec))
        st = jax.device_put(nt, NamedSharding(mesh, P("data")))
        got, _ = jax.jit(lambda p, t, c: tfm.decode_step(cfg, p, t, c))(
            sp, st, sc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-3)
        print("OK")
    """)


def test_production_mesh_shapes():
    run_devices("""
        from repro.launch.mesh import make_production_mesh, batch_axes
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        assert batch_axes(m2, 256) == ("pod", "data")
        assert batch_axes(m2, 16) is None or batch_axes(m2, 16) == "pod"
        assert batch_axes(m1, 1) is None
        print("OK")
    """, n=512)


def test_distributed_flash_decode_matches_oracle():
    """Segmented-softmax decode over a seq-sharded cache == local decode."""
    run_devices("""
        from repro.models import attention
        from repro.launch.mesh import make_host_mesh
        ks = jax.random.split(jax.random.PRNGKey(7), 4)
        B, S, H, Hkv, Dh = 2, 64, 8, 2, 32
        q = jax.random.normal(ks[0], (B, 1, H, Dh))
        kc = jax.random.normal(ks[1], (B, S, Hkv, Dh))
        vc = jax.random.normal(ks[2], (B, S, Hkv, Dh))
        pos = jnp.array([40, 17], jnp.int32)
        want = attention.decode_attention(q, kc, vc, pos)
        mesh = make_host_mesh(2, 4)
        got = jax.jit(lambda *a: attention.distributed_decode_attention(
            *a, mesh))(q, kc, vc, pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-4)
        # ring-cache variant, pos beyond S
        pos2 = jnp.array([130, 31], jnp.int32)
        want2 = attention.decode_attention(q, kc, vc, pos2, window=S)
        got2 = jax.jit(lambda *a: attention.distributed_decode_attention(
            *a, mesh, window=S))(q, kc, vc, pos2)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                                   atol=1e-5, rtol=1e-4)
        print("OK")
    """)
