"""Training substrate: optimizer math, loss descent, checkpoint io."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property-based invariants need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.data import tokens as data_tokens
from repro.models import transformer as tfm
from repro.train import checkpoint, optimizer, train_loop


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = optimizer.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                    min_lr_ratio=0.1)
        lrs = [float(optimizer.schedule(cfg, jnp.asarray(s)))
               for s in (0, 5, 10, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5, abs=0.01)
        assert lrs[2] == pytest.approx(1.0, abs=0.01)
        assert lrs[3] == pytest.approx(0.1, abs=0.01)

    def test_adamw_descends_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0])}
        cfg = optimizer.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                    weight_decay=0.0, min_lr_ratio=1.0)
        state = optimizer.init(params)
        for _ in range(200):
            grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp p^2
            params, state, _ = optimizer.apply(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    @settings(max_examples=20, deadline=None)
    @given(st.floats(0.5, 100.0))
    def test_grad_clip_bounds_update(self, scale):
        params = {"w": jnp.ones((4,))}
        cfg = optimizer.AdamWConfig(lr=1e-2, grad_clip=1.0, warmup_steps=0,
                                    weight_decay=0.0)
        state = optimizer.init(params)
        grads = {"w": jnp.full((4,), scale)}
        _, _, metrics = optimizer.apply(cfg, params, grads, state)
        assert float(metrics["grad_norm"]) == pytest.approx(2 * scale)
        # post-clip effective norm is min(gnorm, clip): m update bounded
        m = jax.tree.leaves(state["m"])  # state is pre-update copy
        assert all(jnp.isfinite(x).all() for x in m)


def test_loss_decreases_tiny_model():
    cfg = get_smoke_config("stablelm-1.6b", vocab_size=128, d_model=64,
                           n_heads=2, n_kv_heads=2, d_ff=128)
    it = data_tokens.batches(cfg, batch_size=4, seq_len=32)
    _, _, hist = train_loop.train(
        cfg, steps=30, batch_iter=it,
        opt_cfg=optimizer.AdamWConfig(lr=3e-3, warmup_steps=5,
                                      total_steps=30),
        log_every=29)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("yi-6b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = optimizer.init(params)
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, params, opt_state, meta={"step": 7})
    p2, o2 = checkpoint.restore(path, params, opt_state)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt_state), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_synthetic_lm_is_learnable_structure():
    """The bigram structure must be deterministic given the seed."""
    g1 = data_tokens.SyntheticLM(256, seed=3)
    g2 = data_tokens.SyntheticLM(256, seed=3)
    np.testing.assert_array_equal(g1.sample(2, 16), g2.sample(2, 16))
    assert g1.sample(2, 16).max() < 256
