"""Cross-request prefix cache, end to end (DESIGN.md §3 "Prefix
sharing").

The tentpole claims under test:

* the radix index maps a prompt to its longest cached FULL-page run,
  capped so at least one suffix token always prefills (first-token
  logits need a forward pass); the final partial page is never shared
  (the COW rule by construction);
* on the shared-prefix workload (page 128, same HBM budget) the
  prefix-cache run emits per-request token ids BIT-IDENTICAL to the
  cold run while prefilling >= 40% fewer prompt tokens (acceptance);
* eviction is refcount-aware: LRU zero-ref cached prefixes are
  reclaimed before any live request is preempted, and a preemption
  victim whose pages are all shared (release frees nothing) is never
  picked (the starvation case);
* the O(n^2) victim list scan in extend_for_decode is gone — a large-
  pool run picks the SAME victims as a quadratic reference (timing-free
  regression);
* engine and cost-model backends make identical admission decisions
  AND identical hit counts (backend parity extends to the cache);
* hit metrics flow: PrefixCache.stats -> ServeResult / GlobalMonitor.
"""
import numpy as np
import pytest

from repro.core.paging import (BlockAllocator, admit_blocks,
                               extend_for_decode)
from repro.core.prefix_cache import PrefixCache
from repro.core.request import Request, TaskType
from repro.data.workload import WorkloadSpec, generate

PAGE = 8


def _req(rid, plen=10, mnt=4, arrival=0.0):
    return Request(rid=rid, prompt_len=plen, max_new_tokens=mnt,
                   arrival=arrival)


def _toks(seed, n):
    return np.random.default_rng(seed).integers(0, 1000, n).astype(np.int32)


# ------------------------------------------------------------ radix unit --
class TestRadixIndex:
    def test_lookup_matches_longest_cached_run(self):
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        cache = PrefixCache(PAGE)
        toks = _toks(0, 3 * PAGE + 3)
        t = a.alloc(0, len(toks) + 1)
        cache.register(a, toks, t)               # 3 full pages indexed
        assert len(cache) == 3

        pages, hit = cache.lookup(toks)
        assert hit == 3 * PAGE and pages == t[:3]
        # diverging third page -> only the first two match
        other = toks.copy()
        other[2 * PAGE] += 1
        pages, hit = cache.lookup(other)
        assert hit == 2 * PAGE and pages == t[:2]
        # diverging FIRST token -> cold
        other = toks.copy()
        other[0] += 1
        assert cache.lookup(other) == ([], 0)

    def test_lookup_never_matches_entire_prompt(self):
        """At least one suffix token must prefill: a prompt of exactly
        k full pages matches at most k-1."""
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        cache = PrefixCache(PAGE)
        toks = _toks(1, 2 * PAGE)
        t = a.alloc(0, len(toks) + 1)
        cache.register(a, toks, t)
        pages, hit = cache.lookup(toks)
        assert hit == PAGE and pages == t[:1]    # capped at (2P-1)//P = 1

    def test_partial_final_page_never_indexed(self):
        """The COW rule by construction: a prompt's trailing partial
        page stays private — only full pages enter the radix."""
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        cache = PrefixCache(PAGE)
        toks = _toks(2, PAGE + 3)                # 1 full + partial
        t = a.alloc(0, len(toks) + 1)
        cache.register(a, toks, t)
        assert len(cache) == 1
        assert cache.pinned_pages() == t[:1]

    def test_register_first_wins_on_duplicates(self):
        """Two concurrent cold requests with the same prefix: the
        second's identical chunk keeps the FIRST's canonical page; the
        duplicate page stays private (refcount untouched)."""
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        cache = PrefixCache(PAGE)
        toks = _toks(3, PAGE + 1)
        t0 = a.alloc(0, len(toks) + 1)
        t1 = a.alloc(1, len(toks) + 1)
        cache.register(a, toks, t0)
        cache.register(a, toks, t1)
        assert len(cache) == 1
        assert cache.pinned_pages() == t0[:1]
        assert a.refs(t0[0]) == 2                # table + pin
        assert a.refs(t1[0]) == 1                # private duplicate

    def test_pinned_prefix_survives_writer_release(self):
        a = BlockAllocator(n_pages=4, page_size=PAGE)
        cache = PrefixCache(PAGE)
        toks = _toks(4, 2 * PAGE + 1)
        t = a.alloc(0, len(toks) + 1)
        cache.register(a, toks, t)
        a.release(0)
        assert a.free_pages() == 2               # 2 pinned, 3rd page freed
        pages, hit = cache.lookup(np.concatenate([toks, _toks(9, 4)]))
        assert hit == 2 * PAGE and pages == t[:2]

    def test_lru_eviction_leaf_first_skips_referenced(self):
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        cache = PrefixCache(PAGE)
        old = _toks(5, 2 * PAGE + 1)             # chain of 2 nodes
        t_old = a.alloc(0, len(old) + 1)
        cache.register(a, old, t_old)
        young = _toks(6, PAGE + 1)
        t_y = a.alloc(1, len(young) + 1)
        cache.register(a, young, t_y)
        a.release(0)                             # old chain zero-ref
        # rid 1 still references its page: only the old chain is
        # evictable, and leaf-first means depth-2 before depth-1
        assert cache.evict_one(a) is True
        assert cache.evict_one(a) is True
        assert cache.evict_one(a) is False       # young page refs==2
        assert len(cache) == 1
        assert cache.pinned_pages() == t_y[:1]
        assert cache.stats.evictions == 2

    def test_admit_blocks_shares_and_evicts_under_pressure(self):
        """admit_blocks with a cache: a hit request allocs only its
        suffix pages; when the free list starves, zero-ref cached
        prefixes are evicted before admission fails."""
        a = BlockAllocator(n_pages=6, page_size=PAGE)
        cache = PrefixCache(PAGE)
        toks = _toks(7, 4 * PAGE)
        r0 = _req(0, plen=len(toks));  r0.tokens = toks
        assert admit_blocks(a, [r0], lambda r: r.prompt_len + 1,
                            cache=cache, tokens_of=lambda r: r.tokens) == 1
        cache.register(a, toks, a.table(0))      # 4 pages indexed
        a.release(0)
        # same prompt again: shares 3 pages (cap), allocs 2 private
        r1 = _req(1, plen=len(toks));  r1.tokens = toks
        assert admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                            cache=cache, tokens_of=lambda r: r.tokens) == 1
        assert r1.prefix_hit_tokens == 3 * PAGE
        assert a.table(1)[:3] == cache.pinned_pages()[:3]
        assert a.shared_pages() == 3
        # a cold 1-page request now starves (0 free): LRU eviction of
        # the zero-ref 4th cached page (the only one no table holds)
        # makes room
        r2 = _req(2, plen=len(toks));  r2.tokens = _toks(8, 4 * PAGE)
        assert admit_blocks(a, [r2], lambda r: PAGE,
                            cache=cache, tokens_of=lambda r: r.tokens) == 1
        assert cache.stats.evictions >= 1
        assert r2.prefix_hit_tokens == 0

    def test_stats_and_monitor_accounting(self):
        from repro.core.monitor import GlobalMonitor
        a = BlockAllocator(n_pages=8, page_size=PAGE)
        cache = PrefixCache(PAGE)
        toks = _toks(10, 2 * PAGE + 1)
        r0 = _req(0, plen=len(toks));  r0.tokens = toks
        r1 = _req(1, plen=len(toks));  r1.tokens = toks
        admit_blocks(a, [r0], lambda r: r.prompt_len + 1,
                     cache=cache, tokens_of=lambda r: r.tokens)
        cache.register(a, toks, a.table(0))
        admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                     cache=cache, tokens_of=lambda r: r.tokens)
        assert cache.stats.lookups == 2 and cache.stats.hits == 1
        assert cache.stats.hit_tokens == 2 * PAGE
        assert cache.pages_saved() == 2
        assert cache.stats.peak_shared == 2
        mon = GlobalMonitor()
        for r in (r0, r1):
            mon.on_prefix_lookup(r.prefix_hit_tokens, PAGE)
        assert mon.prefix_lookups == 2 and mon.prefix_hits == 1
        assert mon.prefix_hit_rate() == 0.5
        assert mon.prefix_pages_saved == 2
        snap = mon.snapshot(0.0)
        assert snap.prefix_hit_rate == 0.5


# ------------------------------------------- refcount-aware preemption ----
class TestRefcountAwareEviction:
    def test_victim_with_zero_reclaimable_never_picked(self):
        """Starvation case (satellite): the YOUNGEST candidate's pages
        are all shared — releasing it frees nothing.  The old policy
        (pure youngest-first) would evict it and starve forever; the
        refcount-aware policy picks the younger request that actually
        frees pages."""
        a = BlockAllocator(n_pages=5, page_size=PAGE)
        cache = PrefixCache(PAGE)
        toks = _toks(0, PAGE)
        donor = _req(0, plen=PAGE - 1, arrival=0.0)
        a.alloc(0, PAGE)
        cache.register(a, toks, a.table(0))      # page pinned
        mid = _req(1, plen=2 * PAGE - 1, arrival=1.0)
        a.alloc(1, 2 * PAGE)                     # 2 private pages
        yng = _req(2, plen=PAGE - 1, arrival=2.0)
        a.alloc(2, PAGE, shared=a.table(0))      # ALL pages shared
        assert a.free_pages() == 2

        # the oldest needs 3 more pages: cache eviction is impossible
        # (the cached page is still referenced by rid 0 and rid 2), so
        # preemption must pick MID (reclaimable 2) over YNG (0)
        donor.generated = 3 * PAGE
        victims = extend_for_decode(
            a, [donor, mid, yng],
            lambda r: r.prompt_len + 1 + r.generated, cache=cache)
        assert victims == [mid]
        assert a.holds(yng.rid) and not a.holds(mid.rid)
        assert len(a.table(donor.rid)) == 4

    def test_cache_evicted_before_any_preemption(self):
        """Zero-ref cached pages are the cheapest reclaim: with enough
        of them, NO live request is preempted."""
        a = BlockAllocator(n_pages=5, page_size=PAGE)
        cache = PrefixCache(PAGE)
        toks = _toks(1, 2 * PAGE)
        a.alloc(0, 2 * PAGE)
        cache.register(a, toks, a.table(0))
        a.release(0)                             # both pages zero-ref
        old = _req(1, plen=2 * PAGE - 1, arrival=0.0)
        yng = _req(2, plen=PAGE - 1, arrival=1.0)
        a.alloc(1, 2 * PAGE)
        a.alloc(2, PAGE)                         # free list empty now
        old.generated = PAGE
        yng.generated = PAGE
        victims = extend_for_decode(
            a, [old, yng], lambda r: r.prompt_len + 1 + r.generated,
            cache=cache)
        assert victims == []                     # nobody preempted
        assert cache.stats.evictions == 2
        assert len(cache) == 0

    def test_self_preempt_when_nothing_reclaimable(self):
        """Degenerate endgame: no cache, no younger victim frees
        anything — the starving request preempts itself (termination)."""
        a = BlockAllocator(n_pages=1, page_size=PAGE)
        cache = PrefixCache(PAGE)
        r0 = _req(0, plen=PAGE - 1, arrival=0.0)
        t0 = a.alloc(0, PAGE)
        cache.register(a, _toks(2, PAGE), t0)    # r0's page pinned
        yng = _req(1, plen=PAGE - 1, arrival=1.0)
        a.alloc(1, PAGE, shared=t0)              # fully shared
        r0.generated = PAGE
        victims = extend_for_decode(
            a, [r0, yng], lambda r: r.prompt_len + 1 + r.generated,
            cache=cache)
        assert victims and victims[0] is r0


# ------------------------------------------------- O(n^2) victim scan -----
def _reference_extend_for_decode(alloc, pool, decode_tokens, cache=None):
    """The pre-PR-3 quadratic formulation (victims tracked in a LIST,
    membership via linear scans) with the refcount-aware policy —
    semantics the set-keyed implementation must reproduce exactly."""
    victims = []
    order = sorted(pool, key=lambda r: (r.arrival, r.rid))
    for r in order:
        if r in victims:                         # O(n) scan (the bug)
            continue
        while alloc.extend(r.rid, decode_tokens(r)) is None:
            if cache is not None and cache.evict_one(alloc):
                continue
            younger = [c for c in order if c not in victims and c is not r
                       and alloc.holds(c.rid)
                       and (c.arrival, c.rid) > (r.arrival, r.rid)
                       and alloc.reclaimable(c.rid) > 0]
            if not younger:
                alloc.release(r.rid)
                victims.append(r)
                break
            v = max(younger, key=lambda c: (alloc.reclaimable(c.rid),
                                            c.arrival, c.rid))
            alloc.release(v.rid)
            victims.append(v)
    return victims


class TestVictimSetRegression:
    def test_large_pool_victims_unchanged(self):
        """Timing-free regression for the set-keyed victim tracking: on
        a 300-request pool under heavy page pressure, the victim
        SEQUENCE matches the quadratic reference exactly."""
        rng = np.random.default_rng(0)

        def build():
            a = BlockAllocator(n_pages=700, page_size=PAGE)
            pool = []
            rng2 = np.random.default_rng(42)
            for rid in range(300):
                plen = int(rng2.integers(1, 3 * PAGE))
                r = _req(rid, plen=plen,
                         arrival=float(rng2.integers(0, 50)))
                if a.alloc(rid, plen + 1) is None:
                    break
                r.generated = int(rng2.integers(1, 2 * PAGE))
                pool.append(r)
            return a, pool

        a1, pool1 = build()
        a2, pool2 = build()
        need = lambda r: r.prompt_len + 1 + r.generated
        got = extend_for_decode(a1, pool1, need)
        ref = _reference_extend_for_decode(a2, pool2, need)
        assert [v.rid for v in got] == [v.rid for v in ref]
        assert len(got) > 10                     # pressure actually bit
        # allocator end states agree too
        assert a1.free_pages() == a2.free_pages()
        for r in pool1:
            assert a1.table(r.rid) == a2.table(r.rid)


# ------------------------------------------------ workload scenarios ------
class TestSharedPrefixWorkload:
    def test_prefix_scenarios_share_token_prefixes(self):
        spec = WorkloadSpec(dataset="alpaca", rps=4.0, n_requests=40,
                            max_model_len=2048, prefix_groups=3,
                            prefix_tokens=256, seed=5, vocab_size=1000)
        reqs = generate(spec)
        heads = {}
        for r in reqs:
            assert r.tokens is not None
            assert len(r.tokens) == r.prompt_len
            assert r.prompt_len > 256             # prefix + >=1 suffix
            heads.setdefault(bytes(r.tokens[:256].tobytes()),
                             []).append(r.rid)
        assert 1 < len(heads) <= 3                # N distinct prefixes
        assert max(len(v) for v in heads.values()) >= 2   # Zipf reuse
        # deterministic
        again = generate(spec)
        for a, b in zip(reqs, again):
            assert np.array_equal(a.tokens, b.tokens)

    def test_classic_spec_unchanged(self):
        spec = WorkloadSpec(dataset="alpaca", n_requests=8, seed=1)
        assert all(r.tokens is None for r in generate(spec))


# --------------------------------------------------- engine end to end ----
import jax                                                    # noqa: E402

from repro.configs import get_smoke_config                    # noqa: E402
from repro.core import (BucketServeScheduler, MemoryBudget,   # noqa: E402
                        SchedulerConfig)
from repro.core.engine import ServingEngine                   # noqa: E402
from repro.core.simulator import (A100X4, CostModel,          # noqa: E402
                                  Simulator)
from repro.models import transformer as tfm                   # noqa: E402

BUDGET = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                      weight_bytes=0)


def _prefix_workload(cfg, n, pre, groups=2, seed=3, max_new=4):
    spec = WorkloadSpec(dataset="alpaca", rps=1e6, n_requests=n, seed=seed,
                        max_model_len=cfg.max_seq_len,
                        task_type=TaskType.OFFLINE, prefix_groups=groups,
                        prefix_tokens=pre, vocab_size=cfg.vocab_size)
    reqs = generate(spec)
    for r in reqs:
        r.max_new_tokens = min(r.max_new_tokens, max_new)
    return reqs


def _engine(cfg, params, *, slots, prefix_cache, page_size=128,
            pool_tokens=None, chunk_tokens=None):
    sched = BucketServeScheduler(cfg, BUDGET, SchedulerConfig(
        max_batch=slots, memory_model="paged", page_size=page_size))
    return ServingEngine(cfg, params, sched, max_slots=slots,
                         cache_len=cfg.max_seq_len, paged=True,
                         page_size=page_size, kv_pool_tokens=pool_tokens,
                         chunk_tokens=chunk_tokens,
                         prefix_cache=prefix_cache)


class TestPrefixCacheEngine:
    """Acceptance (ISSUE 3): on the shared-prefix workload, page 128,
    same HBM budget, the prefix-cache run produces per-request token ids
    BIT-IDENTICAL to the cold run while prefilling >= 40% fewer total
    prompt tokens."""

    def test_shared_prefix_tokens_identical_and_40pct_fewer_prefill(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs, res = {}, {}
        for cached in (False, True):
            reqs = _prefix_workload(cfg, 16, 512)
            eng = _engine(cfg, params, slots=4, prefix_cache=cached,
                          pool_tokens=8 * 1024)
            eng.submit(reqs)
            done = eng.run(max_wall_s=600)
            assert len(done) == len(reqs)
            outs[cached] = {r.rid: eng.outputs[r.rid] for r in reqs}
            res[cached] = eng.result
            for r in reqs:
                assert len(eng.outputs[r.rid]) == r.max_new_tokens
            # allocator invariant: free + unique-live == total; at run
            # end only the cache's pins remain live
            be = eng.backend
            assert be.alloc.free_pages() + be.alloc.live_pages() \
                == be.alloc.n_pages
            if cached:
                assert be.alloc.live_pages() == len(be.prefix_cache)
                assert be.prefix_cache.clear(be.alloc) > 0
                assert be.alloc.free_pages() == be.alloc.n_pages
            else:
                assert be.alloc.live_pages() == 0

        assert outs[True] == outs[False]          # bit-identical token ids
        cold = res[False].prefill_tokens_processed
        cached_toks = res[True].prefill_tokens_processed
        assert cached_toks <= 0.6 * cold, (cached_toks, cold)
        # skipped + processed adds back up to the cold run's work
        assert cached_toks + res[True].prefill_tokens_skipped == cold
        assert res[False].prefix_lookups == 0     # cold run has no cache
        assert res[True].prefix_hits > 0
        assert res[True].prefix_hit_rate() > 0.5
        assert res[True].prefix_pages_saved * 128 \
            == res[True].prefix_hit_tokens
        assert res[True].shared_pages_peak > 0

    def test_monitor_sees_hits(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=256)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        reqs = _prefix_workload(cfg, 8, 128, max_new=2)
        eng = _engine(cfg, params, slots=4, prefix_cache=True)
        eng.submit(reqs)
        assert len(eng.run(max_wall_s=300)) == 8
        mon = eng.sched.monitor
        assert mon.prefix_lookups == 8
        assert mon.prefix_hits == eng.result.prefix_hits
        assert mon.prefix_hit_tokens == eng.result.prefix_hit_tokens

    def test_composes_with_chunked_prefill(self):
        """Chunk plans that START past a cached prefix must slice spans
        at absolute offsets: tokens identical to the cold chunked run."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=256)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs = {}
        for cached in (False, True):
            reqs = _prefix_workload(cfg, 8, 128, seed=9, max_new=3)
            eng = _engine(cfg, params, slots=4, prefix_cache=cached,
                          page_size=64, chunk_tokens=96)
            eng.submit(reqs)
            assert len(eng.run(max_wall_s=300)) == 8
            outs[cached] = {r.rid: eng.outputs[r.rid] for r in reqs}
        assert outs[True] == outs[False]

    def test_preemption_with_cache_still_correct(self):
        """A pool tight enough to force mid-decode preemption AND cache
        eviction: every request completes with outputs identical to an
        unconstrained cached run (restarts re-match the prefix)."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=256)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs = {}
        for pool in (None, 8 * 64):              # ample vs 8-page squeeze
            reqs = _prefix_workload(cfg, 7, 128, seed=11, max_new=24)
            eng = _engine(cfg, params, slots=4, prefix_cache=True,
                          page_size=64, pool_tokens=pool)
            eng.submit(reqs)
            done = eng.run(max_wall_s=600)
            assert len(done) == len(reqs)
            outs[pool] = {r.rid: eng.outputs[r.rid] for r in reqs}
            for r in reqs:
                assert len(eng.outputs[r.rid]) == r.max_new_tokens
        assert outs[None] == outs[8 * 64]

    def test_uncacheable_arch_rejected(self):
        cfg = get_smoke_config("rwkv6-3b")
        assert not cfg.prefix_cacheable
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(AssertionError):
            _engine(cfg, params, slots=4, prefix_cache=True)
        cfg2 = get_smoke_config("qwen3-14b", max_seq_len=256,
                                sliding_window=64)
        assert not cfg2.prefix_cacheable          # ring cache: no resume

    def test_fused_modes_rejected(self):
        """coupled/static bypass backend.chunk_plan — a prefix cache
        there would count hits without ever skipping prefill."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=256)
        with pytest.raises(AssertionError, match="disagg"):
            Simulator(BucketServeScheduler(cfg, BUDGET, SchedulerConfig()),
                      CostModel(cfg, A100X4), mode="coupled", paged=True,
                      prefix_cache=True)


class _RecordingScheduler(BucketServeScheduler):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.formed = []

    def next_prefill_batch(self, now):
        batch = super().next_prefill_batch(now)
        if batch is not None:
            self.formed.append(tuple(r.rid for r in batch.requests))
        return batch


class TestPrefixBackendParity:
    """CostModelBackend mirrors the engine's prefix-cache accounting:
    identical batches AND identical hit counts on the same workload."""

    N, SLOTS, PAGE_ = 12, 4, 128

    def _sched(self, cfg):
        return _RecordingScheduler(cfg, BUDGET, SchedulerConfig(
            max_batch=self.SLOTS, memory_model="paged",
            page_size=self.PAGE_))

    def _workload(self, cfg):
        reqs = _prefix_workload(cfg, self.N, 128, max_new=3)
        for r in reqs:      # all queued up-front: identical first ticks
            r.arrival = 0.0 # on the wall and the virtual clock
        return reqs

    def test_same_batches_and_hit_counts(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=256)
        # ample pool: parity is asserted in the no-starvation regime —
        # under page pressure the two substrates requeue at different
        # (wall vs virtual) times by design, as in PR 2's parity test
        pool_tokens = 64 * self.PAGE_

        sched_sim = self._sched(cfg)
        sim = Simulator(sched_sim, CostModel(cfg, A100X4), mode="disagg",
                        decode_slot_cap=self.SLOTS, paged=True,
                        page_size=self.PAGE_, kv_pool_tokens=pool_tokens,
                        cache_len=cfg.max_seq_len, prefix_cache=True)
        res_sim = sim.run(self._workload(cfg))
        assert len(res_sim.finished()) == self.N

        sched_eng = self._sched(cfg)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, sched_eng, max_slots=self.SLOTS,
                            cache_len=cfg.max_seq_len, paged=True,
                            page_size=self.PAGE_,
                            kv_pool_tokens=pool_tokens, prefix_cache=True)
        eng.submit(self._workload(cfg))
        assert len(eng.run(max_wall_s=300)) == self.N
        res_eng = eng.result

        assert sched_sim.formed == sched_eng.formed
        assert res_sim.prefix_lookups == res_eng.prefix_lookups > 0
        assert res_sim.prefix_hits == res_eng.prefix_hits > 0
        assert res_sim.prefix_hit_tokens == res_eng.prefix_hit_tokens
        assert res_sim.prefill_tokens_skipped \
            == res_eng.prefill_tokens_skipped > 0
        assert sim.backend.alloc.n_pages == eng.backend.alloc.n_pages
