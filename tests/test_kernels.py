"""Per-kernel correctness: interpret-mode Pallas vs. pure-jnp oracle,
swept over shapes and dtypes (assert_allclose per instructions)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attn import flash_decode
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_decode_attn import paged_flash_decode
from repro.kernels.wkv6 import wkv6


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-4)


PREFILL_SHAPES = [
    # (B, T, H, Hkv, Dh, window, causal)
    (1, 128, 4, 4, 64, 0, True),
    (2, 200, 8, 2, 64, 0, True),      # GQA + ragged T (padding)
    (2, 384, 4, 1, 128, 0, True),     # MQA
    (1, 300, 4, 2, 64, 128, True),    # sliding window
    (2, 256, 4, 4, 80, 0, False),     # encoder (hubert head_dim 80)
    (1, 64, 2, 2, 256, 0, True),      # large head dim (recurrentgemma)
]


@pytest.mark.parametrize("shape", PREFILL_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill(shape, dtype):
    B, T, H, Hkv, Dh, window, causal = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, T, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, Dh), dtype)
    lengths = jnp.array([T, max(T // 2, 1)][:B], jnp.int32)
    out = flash_prefill(q, k, v, lengths, causal=causal, window=window,
                        blk_q=128, blk_k=128, interpret=True)
    want = ref.flash_prefill_ref(q, k, v, lengths, causal=causal,
                                 window=window)
    valid = np.arange(T)[None, :, None, None] < np.asarray(lengths)[:, None, None, None]
    if causal:
        # row 0 attends to key 0 only; rows beyond length are unmasked
        # garbage in both impls — compare only valid query rows.
        pass
    np.testing.assert_allclose(
        np.where(valid, np.asarray(out, np.float32), 0),
        np.where(valid, np.asarray(want, np.float32), 0), **_tol(dtype))


DECODE_SHAPES = [
    # (B, S, H, Hkv, Dh, ring)
    (2, 256, 8, 2, 64, False),
    (1, 600, 4, 1, 128, False),       # ragged S (padding) + MQA
    (2, 256, 8, 8, 64, False),        # MHA
    (2, 128, 4, 2, 64, True),         # ring cache, wrapped
    (1, 512, 16, 2, 80, False),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(shape, dtype):
    B, S, H, Hkv, Dh, ring = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    pos = jnp.array([S // 3, 2 * S + 5][:B], jnp.int32) if ring else \
        jnp.array([S - 1, S // 2][:B], jnp.int32)
    out = flash_decode(q, kc, vc, pos, ring=ring, blk_s=128, interpret=True)
    want = ref.flash_decode_ref(q, kc, vc, pos, ring=ring)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


PAGED_SHAPES = [
    # (B, S, H, Hkv, Dh, page, ring)
    (2, 256, 8, 2, 64, 128, False),   # GQA G=4, divisible
    (2, 256, 8, 8, 64, 128, False),   # MHA (G=1)
    (1, 600, 4, 1, 128, 128, False),  # MQA + non-divisible S/page
    (1, 300, 4, 2, 64, 128, False),   # non-divisible, dead tail page
    (2, 128, 4, 2, 64, 64, True),     # ring cache, wrapped
    (2, 96, 8, 2, 64, 64, True),      # ring, non-divisible window/page
    (1, 512, 16, 2, 80, 256, False),  # large G, odd head dim
]


@pytest.mark.parametrize("shape", PAGED_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode(shape, dtype):
    """Paged kernel vs. (a) its jnp oracle, (b) the CONTIGUOUS flash
    decode over the same cache contents: page placement is shuffled, so
    passing proves allocation layout cannot change results."""
    B, S, H, Hkv, Dh, page, ring = shape
    rng = np.random.default_rng(4)
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    pos = jnp.array([2 * S + 5, S // 3][:B], jnp.int32) if ring else \
        jnp.array([S - 1, S // 2][:B], jnp.int32)

    # scatter the contiguous cache into a RANDOMLY PERMUTED page pool
    n_p = -(-S // page)
    Sp = n_p * page
    n_pages = B * n_p + 3                       # a few never-used pages
    perm = rng.permutation(n_pages)[:B * n_p].reshape(B, n_p)
    kp = jnp.pad(kc, ((0, 0), (0, Sp - S), (0, 0), (0, 0))).reshape(
        B, n_p, page, Hkv, Dh)
    vp = jnp.pad(vc, ((0, 0), (0, Sp - S), (0, 0), (0, 0))).reshape(
        B, n_p, page, Hkv, Dh)
    k_pool = jnp.zeros((n_pages, page, Hkv, Dh), dtype).at[
        perm.reshape(-1)].set(kp.reshape(-1, page, Hkv, Dh))
    v_pool = jnp.zeros((n_pages, page, Hkv, Dh), dtype).at[
        perm.reshape(-1)].set(vp.reshape(-1, page, Hkv, Dh))
    bt = jnp.asarray(perm, jnp.int32)

    out = paged_flash_decode(q, k_pool, v_pool, bt, pos, s_len=S,
                             ring=ring, interpret=True)
    oracle = ref.paged_flash_decode_ref(q, k_pool, v_pool, bt, pos,
                                        s_len=S, ring=ring)
    contig = flash_decode(q, kc, vc, pos, ring=ring, blk_s=page,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(contig, np.float32), **_tol(dtype))


def test_paged_gather_is_exact():
    """gather_paged_kv reconstructs the contiguous cache bit-for-bit —
    the invariant behind token-id parity between the paged and
    contiguous engines."""
    from repro.models.attention import gather_paged_kv
    rng = np.random.default_rng(5)
    B, S, Hkv, Dh, page = 3, 200, 2, 64, 64
    n_p = -(-S // page)
    kc = rng.normal(size=(B, S, Hkv, Dh)).astype(np.float32)
    perm = rng.permutation(B * n_p + 2)[:B * n_p].reshape(B, n_p)
    kp = np.zeros((B, n_p * page, Hkv, Dh), np.float32)
    kp[:, :S] = kc
    pool = np.zeros((B * n_p + 2, page, Hkv, Dh), np.float32)
    pool[perm.reshape(-1)] = kp.reshape(B * n_p, page, Hkv, Dh)
    got = gather_paged_kv(jnp.asarray(pool), jnp.asarray(perm, jnp.int32), S)
    assert np.array_equal(np.asarray(got), kc)


WKV_SHAPES = [
    (1, 64, 2, 64),
    (2, 100, 4, 64),                  # ragged T (padding)
    (1, 128, 1, 32),
    (2, 48, 8, 16),
]


@pytest.mark.parametrize("shape", WKV_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6(shape, dtype):
    B, T, H, hs = shape
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = jax.random.normal(ks[0], (B, T, H, hs), dtype)
    k = jax.random.normal(ks[1], (B, T, H, hs), dtype)
    v = jax.random.normal(ks[2], (B, T, H, hs), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hs))).astype(dtype)
    u = jax.random.normal(ks[4], (H, hs), jnp.float32) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, hs, hs), jnp.float32) * 0.1
    y, sT = wkv6(r, k, v, w, u, s0, blk_t=32, interpret=True)
    y_ref, sT_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref), **tol)


def test_ops_dispatcher_equivalence():
    """ops.prefill_attention gives identical results on both paths."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 160, 4, 64))
    k = jax.random.normal(ks[1], (2, 160, 2, 64))
    v = jax.random.normal(ks[2], (2, 160, 2, 64))
    lens = jnp.array([160, 90], jnp.int32)
    ops.configure(use_pallas=False)
    a = ops.prefill_attention(q, k, v, lens)
    ops.configure(use_pallas=True, interpret=True)
    b = ops.prefill_attention(q, k, v, lens)
    ops.configure(use_pallas=False)
    valid = np.arange(160)[None, :, None, None] < np.asarray(lens)[:, None, None, None]
    np.testing.assert_allclose(np.where(valid, np.asarray(a), 0),
                               np.where(valid, np.asarray(b), 0),
                               atol=2e-5, rtol=2e-4)
