"""Per-kernel correctness: interpret-mode Pallas vs. pure-jnp oracle,
swept over shapes and dtypes (assert_allclose per instructions)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attn import flash_decode
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.wkv6 import wkv6


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-4)


PREFILL_SHAPES = [
    # (B, T, H, Hkv, Dh, window, causal)
    (1, 128, 4, 4, 64, 0, True),
    (2, 200, 8, 2, 64, 0, True),      # GQA + ragged T (padding)
    (2, 384, 4, 1, 128, 0, True),     # MQA
    (1, 300, 4, 2, 64, 128, True),    # sliding window
    (2, 256, 4, 4, 80, 0, False),     # encoder (hubert head_dim 80)
    (1, 64, 2, 2, 256, 0, True),      # large head dim (recurrentgemma)
]


@pytest.mark.parametrize("shape", PREFILL_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill(shape, dtype):
    B, T, H, Hkv, Dh, window, causal = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, T, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, Dh), dtype)
    lengths = jnp.array([T, max(T // 2, 1)][:B], jnp.int32)
    out = flash_prefill(q, k, v, lengths, causal=causal, window=window,
                        blk_q=128, blk_k=128, interpret=True)
    want = ref.flash_prefill_ref(q, k, v, lengths, causal=causal,
                                 window=window)
    valid = np.arange(T)[None, :, None, None] < np.asarray(lengths)[:, None, None, None]
    if causal:
        # row 0 attends to key 0 only; rows beyond length are unmasked
        # garbage in both impls — compare only valid query rows.
        pass
    np.testing.assert_allclose(
        np.where(valid, np.asarray(out, np.float32), 0),
        np.where(valid, np.asarray(want, np.float32), 0), **_tol(dtype))


DECODE_SHAPES = [
    # (B, S, H, Hkv, Dh, ring)
    (2, 256, 8, 2, 64, False),
    (1, 600, 4, 1, 128, False),       # ragged S (padding) + MQA
    (2, 256, 8, 8, 64, False),        # MHA
    (2, 128, 4, 2, 64, True),         # ring cache, wrapped
    (1, 512, 16, 2, 80, False),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(shape, dtype):
    B, S, H, Hkv, Dh, ring = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    pos = jnp.array([S // 3, 2 * S + 5][:B], jnp.int32) if ring else \
        jnp.array([S - 1, S // 2][:B], jnp.int32)
    out = flash_decode(q, kc, vc, pos, ring=ring, blk_s=128, interpret=True)
    want = ref.flash_decode_ref(q, kc, vc, pos, ring=ring)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


WKV_SHAPES = [
    (1, 64, 2, 64),
    (2, 100, 4, 64),                  # ragged T (padding)
    (1, 128, 1, 32),
    (2, 48, 8, 16),
]


@pytest.mark.parametrize("shape", WKV_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6(shape, dtype):
    B, T, H, hs = shape
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = jax.random.normal(ks[0], (B, T, H, hs), dtype)
    k = jax.random.normal(ks[1], (B, T, H, hs), dtype)
    v = jax.random.normal(ks[2], (B, T, H, hs), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hs))).astype(dtype)
    u = jax.random.normal(ks[4], (H, hs), jnp.float32) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, hs, hs), jnp.float32) * 0.1
    y, sT = wkv6(r, k, v, w, u, s0, blk_t=32, interpret=True)
    y_ref, sT_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref), **tol)


def test_ops_dispatcher_equivalence():
    """ops.prefill_attention gives identical results on both paths."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 160, 4, 64))
    k = jax.random.normal(ks[1], (2, 160, 2, 64))
    v = jax.random.normal(ks[2], (2, 160, 2, 64))
    lens = jnp.array([160, 90], jnp.int32)
    ops.configure(use_pallas=False)
    a = ops.prefill_attention(q, k, v, lens)
    ops.configure(use_pallas=True, interpret=True)
    b = ops.prefill_attention(q, k, v, lens)
    ops.configure(use_pallas=False)
    valid = np.arange(160)[None, :, None, None] < np.asarray(lens)[:, None, None, None]
    np.testing.assert_allclose(np.where(valid, np.asarray(a), 0),
                               np.where(valid, np.asarray(b), 0),
                               atol=2e-5, rtol=2e-4)
