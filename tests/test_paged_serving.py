"""Paged KV decode pool, end to end (DESIGN.md §3).

The tentpole claims under test:

* allocation layout is INVISIBLE to results — the paged engine emits
  per-request token ids bit-identical to the contiguous slot pool on the
  same workload;
* under the SAME HBM budget, page-granular admission sustains >= 2x the
  concurrent decode requests of the contiguous pool on the mixed
  (heterogeneous-length) workload;
* block exhaustion mid-decode preempts the youngest request through the
  requeue path and every request still completes, with correct outputs;
* the cost-model backend mirrors the engine's block accounting (backend
  parity holds in paged mode);
* OOM-backoff recovery advances only on successful dispatch (the
  ``_cap_scale`` mutate-on-read regression).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (BucketServeScheduler, MemoryBudget, SchedulerConfig,
                        TaskType)
from repro.core.engine import ServingEngine
from repro.core.request import Request
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.workload import WorkloadSpec, generate
from repro.models import transformer as tfm

BUDGET = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                      weight_bytes=0)


def _mixed_requests(n, max_seq, max_new=6, seed=0):
    """The paper's heterogeneous case, clamped for CPU smoke runs the
    same way launch/serve.py does."""
    spec = WorkloadSpec(dataset="mixed", rps=1e6, n_requests=n, seed=seed,
                        max_model_len=max_seq, task_type=TaskType.OFFLINE)
    reqs = generate(spec)
    for r in reqs:
        r.max_new_tokens = min(r.max_new_tokens, max_new)
        r.prompt_len = min(r.prompt_len, max_seq - 16)
    return reqs


def _engine(cfg, params, *, slots, paged, page_size=128, pool_tokens=None,
            max_batch=None):
    sched = BucketServeScheduler(cfg, BUDGET, SchedulerConfig(
        max_batch=max_batch or slots,
        memory_model="paged" if paged else "sum", page_size=page_size))
    eng = ServingEngine(cfg, params, sched, max_slots=slots,
                        cache_len=cfg.max_seq_len, paged=paged,
                        page_size=page_size, kv_pool_tokens=pool_tokens)
    return eng


class TestPagedEngineParity:
    """Same mixed workload through the paged and contiguous pools ->
    identical emitted token ids per request, AND (the acceptance bar)
    page-granular admission sustains >= 2x the concurrency of the
    contiguous pool under the same HBM budget with page size 128."""

    def test_mixed_workload_tokens_identical_and_2x_concurrency(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        contig_slots = 2
        budget_tokens = contig_slots * cfg.max_seq_len   # 2048 = 16 pages

        outs, peaks = {}, {}
        for paged in (False, True):
            reqs = _mixed_requests(20, cfg.max_seq_len)
            eng = _engine(cfg, params,
                          slots=12 if paged else contig_slots,
                          max_batch=12 if paged else contig_slots,
                          paged=paged, page_size=128,
                          pool_tokens=budget_tokens if paged else None)
            eng.submit(reqs)
            done = eng.run(max_wall_s=600)
            assert len(done) == len(reqs)
            outs[paged] = {r.rid: eng.outputs[r.rid] for r in reqs}
            peaks[paged] = eng.result.peak_pool
            for r in reqs:
                assert len(eng.outputs[r.rid]) == r.max_new_tokens

        assert outs[True] == outs[False]          # bit-identical token ids
        assert peaks[False] <= contig_slots
        assert peaks[True] >= 2 * peaks[False], peaks

    def test_windowed_ring_cache_parity(self):
        """Ring (sliding-window) caches page the same way: virtual slot
        pos % W indirects through the table; parity must survive wraps
        and a window that does not divide the page size."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=128,
                               sliding_window=48)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(9)
        outs = {}
        for paged in (False, True):
            reqs = [Request(rid=i, prompt_len=int(rng.integers(16, 100)),
                            max_new_tokens=int(rng.integers(4, 30)),
                            arrival=0.0, task_type=TaskType.OFFLINE)
                    for i in range(6)]
            rng = np.random.default_rng(9)        # same lengths both runs
            eng = _engine(cfg, params, slots=4, paged=paged, page_size=32)
            eng.submit(reqs)
            done = eng.run(max_wall_s=300)
            assert len(done) == 6
            outs[paged] = {r.rid: eng.outputs[r.rid] for r in reqs}
        assert outs[True] == outs[False]

    def test_paged_composes_with_chunked_prefill(self):
        """Chunked prefill writes a contiguous batch cache; the paged
        insert chops it into pages — the two features must compose
        without changing tokens."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=256)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs = {}
        for paged in (False, True):
            sched = BucketServeScheduler(cfg, BUDGET, SchedulerConfig(
                max_batch=4, memory_model="paged" if paged else "sum",
                page_size=64))
            eng = ServingEngine(cfg, params, sched, max_slots=4,
                                cache_len=256, chunk_tokens=64, paged=paged,
                                page_size=64)
            rng = np.random.default_rng(7)
            reqs = [Request(rid=i, prompt_len=int(rng.integers(40, 200)),
                            max_new_tokens=5, arrival=0.0,
                            task_type=TaskType.OFFLINE) for i in range(5)]
            eng.submit(reqs)
            assert len(eng.run(max_wall_s=300)) == 5
            outs[paged] = {r.rid: eng.outputs[r.rid] for r in reqs}
        assert outs[True] == outs[False]

    def test_int8_kv_paged_parity(self):
        """The quantized-KV serving variant pages its scale pools too:
        int8 paged tokens must match int8 contiguous tokens (scale
        entries scattered to the wrong page would silently corrupt)."""
        cfg = dataclasses.replace(
            get_smoke_config("qwen3-14b", max_seq_len=128),
            kv_cache_dtype="int8")
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs = {}
        for paged in (False, True):
            rng = np.random.default_rng(5)
            reqs = [Request(rid=i, prompt_len=int(rng.integers(8, 90)),
                            max_new_tokens=int(rng.integers(3, 9)),
                            arrival=0.0, task_type=TaskType.OFFLINE)
                    for i in range(6)]
            eng = _engine(cfg, params, slots=4, paged=paged, page_size=32)
            eng.submit(reqs)
            assert len(eng.run(max_wall_s=300)) == 6
            outs[paged] = {r.rid: eng.outputs[r.rid] for r in reqs}
        assert outs[True] == outs[False]

    def test_unpaged_arch_rejected(self):
        """Attention-free archs have no KV to page."""
        cfg = get_smoke_config("rwkv6-3b")
        assert not tfm.supports_paged_decode(cfg)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(AssertionError):
            _engine(cfg, params, slots=4, paged=True)

    def test_too_small_explicit_pool_rejected(self):
        """An explicit kv_pool_tokens below one full request + trash
        page must raise, not silently inflate (honest 'same HBM budget'
        comparisons depend on it)."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=256)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="too small"):
            _engine(cfg, params, slots=4, paged=True, page_size=128,
                    pool_tokens=128)
        with pytest.raises(ValueError, match="too small"):
            Simulator(BucketServeScheduler(cfg, BUDGET, SchedulerConfig()),
                      CostModel(cfg, A100X4), mode="disagg", paged=True,
                      page_size=128, kv_pool_tokens=128, cache_len=256)


class TestPagedPreemption:
    def test_block_exhaustion_preempts_youngest_and_completes(self):
        """A pool too small for the live set forces mid-decode page
        exhaustion: the youngest request is evicted through the requeue
        path, re-prefills later, and every request still finishes with a
        full, correct output stream."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=128)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        reqs = [Request(rid=i, prompt_len=int(rng.integers(20, 40)),
                        max_new_tokens=int(rng.integers(20, 40)),
                        arrival=0.0, task_type=TaskType.OFFLINE)
                for i in range(6)]
        # 5 pages of 32: 4 usable after the trash page — one full request
        eng = _engine(cfg, params, slots=4, paged=True, page_size=32,
                      pool_tokens=5 * 32)
        eng.submit(reqs)
        done = eng.run(max_wall_s=600)
        assert len(done) == 6
        assert eng.result.preempt_events > 0
        # preempted requests restart from scratch: outputs are complete
        # and match an unconstrained reference run
        ref_eng = _engine(cfg, params, slots=4, paged=True, page_size=32)
        ref_reqs = [dataclasses.replace(r, arrival=0.0, generated=0,
                                        first_token=-1.0, prefill_start=-1.0,
                                        finished=-1.0)
                    for r in reqs]
        ref_eng.submit(ref_reqs)
        ref_eng.run(max_wall_s=600)
        for r in reqs:
            assert len(eng.outputs[r.rid]) == r.max_new_tokens
            assert eng.outputs[r.rid] == ref_eng.outputs[r.rid]
        # arrival-rate stats were never double-counted by the requeues
        assert len(eng.sched.monitor.seq_lens) == 6

    def test_pages_all_freed_after_run(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=128)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        eng = _engine(cfg, params, slots=4, paged=True, page_size=32,
                      pool_tokens=5 * 32)
        rng = np.random.default_rng(1)
        reqs = [Request(rid=i, prompt_len=int(rng.integers(8, 60)),
                        max_new_tokens=int(rng.integers(2, 20)),
                        arrival=0.0, task_type=TaskType.OFFLINE)
                for i in range(8)]
        eng.submit(reqs)
        assert len(eng.run(max_wall_s=600)) == 8
        be = eng.backend
        assert be.alloc.free_pages() == be.alloc.n_pages   # no leaks
        assert be.alloc.live_pages() == 0


class _RecordingScheduler(BucketServeScheduler):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.formed = []

    def next_prefill_batch(self, now):
        batch = super().next_prefill_batch(now)
        if batch is not None:
            self.formed.append(tuple(r.rid for r in batch.requests))
        return batch


class TestPagedBackendParity:
    """CostModelBackend mirrors the engine's block accounting: the same
    scheduler driven through both backends in PAGED mode still makes
    identical scheduling decisions."""

    N, SLOTS = 12, 4
    PAGE = 128

    def _workload(self):
        rng = np.random.default_rng(11)
        return [Request(rid=i, prompt_len=int(rng.integers(8, 100)),
                        max_new_tokens=4, arrival=0.0,
                        task_type=TaskType.ONLINE) for i in range(self.N)]

    def _sched(self, cfg):
        return _RecordingScheduler(cfg, BUDGET, SchedulerConfig(
            max_batch=self.SLOTS, memory_model="paged",
            page_size=self.PAGE))

    def test_same_batches_and_buckets_paged(self):
        # cache_len BELOW max_seq_len: both backends must derive the
        # page cap from the same cfg.attn_cache_len(cache_len) rule
        cfg = get_smoke_config("qwen3-14b", max_seq_len=256)
        cache_len = 128
        pool_tokens = 16 * self.PAGE

        sched_sim = self._sched(cfg)
        sim = Simulator(sched_sim, CostModel(cfg, A100X4), mode="disagg",
                        decode_slot_cap=self.SLOTS, paged=True,
                        page_size=self.PAGE, kv_pool_tokens=pool_tokens,
                        cache_len=cache_len)
        res = sim.run(self._workload())
        assert len(res.finished()) == self.N
        assert res.preempt_events == 0

        sched_eng = self._sched(cfg)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, sched_eng, max_slots=self.SLOTS,
                            cache_len=cache_len, paged=True,
                            page_size=self.PAGE,
                            kv_pool_tokens=pool_tokens)
        eng.submit(self._workload())
        done = eng.run(max_wall_s=300)
        assert len(done) == self.N
        assert eng.result.preempt_events == 0
        assert eng.backend.alloc.n_pages == sim.backend.alloc.n_pages

        assert sched_sim.formed == sched_eng.formed
        assert [(b.low, b.up) for b in sched_sim.buckets.buckets] == \
               [(b.low, b.up) for b in sched_eng.buckets.buckets]


class TestOOMBackoffRecovery:
    """Regression: ``_cap_scale`` used to advance the recovery factor on
    EVERY read, so idle scheduler ticks (no batch formed) silently
    restored the cap after an OOM.  Recovery now advances only via
    ``notify_dispatch`` (called by the loop per successful dispatch)."""

    def _sched(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=128)
        return BucketServeScheduler(cfg, BUDGET, SchedulerConfig())

    def test_cap_scale_is_a_pure_read(self):
        s = self._sched()
        assert s._cap_scale() == 1.0
        s.notify_oom()
        shrunk = s._cap_scale()
        assert shrunk == pytest.approx(0.85)
        for _ in range(50):                       # reads never recover
            s._cap_scale()
        assert s._cap_scale() == pytest.approx(shrunk)

    def test_recovery_only_on_dispatch(self):
        s = self._sched()
        s.notify_oom()
        shrunk = s._cap_scale()
        s.notify_dispatch()
        once = s._cap_scale()
        assert once == pytest.approx(shrunk * 1.02)
        for _ in range(200):
            s.notify_dispatch()
        assert s._cap_scale() == 1.0              # capped at full

    def test_idle_ticks_do_not_recover(self):
        """A scheduler polled with an empty queue (the loop's idle tick)
        must not creep its cap back up."""
        from repro.core.baselines import DistServeLikeScheduler
        cfg = get_smoke_config("qwen3-14b", max_seq_len=128)
        s = DistServeLikeScheduler(cfg, BUDGET)
        s.notify_oom()
        shrunk = s._cap_scale()
        for t in range(100):
            assert s.next_prefill_batch(float(t)) is None
        assert s._cap_scale() == pytest.approx(shrunk)
