"""PR 8 observability: latency ledger conservation, the tracer seam's
zero-overhead contract, Perfetto export schema, and the derived gauges
(monitor blame window, time-weighted pool utilization, padding waste).

The load-bearing invariant (DESIGN.md §7): a request is in exactly ONE
ledger phase at every instant, so the phase durations sum to the
end-to-end latency by construction — checked here on hand-driven
ledgers AND on full serving runs through every adversarial path
(admission clamp, OOM requeue, restore hold, session-turn cascade,
drop-before-first-token).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.batcher import MemoryBudget
from repro.core.monitor import GlobalMonitor
from repro.core.request import Request, TaskType
from repro.core.scheduler import BucketServeScheduler, SchedulerConfig
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.core.telemetry import (CONSERVE_TOL, NULL_TRACER, PHASES,
                                  WAIT_PHASES, LatencyLedger, NullTracer,
                                  Tracer, blame_means, validate_perfetto)
from repro.data.workload import DEFAULT_CLASS_MIX, WorkloadSpec, generate

CFG = get_config("llama2-13b")
PAGE = 128


# ----------------------------------------------------------- ledger unit --
class TestLedgerUnit:
    def test_lifecycle_conserves(self):
        led = LatencyLedger()
        led.start(1.0)
        led.to("formed", 2.5)
        led.to("prefill", 2.5)
        led.mark_first(4.0)
        led.to("transfer", 4.0)
        led.to("decode", 4.25)
        led.close(9.0)
        assert led.seq == ["queue", "formed", "prefill", "transfer",
                           "decode"]
        assert led.phases == pytest.approx(
            {"queue": 1.5, "formed": 0.0, "prefill": 1.5,
             "transfer": 0.25, "decode": 4.75})
        assert led.conserved()
        assert abs(led.residual()) <= CONSERVE_TOL
        # TTFT view frozen at mark_first: no decode/transfer time
        assert led.ttft_phases == pytest.approx(
            {"queue": 1.5, "formed": 0.0, "prefill": 1.5})

    def test_reentry_is_silent(self):
        led = LatencyLedger()
        led.start(0.0)
        led.to("queue", 1.0)          # same phase: accumulate, no seq
        led.to("queue", 2.0)
        led.close(3.0)
        assert led.seq == ["queue"]
        assert led.phases["queue"] == pytest.approx(3.0)
        assert led.conserved()

    def test_gap_splits_at_penalty_window(self):
        # requeue_gap covers only the restart-penalty window; time past
        # it is ordinary queueing (the request was schedulable again)
        led = LatencyLedger()
        led.start(0.0)
        led.gap(1.0, until=2.0)
        led.to("formed", 3.5)
        led.close(3.5)
        assert led.seq == ["queue", "requeue_gap", "formed"]
        assert led.phases["requeue_gap"] == pytest.approx(1.0)
        assert led.phases["queue"] == pytest.approx(1.0 + 1.5)
        assert led.conserved()

    def test_gap_entirely_within_window(self):
        led = LatencyLedger()
        led.start(0.0)
        led.gap(1.0, until=10.0)
        led.close(3.0)
        assert led.phases["requeue_gap"] == pytest.approx(2.0)
        assert led.phases.get("queue", 0.0) == pytest.approx(1.0)
        assert led.conserved()

    def test_drop_open_and_shut(self):
        # a request dropped the instant it is seen (cascade drop of a
        # held session turn) conserves trivially: zero-width life
        led = LatencyLedger()
        led.start(5.0)
        led.close(5.0)
        assert led.conserved() and led.total() == 0.0
        assert led.ttft_phases is None          # never produced a token

    def test_monotonicity_guard(self):
        led = LatencyLedger()
        led.start(1.0)
        led.to("formed", 1.0 - 1e-12)           # float slack: clamped
        with pytest.raises(AssertionError):
            led.to("prefill", 0.5)              # a real regression

    def test_double_start_rejected(self):
        led = LatencyLedger()
        led.start(0.0)
        with pytest.raises(AssertionError):
            led.start(1.0)

    def test_unknown_phase_rejected(self):
        led = LatencyLedger()
        led.start(0.0)
        with pytest.raises(AssertionError):
            led.to("thinking", 1.0)

    def test_wait_share(self):
        led = LatencyLedger()
        led.start(0.0)
        led.to("prefill", 3.0)                  # 3s queue
        led.close(4.0)                          # 1s prefill
        assert led.wait_share() == pytest.approx(0.75)
        assert set(WAIT_PHASES) < set(PHASES)

    def test_blame_means(self):
        out = blame_means([{"queue": 1.0, "decode": 3.0},
                           {"queue": 3.0}])
        assert out == pytest.approx({"queue": 2.0, "decode": 1.5})
        assert blame_means([]) == {}
        # phase order of PHASES, zero-total phases omitted
        assert "prefill" not in out


# --------------------------------------------------------- tracer/export --
class TestTracerExport:
    def test_roundtrip_schema_valid(self, tmp_path):
        tr = Tracer()
        tr.complete("exec", "batch", 0.5, 1.0, cat="batch",
                    args={"size": 4})
        tr.instant("retention", "evict-walk", 1.0, cat="evict")
        tr.counter("kv", "util", 1.25, {"level": 0.5})
        tr.async_begin("requests", "req-1", 0.0, 1)
        tr.async_end("requests", "req-1", 2.0, 1)
        doc = tr.save(str(tmp_path / "t.json"))
        assert validate_perfetto(doc) == []
        # one named track per distinct name, announced as metadata
        names = {e["args"]["name"] for e in doc["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        assert names == {"exec", "retention", "kv", "requests"}
        # seconds stored as microseconds, sorted by stamp
        ts = [e["ts"] for e in doc["traceEvents"] if e["ph"] != "M"]
        assert ts == sorted(ts) and ts[-1] == pytest.approx(2e6)

    def test_validator_catches_violations(self):
        base = {"name": "x", "cat": "c", "ph": "X", "ts": 1.0, "dur": 1.0,
                "pid": 1, "tid": 1}
        ok = {"traceEvents": [dict(base)]}
        assert validate_perfetto(ok) == []
        bad_order = {"traceEvents": [dict(base, ts=5.0), dict(base)]}
        assert any("non-monotonic" in e for e in
                   validate_perfetto(bad_order))
        neg_dur = {"traceEvents": [dict(base, dur=-1.0)]}
        assert any("dur" in e for e in validate_perfetto(neg_dur))
        bad_ctr = {"traceEvents": [dict(base, ph="C",
                                        args={"v": "high"})]}
        assert any("counter" in e for e in validate_perfetto(bad_ctr))
        orphan = {"traceEvents": [dict(base, ph="e", id=7)]}
        assert any("orphan" in e for e in validate_perfetto(orphan))
        unclosed = {"traceEvents": [dict(base, ph="b", id=7)]}
        assert any("unclosed" in e for e in validate_perfetto(unclosed))
        assert validate_perfetto({"nope": 1}) == ["missing traceEvents list"]
        assert validate_perfetto(None) == ["missing traceEvents list"]


# ---------------------------------------------------------------- monitor --
class TestMonitorGauges:
    def test_idle_tail_prunes_arrival_window(self):
        m = GlobalMonitor(window_s=10.0)
        for t in (0.0, 1.0, 2.0):
            m.on_arrival(t, 64)
        assert m.arrival_rate() > 0.0
        # no arrivals for a long idle stretch: a snapshot must decay
        # the rate to zero, not keep reporting the last burst
        s = m.snapshot(100.0)
        assert s.arrival_rate == 0.0 and len(m.arrivals) == 0

    def test_p95_nearest_rank(self):
        m = GlobalMonitor()
        for i in range(1, 101):
            m.on_first_token(float(i))
            m.on_tpot(float(i) / 1000.0)
        s = m.snapshot(0.0)
        assert s.ttft_p95 == 95.0
        assert s.tpot_p95 == pytest.approx(0.095)
        assert s.ttft_p99 == 99.0 and s.ttft_p50 == 50.0

    def test_retire_blame_window(self):
        m = GlobalMonitor()
        m.on_retire("chat", {"queue": 2.0, "decode": 2.0})
        m.on_retire("chat", {"queue": 4.0})
        m.on_retire("batch", {"queue": 10.0})
        assert m.blame("chat") == pytest.approx(
            {"queue": 3.0, "decode": 1.0})
        # snapshot pools every class
        s = m.snapshot(0.0)
        assert s.blame["queue"] == pytest.approx(16.0 / 3)


# ------------------------------------------------------- serving-loop e2e --
def _burst_sim(tracer=None, n=40):
    """The trace_replay recipe at test scale: heterogeneous class mix,
    4x bursts, shared prefixes, multi-turn sessions, pool tight enough
    to spill AND restore — every adversarial ledger path fires."""
    budget = MemoryBudget(hbm_bytes_per_device=40 * 2 ** 30, n_devices=3,
                          weight_bytes=CFG.param_count() * 2)
    sched = BucketServeScheduler(CFG, budget, SchedulerConfig(
        max_batch=8, memory_model="paged", page_size=PAGE))
    sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                    decode_slot_cap=64, paged=True, page_size=PAGE,
                    kv_pool_tokens=16 * 1024, prefix_cache=True,
                    session_ttl=600.0, host_pool_tokens=64 * 1024,
                    tracer=tracer)
    spec = WorkloadSpec(rps=6.0, n_requests=n,
                        max_model_len=CFG.max_seq_len,
                        vocab_size=CFG.vocab_size,
                        class_mix=DEFAULT_CLASS_MIX, burst_factor=4.0,
                        diurnal_period_s=40.0, burst_every_s=15.0,
                        burst_duration_s=4.0, prefix_groups=4,
                        prefix_tokens=2 * PAGE, sessions=8, turns=3,
                        think_time_s=2.0, seed=7)
    return sim, generate(spec)


def _final_states(res):
    return sorted((r.rid, r.finished, r.first_token, r.generated)
                  for r in res.requests)


class _BombTracer(NullTracer):
    """enabled=False but every emit RAISES: proves disabled runs never
    enter a tracer method — the guard-before-build contract, stronger
    than timing a no-op."""

    def _boom(self, *a, **kw):
        raise RuntimeError("tracer called while disabled")

    track = complete = instant = counter = _boom
    async_begin = async_end = _boom


class TestServingLoopTelemetry:
    def test_disabled_tracer_never_called_and_results_identical(self):
        sim0, reqs0 = _burst_sim(tracer=None)
        res0 = sim0.run(reqs0)
        simb, reqsb = _burst_sim(tracer=_BombTracer())
        resb = simb.run(reqsb)          # would raise on ANY tracer call
        assert _final_states(resb) == _final_states(res0)

    def test_conservation_on_every_adversarial_path(self):
        sim, reqs = _burst_sim()
        res = sim.run(reqs)
        assert res.incomplete() == 0
        assert res.spilled_pages > 0 and res.restored_pages > 0
        phases_seen = set()
        for r in res.requests:
            led = r.ledger
            assert led is not None and led.closed, r.rid
            assert led.conserved(), (r.rid, led.residual(), led.seq)
            phases_seen |= set(led.phases)
        # the burst actually drove the adversarial paths this test is
        # named for — a clamp wait, a restore hold, a session turn
        assert "admission_block" in phases_seen
        assert "restore_hold" in phases_seen
        assert "prefill" in phases_seen and "decode" in phases_seen
        # derived gauges land in the result
        assert 0.0 < res.kv_util_time_weighted <= 1.0
        assert res.batch_padding_fractions
        assert all(0.0 <= f < 1.0 for f in res.batch_padding_fractions)
        assert all(0.0 < h <= 1.0 for h in res.batch_homogeneity)
        blame = res.blame()
        assert blame and set(blame) <= set(PHASES)
        assert res.ttft_blame() and 0.0 <= res.ttft_wait_share() <= 1.0

    def test_enabled_tracer_spans_and_schema(self, tmp_path):
        tr = Tracer()
        sim, reqs = _burst_sim(tracer=tr)
        res = sim.run(reqs)
        assert res.spilled_pages > 0 and res.restored_pages > 0
        doc = tr.save(str(tmp_path / "run.json"))
        assert validate_perfetto(doc) == []
        cats = {}
        for e in doc["traceEvents"]:
            if e["ph"] != "M":
                cats[e.get("cat")] = cats.get(e.get("cat"), 0) + 1
        # one span per batch / spill / restore event, plus the request
        # async spans and the kv counter
        assert cats.get("batch", 0) >= 1
        assert cats.get("spill", 0) >= 1
        assert cats.get("restore", 0) >= 1
        assert cats.get("request", 0) >= 2 * len(res.requests)
        assert cats.get("counter", 0) >= 1

    def test_drop_before_first_token_conserves(self):
        # an unservable singleton (prompt + generation exceed the whole
        # live-token budget) is dropped at OOM time with no token
        # produced: its ledger still closes and conserves
        budget = MemoryBudget(hbm_bytes_per_device=40 * 2 ** 30,
                              n_devices=1,
                              weight_bytes=CFG.param_count() * 2)
        sched = BucketServeScheduler(CFG, budget,
                                     SchedulerConfig(max_batch=4))
        sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                        decode_slot_cap=4)
        over = int(sim.backend.kv_budget_tokens()) + 1
        giant = Request(rid=0, prompt_len=over, max_new_tokens=64,
                        arrival=0.0, task_type=TaskType.ONLINE)
        ok = Request(rid=1, prompt_len=128, max_new_tokens=4,
                     arrival=0.0, task_type=TaskType.ONLINE)
        res = sim.run([giant, ok])
        dropped = next(r for r in res.requests if r.rid == 0)
        served = next(r for r in res.requests if r.rid == 1)
        assert dropped.dropped and dropped.ledger.closed
        assert dropped.ledger.conserved()
        assert dropped.ledger.ttft_phases is None
        assert served.finished >= 0 and served.ledger.conserved()

    def test_null_tracer_is_module_default(self):
        assert NULL_TRACER.enabled is False
        sched = BucketServeScheduler(
            CFG, MemoryBudget(2 ** 30, 1, 0), SchedulerConfig())
        assert sched.tracer is NULL_TRACER
