"""BucketServe core: Algorithm 1, Eqs. (1)-(6), scheduler policies.

Property-based tests (hypothesis) pin the system invariants:
  * buckets always partition [0, L_max) — no gaps, no overlaps;
  * every queued request sits in the bucket covering its length;
  * merge restores the single full-range bucket;
  * Eq. (6) batches never exceed the memory budget;
  * Eq. (4)/Lloyd boundaries never increase expected waste vs. one bucket.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property-based invariants need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (BucketManager, BucketServeScheduler,
                        DynamicBatchController, MemoryBudget, Request,
                        SchedulerConfig, TaskType)
from repro.core import analysis
from repro.core.request import Request as Req

L_MAX = 32768


def mk_reqs(lengths, task=TaskType.OFFLINE):
    return [Req(rid=i, prompt_len=int(s), max_new_tokens=16, arrival=i * 0.01,
                task_type=task) for i, s in enumerate(lengths)]


# ------------------------------------------------------------ Algorithm 1 -
class TestBucketManager:
    def test_initial_single_bucket(self):
        bm = BucketManager(L_MAX)
        assert len(bm.buckets) == 1
        assert (bm.buckets[0].low, bm.buckets[0].up) == (0, L_MAX)

    def test_split_on_pressure(self):
        bm = BucketManager(L_MAX)
        # 60% short requests -> majority below midpoint -> split
        for r in mk_reqs([100] * 12 + [30000] * 8):
            bm.add(r)
        bm.adjust(n_max=10)
        assert len(bm.buckets) == 2
        assert bm.buckets[0].up == L_MAX // 2 == bm.buckets[1].low
        # requests partitioned by length
        assert all(r.prompt_len < L_MAX // 2
                   for r in bm.buckets[0].requests)
        assert all(r.prompt_len >= L_MAX // 2
                   for r in bm.buckets[1].requests)

    def test_no_split_when_majority_long(self):
        bm = BucketManager(L_MAX)
        for r in mk_reqs([30000] * 15 + [100] * 5):
            bm.add(r)
        bm.adjust(n_max=10)      # only 25% below midpoint < theta=0.5
        assert len(bm.buckets) == 1

    def test_merge_on_low_load(self):
        bm = BucketManager(L_MAX)
        for r in mk_reqs([100] * 12 + [30000] * 8):
            bm.add(r)
        bm.adjust(n_max=10)
        assert len(bm.buckets) == 2
        bm.pop(bm.buckets[0].requests + bm.buckets[1].requests)
        for r in mk_reqs([50, 60]):
            bm.add(r)
        bm.adjust(n_max=10)      # total 2 < 10 -> merge (lines 11-13)
        assert len(bm.buckets) == 1
        assert bm.total() == 2

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(1, L_MAX - 1), min_size=1, max_size=200),
           st.integers(1, 64))
    def test_partition_invariant(self, lengths, n_max):
        """Buckets tile [0, L_max) exactly and cover every request."""
        bm = BucketManager(L_MAX)
        for r in mk_reqs(lengths):
            bm.add(r)
        for _ in range(4):       # several adjustment rounds
            bm.adjust(n_max)
        bounds = bm.boundaries()
        assert bounds[0] == 0 and bounds[-1] == L_MAX
        assert bounds == sorted(bounds)
        assert all(b2 > b1 for b1, b2 in zip(bounds, bounds[1:]))
        assert bm.total() == len(lengths)
        for b in bm.buckets:
            for r in b.requests:
                assert b.low <= min(r.prompt_len, L_MAX - 1) < b.up

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(1, L_MAX - 1), min_size=1, max_size=100))
    def test_bisect_assignment_matches_linear(self, lengths):
        a = BucketManager(L_MAX, assignment="linear")
        b = BucketManager(L_MAX, assignment="bisect")
        for r in mk_reqs(lengths):
            a.add(r)
        for r in mk_reqs(lengths):
            b.add(r)
        a.adjust(8), b.adjust(8)
        a.adjust(8), b.adjust(8)
        assert a.boundaries() == b.boundaries()
        assert [len(x) for x in a.buckets] == [len(x) for x in b.buckets]


# ----------------------------------------------------------------- Eq 2-4 -
class TestWasteModel:
    def test_waste_ratio(self):
        assert analysis.waste_ratio([100, 100]) == 0.0
        assert analysis.waste_ratio([50, 100]) == pytest.approx(0.25)

    def test_bucketing_reduces_expected_waste(self):
        rng = np.random.default_rng(0)
        lens = np.concatenate([rng.integers(10, 200, 500),
                               rng.integers(8000, 30000, 500)])
        one = analysis.expected_waste(lens, [0, L_MAX])
        two = analysis.expected_waste(lens, [0, L_MAX // 2, L_MAX])
        assert two < one

    def test_eq4_fixed_point_beats_midpoints(self):
        rng = np.random.default_rng(1)
        lens = rng.lognormal(5.0, 1.2, 2000).clip(1, L_MAX - 1)
        mid = analysis.expected_waste(lens, np.linspace(0, L_MAX, 5))
        opt = analysis.expected_waste(
            lens, analysis.optimal_boundaries_kmeans(lens, 4))
        assert opt <= mid

    def test_kv_cache_eq1(self):
        # Eq. (1): 2 L H D S B N
        assert analysis.kv_cache_bytes(2, 4, 64, 128, 2, 8) == \
            2 * 2 * 4 * 64 * 128 * 2 * 8


# ------------------------------------------------------------------ Eq 5-6 -
class TestBatcher:
    def _controller(self, memory_model="sum"):
        cfg = get_config("llama2-13b")
        budget = MemoryBudget(hbm_bytes_per_device=40 * 2 ** 30, n_devices=2,
                              weight_bytes=cfg.param_count() * 2)
        return DynamicBatchController(cfg, budget, memory_model=memory_model,
                                      decode_reserve=0.0), cfg, budget

    def test_msafe_eq5(self):
        _, cfg, budget = self._controller()
        total = 40 * 2 ** 30 * 2
        remain = total - cfg.param_count() * 2 - 0.05 * total
        assert budget.m_safe() == pytest.approx(0.9 * remain)

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(16, 4000), min_size=1, max_size=64))
    def test_eq6_batch_never_exceeds_budget(self, lengths):
        ctl, cfg, budget = self._controller()
        reqs = mk_reqs(lengths)
        batch = ctl.form_batch(reqs)
        kv = sum(r.prompt_len + r.max_new_tokens for r in batch.requests) \
            * ctl.kv_per_tok
        assert batch.requests          # always serves at least one request
        if len(batch.requests) > 1:
            assert kv <= budget.m_safe()

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(16, 4000), min_size=1, max_size=64))
    def test_padded_model_never_exceeds_budget(self, lengths):
        ctl, cfg, budget = self._controller("padded")
        batch = ctl.form_batch(mk_reqs(lengths))
        if len(batch.requests) > 1:
            pad = max(r.prompt_len + r.max_new_tokens for r in batch.requests)
            pad = ctl.round_up(pad)
            assert pad * len(batch.requests) * ctl.kv_per_tok <= \
                budget.m_safe()


# --------------------------------------------------------------- scheduler -
class TestScheduler:
    def _sched(self, **kw):
        cfg = get_config("llama2-13b")
        budget = MemoryBudget(hbm_bytes_per_device=40 * 2 ** 30, n_devices=2,
                              weight_bytes=cfg.param_count() * 2)
        return BucketServeScheduler(cfg, budget, SchedulerConfig(**kw))

    def test_online_bucket_priority(self):
        s = self._sched()
        offline = mk_reqs([3000] * 4, TaskType.OFFLINE)
        online = mk_reqs([120] * 2, TaskType.ONLINE)
        for i, r in enumerate(online):
            r.rid += 100
            r.arrival = 5.0 + i      # online arrived later
        for r in offline + online:
            s.on_arrival(r, r.arrival)
        batch = s.next_prefill_batch(10.0)
        # online requests must be served despite later arrival
        assert any(r.task_type == TaskType.ONLINE for r in batch.requests)

    def test_sjf_within_bucket_offline(self):
        s = self._sched(offline_policy="sjf")
        reqs = mk_reqs([500, 100, 300], TaskType.OFFLINE)
        for r in reqs:
            s.on_arrival(r, r.arrival)
        batch = s.next_prefill_batch(1.0)
        lens = [r.prompt_len for r in batch.requests]
        assert lens == sorted(lens)

    def test_in_flight_tokens_reduce_batch(self):
        s = self._sched()
        for r in mk_reqs([2000] * 40, TaskType.OFFLINE):
            s.on_arrival(r, r.arrival)
        b1 = s.next_prefill_batch(1.0)
        s2 = self._sched()
        s2.monitor.in_flight_tokens = int(s2.batcher.token_budget() * 0.45)
        for r in mk_reqs([2000] * 40, TaskType.OFFLINE):
            s2.on_arrival(r, r.arrival)
        b2 = s2.next_prefill_batch(1.0)
        assert b2.size < b1.size

    def test_kv_transfer_time_positive(self):
        s = self._sched()
        for r in mk_reqs([1000] * 4):
            s.on_arrival(r, r.arrival)
        b = s.next_prefill_batch(1.0)
        assert s.kv_transfer_seconds(b) > 0
