"""End-to-end serving: simulator behaviour + real-engine integration."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core import (BucketServeScheduler, MemoryBudget, SchedulerConfig,
                        TaskType)
from repro.core.baselines import SIM_MODE, hardware_for, make_scheduler
from repro.core.engine import ServingEngine
from repro.core.request import Request
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.workload import WorkloadSpec, generate
from repro.models import transformer as tfm

CFG = get_config("llama2-13b")


def run_sim(name, spec, n=150):
    reqs = generate(dataclasses.replace(spec, n_requests=n))
    hw, nd, nexec = hardware_for(name, A100X4)
    budget = MemoryBudget(40 * 2 ** 30, nd, CFG.param_count() * 2)
    sim = Simulator(make_scheduler(name, CFG, budget), CostModel(CFG, hw),
                    mode=SIM_MODE[name])
    return sim.run(reqs), nexec


class TestSimulator:
    SPEC = WorkloadSpec(dataset="mixed", rps=8, n_requests=150,
                        max_model_len=CFG.max_seq_len)

    def test_all_systems_complete(self):
        for name in SIM_MODE:
            res, _ = run_sim(name, self.SPEC)
            finished = res.finished()
            assert len(finished) + sum(r.dropped for r in res.requests) == \
                len(res.requests), name
            for r in finished:
                assert r.first_token >= r.arrival
                assert r.finished >= r.first_token
                assert r.generated == r.max_new_tokens

    # offline = deep queue: the regime of the paper's Fig. 5a throughput
    # claims (bucketing is only active when requests actually queue)
    OFFLINE = dataclasses.replace(SPEC, rps=1e6,
                                  task_type=TaskType.OFFLINE)

    def test_bucketserve_beats_baselines_on_mixed(self):
        """The paper's headline: higher offline throughput under
        heterogeneous load than DistServe-like and UELLM-like systems."""
        ours, _ = run_sim("bucketserve", self.OFFLINE)
        dist, _ = run_sim("distserve", self.OFFLINE)
        uellm, _ = run_sim("uellm", self.OFFLINE)
        assert ours.throughput_tok_s() > dist.throughput_tok_s()
        assert ours.throughput_tok_s() > uellm.throughput_tok_s()

    def test_bucketserve_padding_efficiency(self):
        ours, _ = run_sim("bucketserve", self.OFFLINE)
        dist, _ = run_sim("distserve", self.OFFLINE)
        assert ours.padding_efficiency() > dist.padding_efficiency()

    def test_no_oom_for_bucketserve(self):
        """Eq. (5)/(6) memory safety: BucketServe never OOMs."""
        for rps in (4, 16, 32):
            spec = dataclasses.replace(self.SPEC, rps=rps)
            res, _ = run_sim("bucketserve", spec)
            assert res.oom_events == 0

    def test_bucketing_overhead_below_1pct(self):
        """Paper Fig. 6a: bucketing+batching overhead < 1% of e2e time."""
        res, _ = run_sim("bucketserve", self.SPEC)
        assert res.bucketing_overhead_s < 0.01 * res.makespan

    def test_slo_degrades_with_load(self):
        lo, _ = run_sim("bucketserve",
                        dataclasses.replace(self.SPEC, rps=0.5,
                                            dataset="alpaca"))
        hi, _ = run_sim("bucketserve",
                        dataclasses.replace(self.SPEC, rps=64,
                                            dataset="alpaca"))
        assert lo.slo_attainment() >= hi.slo_attainment()


class TestEngine:
    def _setup(self, arch="qwen3-14b", max_seq=128, slots=4):
        cfg = get_smoke_config(arch, max_seq_len=max_seq)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                              weight_bytes=0)
        sched = BucketServeScheduler(cfg, budget,
                                     SchedulerConfig(max_batch=slots))
        return cfg, ServingEngine(cfg, params, sched, max_slots=slots,
                                  cache_len=max_seq)

    def _reqs(self, n, seed=0, lo=8, hi=48):
        rng = np.random.default_rng(seed)
        return [Request(rid=i, prompt_len=int(rng.integers(lo, hi)),
                        max_new_tokens=int(rng.integers(2, 8)), arrival=0.0,
                        task_type=TaskType.ONLINE) for i in range(n)]

    def test_serves_all_requests(self):
        _, eng = self._setup()
        reqs = self._reqs(10)
        eng.submit(reqs)
        done = eng.run(max_wall_s=300)
        assert len(done) == 10
        for r in done:
            assert r.generated == r.max_new_tokens
            assert len(eng.outputs[r.rid]) == r.max_new_tokens

    def test_engine_matches_unbatched_decode(self):
        """Tokens produced via the batched slot engine equal a plain
        single-request prefill+decode -> continuous batching is lossless."""
        cfg, eng = self._setup()
        reqs = self._reqs(5, seed=3)
        eng.submit(reqs)
        eng.run(max_wall_s=300)
        params = eng.params
        for r in reqs:
            toks = jax.numpy.asarray(r.tokens[None, :])
            lens = jax.numpy.asarray([r.prompt_len])
            logits, cache = tfm.prefill(cfg, params, tokens=toks,
                                        lengths=lens, cache_len=128)
            out = [int(logits.argmax(-1)[0])]
            for _ in range(r.max_new_tokens - 1):
                nt = jax.numpy.asarray([out[-1]], jax.numpy.int32)
                logits, cache = tfm.decode_step(cfg, params, nt, cache)
                out.append(int(logits.argmax(-1)[0]))
            assert out == eng.outputs[r.rid], f"rid={r.rid}"

    def test_rwkv_engine(self):
        """Attention-free arch through the same serving stack."""
        _, eng = self._setup(arch="rwkv6-3b")
        reqs = self._reqs(6, seed=5)
        eng.submit(reqs)
        done = eng.run(max_wall_s=300)
        assert len(done) == 6
