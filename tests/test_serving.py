"""End-to-end serving: the unified ServingLoop driving both backends —
cost-model simulation + real-engine integration + engine/sim parity."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core import (BucketServeScheduler, GlobalMonitor, MemoryBudget,
                        SchedulerConfig, TaskType)
from repro.core.baselines import SIM_MODE, hardware_for, make_scheduler
from repro.core.engine import ServingEngine
from repro.core.request import Request
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.workload import WorkloadSpec, generate
from repro.models import transformer as tfm

CFG = get_config("llama2-13b")


def run_sim(name, spec, n=150):
    reqs = generate(dataclasses.replace(spec, n_requests=n))
    hw, nd, nexec = hardware_for(name, A100X4)
    budget = MemoryBudget(40 * 2 ** 30, nd, CFG.param_count() * 2)
    sim = Simulator(make_scheduler(name, CFG, budget), CostModel(CFG, hw),
                    mode=SIM_MODE[name])
    return sim.run(reqs), nexec


class TestSimulator:
    SPEC = WorkloadSpec(dataset="mixed", rps=8, n_requests=150,
                        max_model_len=CFG.max_seq_len)

    def test_all_systems_complete(self):
        for name in SIM_MODE:
            res, _ = run_sim(name, self.SPEC)
            finished = res.finished()
            assert len(finished) + sum(r.dropped for r in res.requests) == \
                len(res.requests), name
            for r in finished:
                assert r.first_token >= r.arrival
                assert r.finished >= r.first_token
                assert r.generated == r.max_new_tokens

    # offline = deep queue: the regime of the paper's Fig. 5a throughput
    # claims (bucketing is only active when requests actually queue)
    OFFLINE = dataclasses.replace(SPEC, rps=1e6,
                                  task_type=TaskType.OFFLINE)

    def test_bucketserve_beats_baselines_on_mixed(self):
        """The paper's headline: higher offline throughput under
        heterogeneous load than DistServe-like and UELLM-like systems."""
        ours, _ = run_sim("bucketserve", self.OFFLINE)
        dist, _ = run_sim("distserve", self.OFFLINE)
        uellm, _ = run_sim("uellm", self.OFFLINE)
        assert ours.throughput_tok_s() > dist.throughput_tok_s()
        assert ours.throughput_tok_s() > uellm.throughput_tok_s()

    def test_bucketserve_padding_efficiency(self):
        ours, _ = run_sim("bucketserve", self.OFFLINE)
        dist, _ = run_sim("distserve", self.OFFLINE)
        assert ours.padding_efficiency() > dist.padding_efficiency()

    def test_no_oom_for_bucketserve(self):
        """Eq. (5)/(6) memory safety: BucketServe never OOMs."""
        for rps in (4, 16, 32):
            spec = dataclasses.replace(self.SPEC, rps=rps)
            res, _ = run_sim("bucketserve", spec)
            assert res.oom_events == 0

    def test_bucketing_overhead_below_1pct(self):
        """Paper Fig. 6a: bucketing+batching overhead < 1% of e2e time."""
        res, _ = run_sim("bucketserve", self.SPEC)
        assert res.bucketing_overhead_s < 0.01 * res.makespan

    def test_slo_degrades_with_load(self):
        lo, _ = run_sim("bucketserve",
                        dataclasses.replace(self.SPEC, rps=0.5,
                                            dataset="alpaca"))
        hi, _ = run_sim("bucketserve",
                        dataclasses.replace(self.SPEC, rps=64,
                                            dataset="alpaca"))
        assert lo.slo_attainment() >= hi.slo_attainment()


class TestEngine:
    def _setup(self, arch="qwen3-14b", max_seq=128, slots=4):
        cfg = get_smoke_config(arch, max_seq_len=max_seq)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                              weight_bytes=0)
        sched = BucketServeScheduler(cfg, budget,
                                     SchedulerConfig(max_batch=slots))
        return cfg, ServingEngine(cfg, params, sched, max_slots=slots,
                                  cache_len=max_seq)

    def _reqs(self, n, seed=0, lo=8, hi=48):
        rng = np.random.default_rng(seed)
        return [Request(rid=i, prompt_len=int(rng.integers(lo, hi)),
                        max_new_tokens=int(rng.integers(2, 8)), arrival=0.0,
                        task_type=TaskType.ONLINE) for i in range(n)]

    def test_serves_all_requests(self):
        _, eng = self._setup()
        reqs = self._reqs(10)
        eng.submit(reqs)
        done = eng.run(max_wall_s=300)
        assert len(done) == 10
        for r in done:
            assert r.generated == r.max_new_tokens
            assert len(eng.outputs[r.rid]) == r.max_new_tokens

    def test_engine_matches_unbatched_decode(self):
        """Tokens produced via the batched slot engine equal a plain
        single-request prefill+decode -> continuous batching is lossless."""
        cfg, eng = self._setup()
        reqs = self._reqs(5, seed=3)
        eng.submit(reqs)
        eng.run(max_wall_s=300)
        params = eng.params
        for r in reqs:
            toks = jax.numpy.asarray(r.tokens[None, :])
            lens = jax.numpy.asarray([r.prompt_len])
            logits, cache = tfm.prefill(cfg, params, tokens=toks,
                                        lengths=lens, cache_len=128)
            out = [int(logits.argmax(-1)[0])]
            for _ in range(r.max_new_tokens - 1):
                nt = jax.numpy.asarray([out[-1]], jax.numpy.int32)
                logits, cache = tfm.decode_step(cfg, params, nt, cache)
                out.append(int(logits.argmax(-1)[0]))
            assert out == eng.outputs[r.rid], f"rid={r.rid}"

    def test_rwkv_engine(self):
        """Attention-free arch through the same serving stack."""
        _, eng = self._setup(arch="rwkv6-3b")
        reqs = self._reqs(6, seed=5)
        eng.submit(reqs)
        done = eng.run(max_wall_s=300)
        assert len(done) == 6


class TestChunkedPrefill:
    """Chunked prefill (DESIGN.md §2): prefill_chunk composition is
    bit-exact vs whole-prompt prefill, and the engine interleaves decode
    iterations between a long prompt's chunks."""

    def test_prefill_chunk_matches_prefill(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=128)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        lens = np.array([10, 37, 64], np.int32)
        B, pad, C = 3, 64, 16
        toks = np.zeros((B, pad), np.int32)
        for i, L in enumerate(lens):
            toks[i, :L] = rng.integers(0, cfg.vocab_size, L)
        logits_full, cache_full = tfm.prefill(
            cfg, params, tokens=jax.numpy.asarray(toks),
            lengths=jax.numpy.asarray(lens), cache_len=128)
        cache = tfm.init_cache(cfg, B, 128)
        collected = np.zeros((B, cfg.vocab_size), np.float32)
        for s in range(0, pad, C):
            lg, cache = tfm.prefill_chunk(
                cfg, params, jax.numpy.asarray(toks[:, s:s + C]), cache, s,
                jax.numpy.asarray(lens))
            fin = ((lens - 1) >= s) & ((lens - 1) < s + C)
            collected[fin] = np.asarray(lg)[fin]
        np.testing.assert_allclose(collected, np.asarray(logits_full),
                                   rtol=1e-5, atol=1e-5)
        # cache parity at every valid position (per-row prompt length)
        k_full = cache_full["groups"][0][0]["k"]
        k_chunk = cache["groups"][0][0]["k"]
        for b, L in enumerate(lens):
            np.testing.assert_allclose(np.asarray(k_chunk[:, b, :L]),
                                       np.asarray(k_full[:, b, :L]),
                                       rtol=1e-5, atol=1e-5)

    def test_chunk_gating(self):
        """Ring-cache (windowed) and VLM configs fall back to whole-prompt
        prefill — chunking needs a positional cache."""
        assert tfm.supports_chunked_prefill(
            get_smoke_config("qwen3-14b", max_seq_len=128))
        assert not tfm.supports_chunked_prefill(
            get_smoke_config("recurrentgemma-2b", max_seq_len=128))
        assert not tfm.supports_chunked_prefill(
            get_smoke_config("qwen3-14b", max_seq_len=128,
                             sliding_window=48))

    def test_engine_interleaves_decode_between_chunks(self):
        """Short requests keep decoding while a long prompt prefills in
        chunks — the phase-interference fix chunking exists for."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=256)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                              weight_bytes=0)
        sched = BucketServeScheduler(cfg, budget,
                                     SchedulerConfig(max_batch=4))
        eng = ServingEngine(cfg, params, sched, max_slots=4, cache_len=256,
                            chunk_tokens=64)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt_len=int(rng.integers(8, 48)),
                        max_new_tokens=8, arrival=0.0,
                        task_type=TaskType.ONLINE) for i in range(6)]
        reqs += [Request(rid=100 + i, prompt_len=200, max_new_tokens=4,
                         arrival=0.0, task_type=TaskType.OFFLINE)
                 for i in range(2)]
        eng.submit(reqs)
        done = eng.run(max_wall_s=300)
        assert len(done) == len(reqs)
        for r in done:
            assert r.generated == r.max_new_tokens
            assert len(eng.outputs[r.rid]) == r.max_new_tokens
        assert eng.interleaved_decode_steps > 0

    def test_chunked_tokens_match_unchunked(self):
        """Same workload with and without chunking produces the same
        token streams (chunking changes scheduling, not math)."""
        outs = []
        for chunk in (None, 32):
            cfg = get_smoke_config("qwen3-14b", max_seq_len=128)
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                                  weight_bytes=0)
            sched = BucketServeScheduler(cfg, budget,
                                         SchedulerConfig(max_batch=4))
            eng = ServingEngine(cfg, params, sched, max_slots=4,
                                cache_len=128, chunk_tokens=chunk)
            rng = np.random.default_rng(7)
            reqs = [Request(rid=i, prompt_len=int(rng.integers(40, 100)),
                            max_new_tokens=5, arrival=0.0,
                            task_type=TaskType.OFFLINE) for i in range(4)]
            eng.submit(reqs)
            done = eng.run(max_wall_s=300)
            assert len(done) == 4
            outs.append({r.rid: eng.outputs[r.rid] for r in reqs})
        assert outs[0] == outs[1]


class _RecordingScheduler(BucketServeScheduler):
    """Records every formed batch (request-id tuples) for parity checks."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.formed = []

    def next_prefill_batch(self, now):
        batch = super().next_prefill_batch(now)
        if batch is not None:
            self.formed.append(tuple(r.rid for r in batch.requests))
        return batch


class TestBackendParity:
    """The tentpole invariant: ONE scheduling policy, pluggable
    substrates.  The same BucketServeScheduler driven through the
    CostModelBackend (virtual time) and the JaxEngineBackend (wall time)
    on an identical workload must make identical scheduling decisions —
    same batch compositions, same bucket boundaries."""

    N, SLOTS = 12, 4

    def _workload(self):
        rng = np.random.default_rng(11)
        return [Request(rid=i, prompt_len=int(rng.integers(8, 100)),
                        max_new_tokens=4, arrival=0.0,
                        task_type=TaskType.ONLINE) for i in range(self.N)]

    def _sched(self, cfg):
        budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                              weight_bytes=0)
        return _RecordingScheduler(cfg, budget,
                                   SchedulerConfig(max_batch=self.SLOTS))

    def test_same_batches_and_buckets(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=128)

        sched_sim = self._sched(cfg)
        sim = Simulator(sched_sim, CostModel(cfg, A100X4), mode="disagg",
                        decode_slot_cap=self.SLOTS)
        res = sim.run(self._workload())
        assert len(res.finished()) == self.N

        sched_eng = self._sched(cfg)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, sched_eng, max_slots=self.SLOTS,
                            cache_len=128)
        eng.submit(self._workload())
        done = eng.run(max_wall_s=300)
        assert len(done) == self.N

        assert sched_sim.formed == sched_eng.formed
        assert [(b.low, b.up) for b in sched_sim.buckets.buckets] == \
               [(b.low, b.up) for b in sched_eng.buckets.buckets]

        # PR 8: the latency ledger extends the parity surface — wall
        # and virtual durations legitimately differ, but the phase
        # TRANSITION sequence is a pure function of the scheduling
        # decisions, so it must be identical; and conservation must
        # hold on both clocks for every request
        assert {r.rid: r.ledger.seq for r in res.requests} == \
               {r.rid: r.ledger.seq for r in eng.result.requests}
        for r in (*res.requests, *eng.result.requests):
            assert r.ledger.conserved(), (r.rid, r.ledger.residual())


class TestRequeueStats:
    """Re-queues (OOM evictions, slot clamps) must not double-count
    arrival statistics (the pre-refactor double-increment bug)."""

    def test_monitor_requeue_skips_workload_stats(self):
        m = GlobalMonitor()
        m.on_arrival(0.0, 100)
        m.on_requeue()
        assert m.queue_len == 2            # occupancy restored
        assert len(m.arrivals) == 1        # rate window NOT re-counted
        assert len(m.seq_lens) == 1        # seq-len stats NOT re-counted

    def test_scheduler_requeue_path(self):
        budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                              weight_bytes=0)
        sched = BucketServeScheduler(CFG, budget, SchedulerConfig())
        r = Request(rid=0, prompt_len=64, max_new_tokens=8, arrival=0.0)
        sched.on_arrival(r, 0.0)
        batch = sched.next_prefill_batch(0.0)
        assert batch is not None and batch.requests == [r]
        sched.on_arrival(r, 1.0, requeue=True)
        assert sched.queued() == 1
        assert sched.monitor.queue_len == 1
        assert len(sched.monitor.arrivals) == 1      # not double-counted
        assert len(sched.monitor.seq_lens) == 1

    def test_engine_slot_clamp_requeues_without_double_count(self):
        """Batch larger than free slots: the excess re-queues and still
        gets served, with arrival stats counted exactly once."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=128)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                              weight_bytes=0)
        # scheduler may form batches of 8; the engine only has 3 slots
        sched = BucketServeScheduler(cfg, budget,
                                     SchedulerConfig(max_batch=8))
        eng = ServingEngine(cfg, params, sched, max_slots=3, cache_len=128)
        rng = np.random.default_rng(2)
        reqs = [Request(rid=i, prompt_len=int(rng.integers(8, 60)),
                        max_new_tokens=3, arrival=0.0,
                        task_type=TaskType.OFFLINE) for i in range(8)]
        eng.submit(reqs)
        done = eng.run(max_wall_s=300)
        assert len(done) == 8
        assert len(sched.monitor.seq_lens) == 8      # once per request
        assert sched.monitor.queue_len == 0


# ------------------------------------------------- trace round trip ----
from repro.data.trace import TraceRecorder, TraceWorkload     # noqa: E402
from repro.data.workload import DEFAULT_CLASS_MIX             # noqa: E402


class TestTraceRoundTrip:
    """Satellite of PR 7, extending the parity suite: serve a
    heterogeneous trace (class mix + shared prefixes + multi-turn
    sessions) on the cost-model backend with the recorder attached,
    then replay the written trace into BOTH backends.  The sim replay
    must be fully bit-identical (formed-batch log, prompt token ids,
    cache-hit counters, per-request timings); the engine replay must
    make the SAME scheduling decisions (formed batches, prompt ids,
    session/prefix hit counts) — i.e. the trace file carries enough to
    reproduce a run on either substrate."""

    SLOTS = 4
    PAGE = 16

    def _sched(self, cfg):
        budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                              weight_bytes=0)
        return _RecordingScheduler(cfg, budget, SchedulerConfig(
            max_batch=self.SLOTS, memory_model="paged",
            page_size=self.PAGE))

    def _sim(self, cfg, recorder=None):
        sched = self._sched(cfg)
        sim = Simulator(sched, CostModel(cfg, A100X4), mode="disagg",
                        decode_slot_cap=self.SLOTS, paged=True,
                        page_size=self.PAGE,
                        kv_pool_tokens=256 * self.PAGE,
                        cache_len=cfg.max_seq_len, prefix_cache=True,
                        session_ttl=1000.0, recorder=recorder)
        return sched, sim

    def _workload(self, cfg):
        spec = WorkloadSpec(rps=1e6, n_requests=10, seed=23,
                            max_model_len=cfg.max_seq_len,
                            vocab_size=cfg.vocab_size,
                            class_mix=DEFAULT_CLASS_MIX, burst_factor=4.0,
                            prefix_groups=2, prefix_tokens=2 * self.PAGE,
                            sessions=1, turns=2, think_time_s=0.0)
        reqs = generate(spec)
        for r in reqs:      # deep queue: identical first ticks on wall
            r.arrival = 0.0  # and virtual clocks (cf. TestBackendParity)
            # a turn unlocks at (previous turn's finish + think_gap) on
            # the backend's OWN clock; a generous gap parks it after the
            # initial queue drains on both the wall and virtual clocks,
            # so its batch lands at the same point in both logs
            r.think_gap = 8.0 if r.turn > 0 else 0.0
            r.max_new_tokens = min(r.max_new_tokens, 4)
            # moderate lengths: near-window prompts make slot-clamp
            # requeues land at different (wall vs virtual) instants,
            # which is engine-timing variance, not a trace property
            if r.tokens is not None:
                r.prompt_len = min(r.prompt_len, 120)
                r.tokens = r.tokens[:r.prompt_len]
            if r.utterance is not None:
                r.utterance = r.utterance[:64]
        # the max_new clamp shrinks each turn's generated span, so the
        # precomputed transcript lengths of later turns must shrink too
        by_turn = {(r.session_id, r.turn): r for r in reqs
                   if r.session_id is not None}
        for (sid, t), r in sorted(by_turn.items()):
            if t == 0:
                continue
            prev = by_turn[(sid, t - 1)]
            r.history_tokens = prev.prompt_len + prev.max_new_tokens
            r.prompt_len = r.history_tokens + len(r.utterance)
            assert r.prompt_len < cfg.max_seq_len
        return reqs

    @staticmethod
    def _prompt_ids(res):
        return {r.rid: (None if r.tokens is None else r.tokens.tobytes())
                for r in res.requests if r.turn == 0}

    @staticmethod
    def _hits(res):
        return (res.prefix_lookups, res.prefix_hits, res.prefix_hit_tokens,
                res.session_lookups, res.session_hits,
                res.session_hit_tokens)

    def test_replay_into_both_backends(self, tmp_path):
        # 512-token window: heterogeneous prompts leave room for the
        # session transcripts to grow (and so be reused) across turns
        cfg = get_smoke_config("qwen3-14b", max_seq_len=512)
        reqs = self._workload(cfg)
        n = len(reqs)

        # original run, recorder attached
        rec = TraceRecorder()
        sched0, sim0 = self._sim(cfg, recorder=rec)
        res0 = sim0.run(reqs)
        assert len(res0.finished()) == n
        assert res0.prefix_hits > 0 and res0.session_hits > 0
        path = str(tmp_path / "round.jsonl")
        rec.save(path)

        # replay -> cost-model backend: full bit-identity
        tw = TraceWorkload(path)
        assert len(tw) == n
        rec1 = TraceRecorder()
        sched1, sim1 = self._sim(cfg, recorder=rec1)
        res1 = sim1.run(tw.requests())
        assert rec1.batch_log == rec.batch_log
        assert sched1.formed == sched0.formed
        assert self._prompt_ids(res1) == self._prompt_ids(res0)
        assert self._hits(res1) == self._hits(res0)
        assert sorted((r.rid, r.finished, r.first_token, r.generated)
                      for r in res1.requests) == \
               sorted((r.rid, r.finished, r.first_token, r.generated)
                      for r in res0.requests)
        # PR 8: ledgers are EXACTLY identical on a bit-identical replay
        # — same stamps, same phases, same transitions — and conserved
        assert {r.rid: (r.ledger.seq, r.ledger.phases)
                for r in res1.requests} == \
               {r.rid: (r.ledger.seq, r.ledger.phases)
                for r in res0.requests}
        for r in res0.requests:
            assert r.ledger.conserved(), (r.rid, r.ledger.residual())

        # replay -> jax engine backend: same scheduling decisions
        sched2 = self._sched(cfg)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        # the fused engine shares its slots between prefill admission
        # and live decodes; 3x the prefill batch cap keeps its slot
        # clamp from firing (the disagg sim gives prefill its own 4)
        eng = ServingEngine(cfg, params, sched2,
                            max_slots=3 * self.SLOTS,
                            cache_len=cfg.max_seq_len, paged=True,
                            page_size=self.PAGE,
                            kv_pool_tokens=256 * self.PAGE,
                            prefix_cache=True, session_ttl=1000.0)
        eng.submit(tw.requests())
        assert len(eng.run(max_wall_s=300)) == n
        assert sched2.formed == sched0.formed
        assert self._prompt_ids(eng.result) == self._prompt_ids(res0)
        assert self._hits(eng.result) == self._hits(res0)
        # PR 8: wall-clock durations differ, but every engine ledger
        # still conserves on its own clock
        for r in eng.result.requests:
            assert r.ledger.conserved(), (r.rid, r.ledger.residual())
