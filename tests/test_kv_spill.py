"""Host-RAM KV spill tier (PR 5, DESIGN.md §3 "Host spill tier").

The tentpole claims under test:

* eviction is no longer (only) destructive: each retention rung —
  expired session tails, LRU cold radix prefixes, live session tails —
  SPILLS its victim to a host pool before it would drop it, and drops
  only when the host budget is also exhausted (host-side LRU);
* a lookup whose hit continues into spilled pages initiates a
  host->device RESTORE and the request is HELD (``Request.spill_wait``)
  instead of being admitted to re-prefill restorable KV; the restore
  latency lands on that request's TTFT;
* restored pages are BIT-IDENTICAL to what was spilled: on the
  sessions x turns workload with a pool tight enough that PR 4 unpins
  live sessions, the --kv-spill run produces token ids equal to the
  no-spill run while turns >= 2 prefill >= 40% fewer prompt tokens
  than the unpin baseline under the same HBM budget;
* engine and cost-model backends agree on formed batches, spill and
  restore counts, and session hit counts (backend parity extends to
  spill decisions);
* satellites: one shared ``maintain`` path drives TTL expiry and copy
  completion identically in both backends; ``decode_preempt`` uploads
  block tables incrementally (O(new pages), regression-tested against
  the full-rescan reference).
"""
import math

import numpy as np
import pytest

from repro.core.paging import BlockAllocator, admit_blocks, extend_for_decode
from repro.core.request import Request, TaskType
from repro.core.retention import KvRetention, maintain_backend

PAGE = 8


def _req(rid, plen=10, mnt=4, arrival=0.0, sid=None, turn=0):
    return Request(rid=rid, prompt_len=plen, max_new_tokens=mnt,
                   arrival=arrival, session_id=sid, turn=turn)


def _toks(seed, n):
    return np.random.default_rng(seed).integers(0, 1000, n).astype(np.int32)


def _release(rt, a, req, path, now=0.0):
    req.generated = max(req.generated, 1)
    rt.on_release(a, req, path, now)


def _rt(a, *, ttl=1000.0, host=8, sec=0.5):
    return KvRetention(PAGE, session_ttl=ttl, host_pool_pages=host,
                       spill_seconds_per_page=sec)


class _RecordingCopier:
    """Protocol double: records the byte-movement calls the backend
    copier would receive, so unit tests can assert dispatch order."""

    def __init__(self):
        self.events = []

    def spill(self, page, hslot):
        self.events.append(("spill", page, hslot))

    def restore(self, hslot, page):
        self.events.append(("restore", hslot, page))

    def drop(self, hslot):
        self.events.append(("drop", hslot))

    def poll(self):
        pass


# --------------------------------------------------------- allocator unit --
class TestAllocatorSpill:
    def test_spill_frees_device_and_occupies_host(self):
        a = BlockAllocator(n_pages=4, page_size=PAGE, host_pages=2)
        t = a.alloc(0, PAGE)
        a.pin(t[0])
        a.release(0)                        # pin is now the last ref
        h = a.spill(t[0])
        assert h is not None
        assert a.free_pages() == 4 and a.live_pages() == 0
        assert a.spilled_slots() == 1 and a.free_host_slots() == 1
        # combined accounting: free + unique-live + spilled == accounted
        assert (a.free_pages() + a.live_pages() == a.n_pages
                and a.free_host_slots() + a.spilled_slots() == a.host_pages)

    def test_spill_refused_while_referenced(self):
        """A page in any live block table must never spill — the sharer
        would read freed HBM."""
        a = BlockAllocator(n_pages=4, page_size=PAGE, host_pages=2)
        t = a.alloc(0, PAGE)
        a.pin(t[0])                          # cache pin + table ref
        assert a.spill(t[0]) is None
        assert a.spilled_slots() == 0 and a.refs(t[0]) == 2

    def test_spill_refused_when_host_full(self):
        a = BlockAllocator(n_pages=4, page_size=PAGE, host_pages=1)
        t0 = a.alloc(0, PAGE)
        t1 = a.alloc(1, PAGE)
        a.pin(t0[0])
        a.pin(t1[0])
        a.release(0)
        a.release(1)
        assert a.spill(t0[0]) is not None
        assert a.spill(t1[0]) is None        # host pool exhausted
        assert a.refs(t1[0]) == 1            # untouched

    def test_restore_roundtrip_and_idempotence(self):
        a = BlockAllocator(n_pages=2, page_size=PAGE, host_pages=1)
        t = a.alloc(0, PAGE)
        a.pin(t[0])
        a.release(0)
        h = a.spill(t[0])
        p1 = a.restore_begin(h)
        assert p1 is not None and a.refs(p1) == 1
        assert a.restore_begin(h) == p1      # idempotent begin
        assert a.spilled_slots() == 1        # slot held until commit
        assert a.restore_commit(h) is True
        assert a.restore_commit(h) is False  # idempotent commit
        assert a.free_host_slots() == 1
        assert a.unpin(p1) is True

    def test_drop_spilled_refused_mid_restore(self):
        a = BlockAllocator(n_pages=2, page_size=PAGE, host_pages=1)
        t = a.alloc(0, PAGE)
        a.pin(t[0])
        a.release(0)
        h = a.spill(t[0])
        a.restore_begin(h)
        assert a.drop_spilled(h) is False    # copy in flight
        a.restore_commit(h)


# --------------------------------------------------------- retention unit --
class TestSpillRungs:
    def test_pressure_spills_before_dropping(self):
        """Admission pressure on retained pages spills them (content
        survives on host) instead of destroying them."""
        a = BlockAllocator(n_pages=4, page_size=PAGE, host_pages=8)
        rt = _rt(a)
        r0 = _req(0, sid=1)
        p0 = _toks(0, 3 * PAGE + 2)
        a.alloc(0, 4 * PAGE)
        _release(rt, a, r0, p0, now=0.0)     # 3 full + tail retained
        cold = _req(1, plen=2 * PAGE - 1)
        cold.tokens = _toks(1, cold.prompt_len)
        assert admit_blocks(a, [cold], lambda r: r.prompt_len + 1,
                            cache=rt, tokens_of=lambda r: r.tokens) == 1
        assert rt.stats.pages_spilled >= 2
        assert rt.stats.spill_drops == 0
        assert rt.prefix.stats.evictions == 0        # nothing destroyed
        assert a.spilled_slots() == rt.stats.pages_spilled

    def test_decode_pressure_spills_before_preempting(self):
        """extend_for_decode: the spill rung frees pages so neither the
        retained session nor any live request is destroyed."""
        a = BlockAllocator(n_pages=4, page_size=PAGE, host_pages=8)
        rt = _rt(a)
        r0 = _req(0, sid=1)
        p0 = _toks(2, PAGE + 2)
        a.alloc(0, len(p0) + 1)
        _release(rt, a, r0, p0, now=0.0)     # 2 pages retained
        old = _req(1, plen=PAGE - 1, arrival=0.0)
        yng = _req(2, plen=PAGE - 1, arrival=1.0)
        a.alloc(1, PAGE)
        a.alloc(2, PAGE)
        assert a.free_pages() == 0
        old.generated = PAGE
        yng.generated = PAGE
        victims = extend_for_decode(
            a, [old, yng], lambda r: r.prompt_len + 1 + r.generated,
            cache=rt)
        assert victims == []
        assert rt.stats.pages_spilled == 2
        assert 1 in rt.sessions              # session still resumable
        assert rt.sessions[1].tail_hslot is not None

    def test_ttl_expiry_demotes_instead_of_dropping(self):
        a = BlockAllocator(n_pages=8, page_size=PAGE, host_pages=8)
        rt = KvRetention(PAGE, session_ttl=5.0, host_pool_pages=8,
                         spill_seconds_per_page=0.5)
        r0 = _req(0, sid=1)
        p0 = _toks(3, PAGE + 3)
        a.alloc(0, len(p0) + 1)
        _release(rt, a, r0, p0, now=0.0)
        freed = rt.tick(a, 6.0)              # past the TTL
        assert freed == 1                    # the tail's HBM came back
        e = rt.sessions[1]
        assert e.tail_hslot is not None and e.tail_page is None
        assert e.expires_at == math.inf      # host LRU owns it now
        assert rt.stats.sessions_expired == 0
        assert rt.stats.pages_spilled == 1

    def test_host_exhaustion_falls_back_to_drop(self):
        """With a 0-page host pool the ladder degenerates to PR 4
        destructive eviction."""
        a = BlockAllocator(n_pages=4, page_size=PAGE, host_pages=0)
        rt = KvRetention(PAGE, session_ttl=1000.0)
        r0 = _req(0, sid=1)
        p0 = _toks(4, 3 * PAGE + 2)
        a.alloc(0, 4 * PAGE)
        _release(rt, a, r0, p0, now=0.0)
        cold = _req(1, plen=2 * PAGE - 1)
        cold.tokens = _toks(5, cold.prompt_len)
        assert admit_blocks(a, [cold], lambda r: r.prompt_len + 1,
                            cache=rt, tokens_of=lambda r: r.tokens) == 1
        assert rt.stats.pages_spilled == 0
        assert rt.prefix.stats.evictions >= 1

    def test_host_lru_drops_colder_for_warmer(self):
        """A full host pool makes room for a WARMER incoming spill by
        dropping its LRU entry — and refuses a colder incoming one."""
        a = BlockAllocator(n_pages=8, page_size=PAGE, host_pages=1)
        rt = _rt(a, host=1)
        # two single-page radix entries, distinct paths, no sessions
        for seed in (10, 11):
            r = _req(seed, sid=None)
            path = _toks(seed, PAGE)
            a.alloc(seed, PAGE + 1)
            _release(rt, a, r, path, now=0.0)
        # warm up the second path (later stamp)
        rt.lookup(np.concatenate([_toks(11, PAGE), _toks(99, 2)]), req=None,
                  alloc=a)
        spilled = rt.evict(a, 2)
        assert spilled == 2
        # one page spilled at rest, one destroyed along the way
        assert a.spilled_slots() == 1
        assert rt.stats.pages_spilled >= 1
        assert rt.stats.spill_drops + rt.prefix.stats.evictions >= 1


class TestRestoreHold:
    def _spilled_session(self, a, rt, seed=20, sid=7, now=0.0):
        r0 = _req(0, sid=sid)
        path = _toks(seed, 2 * PAGE + 5)
        a.alloc(0, len(path) + 1)
        _release(rt, a, r0, path, now=now)
        # pressure: spill everything retained
        need = a.n_pages - a.free_pages()
        rt.evict(a, need)
        assert a.free_pages() == a.n_pages
        assert rt.stats.pages_spilled == 3   # 2 full + tail
        return path

    def test_lookup_initiates_restore_and_holds(self):
        a = BlockAllocator(n_pages=4, page_size=PAGE, host_pages=8)
        rt = _rt(a, sec=0.5)
        cop = _RecordingCopier()
        rt.copier = cop
        path = self._spilled_session(a, rt)
        rt.tick(a, 1.0)
        r1 = _req(1, plen=len(path) + 6, sid=7, turn=1)
        r1.tokens = np.concatenate([path, _toks(21, 6)])
        n = admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                         cache=rt, tokens_of=lambda r: r.tokens)
        assert n == 0                            # HELD, not admitted
        assert r1.spill_wait == pytest.approx(1.0 + 3 * 0.5)
        assert rt.stats.restore_holds == 1
        assert r1.session_hit_tokens == 0        # no claim while held
        assert [e[0] for e in cop.events].count("restore") == 3
        # restores reserved device pages (pinned by the cache)
        assert a.free_pages() == 1
        # completion at the modeled time: pages flip LIVE
        rt.tick(a, r1.spill_wait)
        assert rt.stats.pages_restored == 3
        assert rt.stats.restored_tokens == len(path)
        assert rt.restores_in_flight() == 0
        e = rt.sessions[7]
        assert e.tail_hslot is None and e.tail_page is not None
        # the re-queued admission now takes the full session hit
        r1.spill_wait = -1.0
        n = admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                         cache=rt, tokens_of=lambda r: r.tokens)
        assert n == 1
        assert r1.session_hit_tokens == len(path)
        assert r1.prefix_hit_tokens == len(path)

    def test_second_holder_joins_inflight_restore(self):
        """A second request hitting a restoring path waits for the SAME
        transfer — no duplicate copies, no double restore."""
        a = BlockAllocator(n_pages=8, page_size=PAGE, host_pages=8)
        rt = _rt(a, sec=0.5)
        r0 = _req(0, sid=None)
        path = _toks(30, 2 * PAGE)
        a.alloc(0, len(path) + 1)
        _release(rt, a, r0, path, now=0.0)
        rt.evict(a, 2)                           # both pages spilled
        suffix = np.concatenate([path, _toks(31, 4)])
        r1, r2 = _req(1, plen=len(suffix)), _req(2, plen=len(suffix))
        r1.tokens = r2.tokens = suffix
        assert admit_blocks(a, [r1], lambda r: r.prompt_len + 1,
                            cache=rt, tokens_of=lambda r: r.tokens) == 0
        assert admit_blocks(a, [r2], lambda r: r.prompt_len + 1,
                            cache=rt, tokens_of=lambda r: r.tokens) == 0
        assert r2.spill_wait == r1.spill_wait
        assert rt.stats.pages_restored == 0
        rt.tick(a, r1.spill_wait)
        assert rt.stats.pages_restored == 2      # one transfer, not two

    def test_register_revives_spilled_nodes(self):
        """A re-prefill over a spilled path re-materializes the same
        KV (pure function of the token path) — register adopts the
        fresh pages and the host copies are discarded for free."""
        a = BlockAllocator(n_pages=8, page_size=PAGE, host_pages=8)
        rt = _rt(a)
        r0 = _req(0, sid=None)
        path = _toks(40, 2 * PAGE)
        a.alloc(0, len(path) + 1)
        _release(rt, a, r0, path, now=0.0)
        rt.evict(a, 2)
        assert rt.prefix.spilled_nodes() == 2
        # a cold duplicate re-prefilled the same path
        t = a.alloc(1, 2 * PAGE)
        rt.prefix.register(a, path, t)
        assert rt.prefix.spilled_nodes() == 0
        assert a.spilled_slots() == 0            # host slots returned
        assert rt.stats.spill_drops == 0         # revive, not destruction
        pages, hit = rt.prefix.lookup(np.concatenate([path, _toks(41, 2)]))
        assert hit == 2 * PAGE and pages == t[:2]


class TestMaintainParity:
    """Satellite: ONE shared maintain path — TTL expiry and restore
    completion fire at the same clock times through either backend's
    ``maintain`` because both delegate to ``maintain_backend``."""

    class _Stub:
        paged = True

        def __init__(self, rt, alloc):
            self.retention = rt
            self.alloc = alloc

    def _drive(self, times):
        a = BlockAllocator(n_pages=4, page_size=PAGE, host_pages=8)
        rt = KvRetention(PAGE, session_ttl=5.0, host_pool_pages=8,
                         spill_seconds_per_page=0.25)
        be = self._Stub(rt, a)
        r0 = _req(0, sid=1)
        path = _toks(50, PAGE + 3)
        a.alloc(0, len(path) + 1)
        _release(rt, a, r0, path, now=0.0)
        events = []
        for t in times:
            maintain_backend(be, t)
            e = rt.sessions.get(1)
            events.append((t, rt.stats.pages_spilled,
                           rt.stats.pages_restored,
                           None if e is None else e.tail_hslot is not None))
        return events, rt, a

    def test_same_times_same_transitions(self):
        times = [1.0, 4.9, 5.0, 7.5]
        ev1, rt1, _ = self._drive(times)
        ev2, rt2, _ = self._drive(times)
        assert ev1 == ev2
        # demotion happened exactly at the 5.0 tick
        assert ev1[1][3] is False and ev1[2][3] is True

    def test_maintain_noops_without_paged_pool(self):
        class _NoPool:
            retention = None
            paged = False

        maintain_backend(_NoPool(), 1.0)     # must not raise


# ------------------------------------------- block-table mirror satellite --
class TestBlockTableMirrorIncremental:
    """Satellite: decode_preempt's block-table upload is O(new pages)
    per grown request.  Timing-free regression: drive the incremental
    mirror and the old full-rescan reference through the same
    alloc/extend/preempt churn — identical host tensors, with the
    incremental path writing only appended cells."""

    def _reference_sync(self, host, pool, slot_of, alloc, trash):
        """The pre-PR-5 formulation (engine.py decode_preempt): rescan
        every pooled request's full table per dispatch."""
        compares = 0
        for r in pool:
            slot = slot_of.get(r.rid)
            if slot is None:
                continue
            t = np.asarray(alloc.table(r.rid), np.int32)
            compares += len(t)
            if not np.array_equal(host[slot, :len(t)], t):
                host[slot, :len(t)] = t
        return compares

    def test_incremental_matches_reference_through_churn(self):
        from repro.core.engine import _BlockTableMirror
        rng = np.random.default_rng(0)
        n_slots, pages_per_seq, trash = 8, 32, 999
        alloc = BlockAllocator(n_pages=256, page_size=PAGE)
        mirror = _BlockTableMirror(n_slots, pages_per_seq, trash)
        ref = np.full((n_slots, pages_per_seq), trash, np.int32)
        pool, slot_of, free = [], {}, list(range(n_slots))
        rid, tokens = 0, {}
        ref_compares = 0
        for step in range(400):
            op = rng.random()
            if op < 0.3 and free:                     # admit
                r = _req(rid, plen=int(rng.integers(1, 10 * PAGE)))
                if alloc.alloc(r.rid, r.prompt_len + 1) is not None:
                    slot = free.pop()
                    slot_of[r.rid] = slot
                    t = alloc.table(r.rid)
                    ref[slot] = trash
                    ref[slot, :len(t)] = t
                    mirror.insert(slot, r.rid, t)
                    tokens[r.rid] = r.prompt_len + 1
                    pool.append(r)
                    rid += 1
            elif op < 0.8 and pool:                   # decode growth
                for r in pool:
                    tokens[r.rid] = min(tokens[r.rid]
                                        + int(rng.integers(0, 2 * PAGE)),
                                        pages_per_seq * PAGE)
                    alloc.extend(r.rid, tokens[r.rid])
            elif pool:                                # release
                r = pool.pop(int(rng.integers(len(pool))))
                alloc.release(r.rid)
                slot = slot_of.pop(r.rid)
                free.append(slot)
                ref[slot] = trash
                mirror.clear(slot, r.rid)
                tokens.pop(r.rid)
            # the per-dispatch sync both paths run
            ref_compares += self._reference_sync(ref, pool, slot_of,
                                                 alloc, trash)
            for r in pool:
                mirror.sync(slot_of[r.rid], r.rid, alloc)
            assert np.array_equal(mirror.host, ref)
        # O(new pages): the incremental path touched far fewer cells
        # than the reference rescanned (timing-free bound)
        assert mirror.writes < ref_compares / 4, \
            (mirror.writes, ref_compares)


# --------------------------------------------------- engine end to end ----
import jax                                                    # noqa: E402

from repro.configs import get_smoke_config                    # noqa: E402
from repro.core import (BucketServeScheduler, MemoryBudget,   # noqa: E402
                        SchedulerConfig)
from repro.core.engine import ServingEngine                   # noqa: E402
from repro.core.simulator import (A100X4, CostModel,          # noqa: E402
                                  Simulator)
from repro.data.workload import WorkloadSpec, generate        # noqa: E402
from repro.models import transformer as tfm                   # noqa: E402

BUDGET = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                      weight_bytes=0)
PAGE_E = 128
TIGHT_POOL = 12 * PAGE_E      # forces PR 4 to unpin live sessions


def _session_workload(cfg, *, sessions=3, turns=4, utter=200, out=8,
                      seed=7):
    spec = WorkloadSpec(dataset="alpaca", rps=1e6, sessions=sessions,
                        turns=turns, utterance_tokens=utter,
                        max_new_tokens=out, seed=seed,
                        task_type=TaskType.OFFLINE,
                        max_model_len=cfg.max_seq_len,
                        vocab_size=cfg.vocab_size)
    return generate(spec)


def _engine(cfg, params, *, host_pool_tokens=None, slots=4,
            pool_tokens=TIGHT_POOL, session_ttl=1000.0, spill_dtype=""):
    sched = BucketServeScheduler(cfg, BUDGET, SchedulerConfig(
        max_batch=slots, memory_model="paged", page_size=PAGE_E))
    return ServingEngine(cfg, params, sched, max_slots=slots,
                         cache_len=cfg.max_seq_len, paged=True,
                         page_size=PAGE_E, kv_pool_tokens=pool_tokens,
                         session_ttl=session_ttl,
                         host_pool_tokens=host_pool_tokens,
                         spill_dtype=spill_dtype)


class TestSpillEngineAcceptance:
    """Acceptance (ISSUE 5): sessions x turns workload, page 128, pool
    tight enough that PR 4 unpins live sessions — with the spill tier
    every request's token ids are bit-identical to the no-spill run,
    and turns >= 2 prefill >= 40% fewer prompt tokens than the unpin
    baseline under the SAME HBM budget."""

    def _run(self, cfg, params, host_pool_tokens, **kw):
        reqs = _session_workload(cfg)
        eng = _engine(cfg, params, host_pool_tokens=host_pool_tokens, **kw)
        eng.submit(reqs)
        done = eng.run(max_wall_s=600)
        assert len(done) == len(reqs)
        return eng, reqs

    def test_bit_identical_and_40pct_fewer_prefill_than_unpin(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        outs, pre, res = {}, {}, {}
        for host in (None, 64 * PAGE_E):
            eng, reqs = self._run(cfg, params, host)
            outs[host] = {r.rid: eng.outputs[r.rid] for r in reqs}
            outs[host].update({(r.rid, "p"): r.tokens.tolist()
                               for r in reqs})
            pre[host] = {r.rid: (r.turn, r.prefilled_tokens) for r in reqs}
            res[host] = eng.result
            for r in reqs:
                assert len(eng.outputs[r.rid]) == r.max_new_tokens
            be = eng.backend
            assert be.alloc.free_pages() + be.alloc.live_pages() \
                == be.alloc.n_pages
            assert (be.alloc.free_host_slots() + be.alloc.spilled_slots()
                    == be.alloc.host_pages)
            be.retention.clear(be.alloc)
            assert be.alloc.free_pages() == be.alloc.n_pages
            assert be.alloc.spilled_slots() == 0

        # the tight pool really did force PR 4's destructive eviction
        unpin = res[None]
        assert (unpin.sessions_evicted + unpin.sessions_expired
                + unpin.prefix_evictions) > 0
        assert unpin.spilled_pages == 0
        # the spill tier replaced destruction with copies ...
        spill = res[64 * PAGE_E]
        assert spill.spilled_pages > 0
        assert spill.restored_pages > 0
        assert spill.restored_tokens > 0
        # ... bit-identically ...
        assert outs[64 * PAGE_E] == outs[None]
        # ... and turns >= 2 re-prefill >= 40% fewer prompt tokens
        unpin_t2 = sum(p for t, p in pre[None].values() if t >= 1)
        spill_t2 = sum(p for t, p in pre[64 * PAGE_E].values() if t >= 1)
        assert spill_t2 <= 0.6 * unpin_t2, (spill_t2, unpin_t2)

    def test_restore_latency_lands_on_ttft(self):
        """Held turns pay the restore wait in their TTFT (arrival is
        not reset when the parked request re-enters the queue)."""
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        eng, reqs = self._run(cfg, params, 64 * PAGE_E)
        r = eng.result
        assert r.spill_hold_events > 0
        for q in reqs:
            assert q.first_token >= q.arrival
            assert q.ttft() < math.inf


def _record_dispatched(backend, log):
    """Record the composition of every batch that actually DISPATCHES
    (reaches prefill_chunk 0, i.e. survived the slot and KV-page
    admission clamps).  Formation ATTEMPTS are not comparable across
    backends — a batch that fails admission is re-formed every
    scheduler tick until pages free, and tick cadence is a clock
    property (wall vs virtual), not a policy one."""
    orig = backend.prefill_chunk

    def rec(job, idx, _orig=orig, _log=log):
        if idx == 0:
            _log.append(tuple(r.rid for r in job.batch.requests))
        return _orig(job, idx)

    backend.prefill_chunk = rec


class TestSpillBackendParity:
    """Engine vs cost model under the spill tier: identical dispatched
    batches, spill/restore counts and session hit counts — the spill
    DECISIONS live in the shared retention layer, the backends only
    move/price bytes."""

    SLOTS = 4
    POOL = 10 * PAGE_E

    def _sched(self, cfg):
        return BucketServeScheduler(cfg, BUDGET, SchedulerConfig(
            max_batch=self.SLOTS, memory_model="paged",
            page_size=PAGE_E))

    def _workload(self, cfg):
        reqs = _session_workload(cfg, sessions=2, turns=4, utter=220,
                                 out=4)
        for r in reqs:
            r.arrival = 0.0
        return reqs

    @pytest.mark.parametrize("spill_dtype", ["bf16", "int4"])
    def test_same_batches_and_spill_counts(self, spill_dtype):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        host = 64 * PAGE_E
        n = 8

        sim = Simulator(self._sched(cfg), CostModel(cfg, A100X4),
                        mode="disagg",
                        decode_slot_cap=self.SLOTS, paged=True,
                        page_size=PAGE_E, kv_pool_tokens=self.POOL,
                        cache_len=cfg.max_seq_len, session_ttl=1000.0,
                        host_pool_tokens=host, spill_dtype=spill_dtype)
        disp_sim = []
        _record_dispatched(sim.backend, disp_sim)
        res_sim = sim.run(self._workload(cfg))
        assert len(res_sim.finished()) == n

        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, self._sched(cfg),
                            max_slots=self.SLOTS,
                            cache_len=cfg.max_seq_len, paged=True,
                            page_size=PAGE_E, kv_pool_tokens=self.POOL,
                            session_ttl=1000.0, host_pool_tokens=host,
                            spill_dtype=spill_dtype)
        disp_eng = []
        _record_dispatched(eng.backend, disp_eng)
        eng.submit(self._workload(cfg))
        assert len(eng.run(max_wall_s=300)) == n
        res_eng = eng.result

        assert disp_sim == disp_eng
        assert res_sim.spilled_pages == res_eng.spilled_pages > 0
        assert res_sim.restored_pages == res_eng.restored_pages > 0
        assert res_sim.restored_tokens == res_eng.restored_tokens > 0
        assert res_sim.spill_drops == res_eng.spill_drops
        assert res_sim.spill_hold_events == res_eng.spill_hold_events > 0
        assert res_sim.session_lookups == res_eng.session_lookups > 0
        assert res_sim.session_hits == res_eng.session_hits > 0
        assert res_sim.session_hit_tokens == res_eng.session_hit_tokens
        assert res_sim.prefill_tokens_skipped \
            == res_eng.prefill_tokens_skipped > 0
        # quantized-tier parity: both backends price the SAME
        # compressed bytes and the SAME modeled restore time
        assert res_sim.spilled_bytes == res_eng.spilled_bytes > 0
        assert res_sim.restored_bytes == res_eng.restored_bytes > 0
        assert res_sim.restore_time_total \
            == pytest.approx(res_eng.restore_time_total)
        if spill_dtype == "int4":
            # compressed slots: strictly fewer bytes than the bf16
            # hot-tier footprint of the same pages
            hot = res_eng.spilled_pages * PAGE_E \
                * cfg.cache_bytes_per_token()
            assert res_eng.spilled_bytes < hot / 2


class TestQuantizedSpillEngine:
    """Tentpole bit-accuracy story, engine end to end.

    * int8 POOL + int8 SPILL: spilled pages hold the pool's own int8
      codes (pass-through, no requantization), so restore is LOSSLESS
      and outputs are bit-identical to the same pool without a spill
      tier.
    * int4 SPILL of a bf16 pool: lossy, but scheduling must be
      UNCHANGED vs the bf16-spill run under the same budget — the
      compressed tier only moves fewer bytes, it does not change which
      batches dispatch."""

    HOST = 64 * PAGE_E

    def _run(self, cfg, params, host, disp=None, **kw):
        reqs = _session_workload(cfg)
        eng = _engine(cfg, params, host_pool_tokens=host, **kw)
        if disp is not None:
            _record_dispatched(eng.backend, disp)
        eng.submit(reqs)
        assert len(eng.run(max_wall_s=600)) == len(reqs)
        outs = {r.rid: eng.outputs[r.rid] for r in reqs}
        outs.update({(r.rid, "p"): r.tokens.tolist() for r in reqs})
        return eng, outs

    def test_int8_pool_spill_restore_lossless(self):
        # int8 halves the page cost, so a ~equally tight pool needs a
        # ~halved byte budget (12 int8 pages here vs TIGHT_POOL's 11)
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024,
                               kv_cache_dtype="int8")
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        pool = 7 * PAGE_E
        _, base = self._run(cfg, params, None, pool_tokens=pool)
        eng, spill = self._run(cfg, params, self.HOST, pool_tokens=pool,
                               spill_dtype="int8")
        assert eng.result.spilled_pages > 0
        assert eng.result.restored_pages > 0
        assert spill == base                 # token ids bit-identical

    def test_int4_spill_leaves_dispatch_unchanged(self):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        disp, res = {}, {}
        for dt in ("bf16", "int4"):
            disp[dt] = []
            eng, _ = self._run(cfg, params, self.HOST, disp=disp[dt],
                               spill_dtype=dt)
            res[dt] = eng.result
        assert res["bf16"].spilled_pages > 0
        assert disp["int4"] == disp["bf16"]  # same batches dispatched
        assert res["int4"].spilled_pages == res["bf16"].spilled_pages
        assert res["int4"].restored_pages == res["bf16"].restored_pages
        assert res["int4"].spilled_bytes < res["bf16"].spilled_bytes / 2
        assert res["int4"].restore_time_total \
            < res["bf16"].restore_time_total


class TestInt4LogitDrift:
    """Documented int4 accuracy bound (DESIGN.md §3 "Tier precision"):
    round-tripping a prefilled KV cache through the spill tier's int4
    quantizer perturbs next-token logits by < 1.5 on the smoke config
    (observed ~0.7 with random weights, logit scale ~3)."""

    def test_roundtrip_logit_delta_bounded(self):
        import numpy as _np

        from repro.models.attention import (dequantize_kv_int4,
                                            quantize_kv_int4)

        cfg = get_smoke_config("qwen3-14b")
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0,
                                 cfg.vocab_size)
        l1, c1 = tfm.prefill(cfg, params, tokens=tok, cache_len=40)

        def roundtrip(lay):
            out = dict(lay)
            for k in ("k", "v"):
                x = _np.asarray(lay[k], _np.float32)
                packed, sc = quantize_kv_int4(x)
                import jax.numpy as jnp
                out[k] = jnp.asarray(
                    dequantize_kv_int4(packed, sc, x.shape[-1])
                ).astype(lay[k].dtype)
            return out

        c2 = dict(c1)
        c2["groups"] = [[roundtrip(lay) for lay in g]
                        for g in c1["groups"]]
        nt = l1.argmax(-1)
        l1b, _ = tfm.decode_step(cfg, params, nt, c1)
        l2b, _ = tfm.decode_step(cfg, params, nt, c2)
        import jax.numpy as jnp
        delta = float(jnp.max(jnp.abs(l1b - l2b)))
        assert delta < 1.5, delta
        assert bool(jnp.isfinite(l2b).all())


class TestRestoreAwareAdmission:
    """Satellite: Eq.-(6) admission prices in-flight restore traffic —
    reserved device pages plus the COMPRESSED byte backlog on the PCIe
    channel, converted through Eq. (6)'s own kv-bytes denominator."""

    def _sched(self, model="paged"):
        cfg = get_smoke_config("qwen3-14b", max_seq_len=1024)
        return BucketServeScheduler(cfg, BUDGET, SchedulerConfig(
            max_batch=4, memory_model=model, page_size=PAGE_E))

    def test_pressure_terms(self):
        b = self._sched().batcher
        # device term: pages reserved by restore_begin
        assert b.admission_pressure_tokens(2, 0) == 2 * PAGE_E
        # channel term: compressed bytes through the Eq.-(6) denominator
        assert b.admission_pressure_tokens(0, 5 * b.kv_per_tok) == 5
        assert b.admission_pressure_tokens(2, 5 * b.kv_per_tok) \
            == 2 * PAGE_E + 5

    def test_sum_model_prices_backlog_only(self):
        b = self._sched("sum").batcher
        # no paged pool: reservations aren't device pages, only the
        # channel backlog is real occupancy-to-be
        assert b.admission_pressure_tokens(2, 0) == 0
        assert b.admission_pressure_tokens(2, 3 * b.kv_per_tok) == 3

    def test_monitor_levels_throttle_n_max(self):
        s = self._sched()
        base = s._n_max()
        assert base > 1
        # a huge compressed backlog throttles admission ...
        s.monitor.on_restore_state(4, 10 ** 9 * s.batcher.kv_per_tok)
        assert s._pressure_tokens() > 10 ** 9
        assert s._n_max() < base
        # ... and the monitor holds LEVELS, not counters: the next
        # maintain tick with a drained channel clears the pressure
        s.monitor.on_restore_state(0, 0)
        assert s._pressure_tokens() == 0
        assert s._n_max() == base
