"""int8 KV-cache serving variant (beyond-paper): accuracy + mechanics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property-based invariants need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as tfm
from repro.models.attention import dequantize_kv, quantize_kv


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4), st.floats(0.01, 100.0))
def test_quant_roundtrip_error_bound(s, h, scale):
    key = jax.random.PRNGKey(s * 7 + h)
    x = jax.random.normal(key, (2, s, h, 16)) * scale
    q, sc = quantize_kv(x)
    back = dequantize_kv(q, sc)
    # symmetric int8: per-row error <= scale/127 * 0.5 quantization step
    err = jnp.abs(back - x)
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 * 0.51
    assert bool((err <= bound + 1e-6).all())
    assert q.dtype == jnp.int8


@pytest.mark.parametrize("arch", ["qwen3-14b", "yi-6b"])
def test_int8_decode_tracks_bf16(arch):
    cfg = get_smoke_config(arch)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                             cfg.vocab_size)
    l1, c1 = tfm.prefill(cfg, params, tokens=tok, cache_len=40)
    l2, c2 = tfm.prefill(cfg8, params, tokens=tok, cache_len=40)
    assert c2["groups"][0][0]["k"].dtype == jnp.int8
    assert "k_s" in c2["groups"][0][0]
    agree = 0
    for _ in range(6):
        nt1 = l1.argmax(-1).astype(jnp.int32)
        nt2 = l2.argmax(-1).astype(jnp.int32)
        agree += int((nt1 == nt2).all())
        l1, c1 = tfm.decode_step(cfg, params, nt1, c1)
        l2, c2 = tfm.decode_step(cfg8, params, nt2, c2)
    assert agree >= 5          # greedy tokens match (tiny drift tolerated)


def test_int8_with_sliding_window_ring():
    cfg = get_smoke_config("yi-6b", sliding_window=16,
                           kv_cache_dtype="int8")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0,
                             cfg.vocab_size)
    logits, cache = tfm.prefill(cfg, params, tokens=tok, cache_len=64)
    assert cache["groups"][0][0]["k"].shape[2] == 16    # ring-sized
    for _ in range(8):                                   # wraps the ring
        nt = logits.argmax(-1).astype(jnp.int32)
        logits, cache = tfm.decode_step(cfg, params, nt, cache)
        assert bool(jnp.isfinite(logits).all())


def test_variant_registry():
    cfg = get_config("yi-6b", variant="swa+int8")
    assert cfg.sliding_window > 0 and cfg.kv_cache_dtype == "int8"
    assert cfg.name.endswith("+swa+int8")
