"""int8 KV-cache serving variant (beyond-paper): accuracy + mechanics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property-based invariants need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as tfm
from repro.models.attention import (dequantize_kv, dequantize_kv_int4,
                                    pack_int4, quantize_kv,
                                    quantize_kv_int4, unpack_int4)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4), st.floats(0.01, 100.0))
def test_quant_roundtrip_error_bound(s, h, scale):
    key = jax.random.PRNGKey(s * 7 + h)
    x = jax.random.normal(key, (2, s, h, 16)) * scale
    q, sc = quantize_kv(x)
    back = dequantize_kv(q, sc)
    # symmetric int8: per-row error <= scale/127 * 0.5 quantization step
    err = jnp.abs(back - x)
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 * 0.51
    assert bool((err <= bound + 1e-6).all())
    assert q.dtype == jnp.int8


@pytest.mark.parametrize("arch", ["qwen3-14b", "yi-6b"])
def test_int8_decode_tracks_bf16(arch):
    cfg = get_smoke_config(arch)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                             cfg.vocab_size)
    l1, c1 = tfm.prefill(cfg, params, tokens=tok, cache_len=40)
    l2, c2 = tfm.prefill(cfg8, params, tokens=tok, cache_len=40)
    assert c2["groups"][0][0]["k"].dtype == jnp.int8
    assert "k_s" in c2["groups"][0][0]
    agree = 0
    for _ in range(6):
        nt1 = l1.argmax(-1).astype(jnp.int32)
        nt2 = l2.argmax(-1).astype(jnp.int32)
        agree += int((nt1 == nt2).all())
        l1, c1 = tfm.decode_step(cfg, params, nt1, c1)
        l2, c2 = tfm.decode_step(cfg8, params, nt2, c2)
    assert agree >= 5          # greedy tokens match (tiny drift tolerated)


def test_int8_with_sliding_window_ring():
    cfg = get_smoke_config("yi-6b", sliding_window=16,
                           kv_cache_dtype="int8")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0,
                             cfg.vocab_size)
    logits, cache = tfm.prefill(cfg, params, tokens=tok, cache_len=64)
    assert cache["groups"][0][0]["k"].shape[2] == 16    # ring-sized
    for _ in range(8):                                   # wraps the ring
        nt = logits.argmax(-1).astype(jnp.int32)
        logits, cache = tfm.decode_step(cfg, params, nt, cache)
        assert bool(jnp.isfinite(logits).all())


# ------------------------------------------- int4 spill-tier compression --
@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 17), st.integers(1, 5))
def test_int4_pack_unpack_roundtrip(seed, n, rows):
    """Exact nibble roundtrip over the full signed 4-bit range,
    including -8 and ODD last-axis lengths (zero-padded tail)."""
    q = np.random.default_rng(seed).integers(
        -8, 8, (rows, n)).astype(np.int8)
    p = pack_int4(q)
    assert p.dtype == np.uint8 and p.shape == (rows, (n + 1) // 2)
    assert np.array_equal(unpack_int4(p, n), q)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4), st.integers(1, 19),
       st.floats(0.01, 100.0))
def test_int4_quant_roundtrip_error_bound(s, h, d, scale):
    """Symmetric int4: per-row error <= scale/7 * 0.5 quantization
    step; one f32 scale per (token, head) row (broadcasting), packed
    payload is ceil(Dh/2) bytes (odd page tails)."""
    rng = np.random.default_rng(s * 31 + h * 7 + d)
    x = (rng.standard_normal((2, s, h, d)) * scale).astype(np.float32)
    packed, sc = quantize_kv_int4(x)
    assert sc.shape == x.shape[:-1] and sc.dtype == np.float32
    assert packed.shape == x.shape[:-1] + ((d + 1) // 2,)
    back = dequantize_kv_int4(packed, sc, d)
    bound = np.abs(x).max(axis=-1, keepdims=True) / 7.0 * 0.51
    assert (np.abs(back - x) <= bound + 1e-6).all()


def test_int4_dequantize_target_dtype():
    x = np.linspace(-3.0, 3.0, 32, dtype=np.float32).reshape(2, 16)
    packed, sc = quantize_kv_int4(x)
    back = dequantize_kv_int4(packed, sc, 16, dtype=np.float16)
    assert back.dtype == np.float16 and back.shape == x.shape


def test_spill_bytes_per_token_ladder():
    """Tier precision is a BYTE property of the config: int8 roughly
    halves and int4 roughly quarters the per-token spill footprint
    (per-page f32 scale planes included), and bf16 spill is exactly
    the hot-tier cache footprint."""
    cfg = get_config("llama2-13b")
    bf16 = cfg.spill_bytes_per_token("")
    i8 = cfg.spill_bytes_per_token("int8")
    i4 = cfg.spill_bytes_per_token("int4")
    assert bf16 == cfg.cache_bytes_per_token()
    assert bf16 == cfg.spill_bytes_per_token("bf16")
    assert i4 < i8 < bf16
    assert i8 <= 0.55 * bf16          # ~2x incl. scale overhead
    assert i4 <= 0.30 * bf16          # ~4x incl. scale overhead
    with pytest.raises(ValueError):
        cfg.spill_bytes_per_token("fp8")


def test_variant_registry():
    cfg = get_config("yi-6b", variant="swa+int8")
    assert cfg.sliding_window > 0 and cfg.kv_cache_dtype == "int8"
    assert cfg.name.endswith("+swa+int8")
