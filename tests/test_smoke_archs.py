"""Per-architecture smoke tests (reduced configs, CPU).

For each assigned architecture: instantiate the reduced same-family
variant (2 layers, d_model<=512, <=4 experts), run one forward and one
train step, assert output shapes and finiteness; run a short
prefill+decode and check it against the full-forward oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_smoke_config, list_archs
from repro.data import tokens as data_tokens
from repro.models import transformer as tfm
from repro.train import optimizer, train_loop

ALL = list(list_archs())


def _inputs(cfg, key, B, S):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.arch_type == "audio":
        kw["embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.02
        tok = None
    if cfg.arch_type == "vlm":
        kw["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_vision)) * 0.02
    return tok, kw


def _moe_impl(cfg):
    return "ref" if cfg.n_experts else "local"


@pytest.mark.parametrize("arch", ALL)
def test_forward_smoke(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    B, S = 2, 40
    tok, kw = _inputs(cfg, key, B, S)
    logits = tfm.forward(cfg, params, tokens=tok, moe_impl=_moe_impl(cfg), **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    opt_cfg = optimizer.AdamWConfig(lr=1e-3, total_steps=10)
    opt_state = optimizer.init(params)
    step = jax.jit(train_loop.make_train_step(cfg, opt_cfg,
                                              moe_impl=_moe_impl(cfg)))
    batch = next(data_tokens.batches(cfg, batch_size=2, seq_len=32))
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ALL
                                  if get_config(a).has_decode])
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    B, S = 2, 24
    lengths = jnp.array([16, 12], jnp.int32)
    tok, kw = _inputs(cfg, key, B, S)
    mi = _moe_impl(cfg)
    full = tfm.forward(cfg, params, tokens=tok, moe_impl=mi, **kw)
    prompt = tok[:, :16]
    logits, cache = tfm.prefill(cfg, params, tokens=prompt, lengths=lengths,
                                cache_len=S + 4, moe_impl=mi, **kw)
    for b in range(B):
        np.testing.assert_allclose(logits[b], full[b, lengths[b] - 1],
                                   atol=2e-4, rtol=2e-3)
    for _ in range(4):
        next_tok = tok[jnp.arange(B), cache["pos"]]
        logits, cache = tfm.decode_step(cfg, params, next_tok, cache,
                                        moe_impl=mi)
        for b in range(B):
            np.testing.assert_allclose(logits[b], full[b, cache["pos"][b] - 1],
                                       atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-14b"])
def test_swa_variant_decode(arch):
    """Sliding-window serving variant: decode works past the window."""
    cfg = get_smoke_config(arch, sliding_window=16)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    B = 2
    lengths = jnp.array([20, 24], jnp.int32)
    tok = jax.random.randint(key, (B, 24), 0, cfg.vocab_size)
    logits, cache = tfm.prefill(cfg, params, tokens=tok, lengths=lengths,
                                cache_len=64)
    # cache must be window-sized, not seq-sized
    k0 = cache["groups"][0][0]["k"]
    assert k0.shape[2] == 16
    for _ in range(8):
        nt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, cache = tfm.decode_step(cfg, params, nt, cache)
        assert bool(jnp.isfinite(logits).all())


def test_encoder_has_no_decode():
    cfg = get_config("hubert-xlarge")
    assert not cfg.has_decode
    assert not cfg.subquadratic  # and it is excluded from decode shapes


def test_assigned_registry_complete():
    assert len(ASSIGNED) == 10
    families = {get_config(a).arch_type for a in ASSIGNED}
    assert families == {"dense", "ssm", "moe", "audio", "hybrid", "vlm"}
