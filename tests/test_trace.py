"""Trace schema, burst generator, and tail-percentile properties.

Plain seeded-rng randomization (no hypothesis dependency — the PR 5
container note): each property loops over a spread of generated cases,
so failures reproduce exactly from the printed seed.
"""
import json
import math

import numpy as np
import pytest

from repro.core.request import Request, TaskType
from repro.core.serving_loop import ServeResult
from repro.data import trace as tr
from repro.data.workload import (CLASS_SLOS, DEFAULT_CLASS_MIX,
                                 WorkloadSpec, envelope_fn, generate)


def _result(requests):
    return ServeResult(requests=requests, makespan=1.0, busy_prefill=0.0,
                       busy_decode=0.0, useful_flops=0.0, padded_flops=0.0,
                       oom_events=0, bucketing_overhead_s=0.0)


def _random_requests(rng, n):
    """A randomized but trace-legal stream: odd class tags, zero-output
    requests, sessions, sparse tokens — sorted by arrival."""
    arrivals = np.sort(rng.uniform(0.0, 50.0, n))
    reqs = []
    for i in range(n):
        cls = rng.choice(["chat", "longctx", "batch", ""])
        has_tokens = rng.random() < 0.5
        plen = int(rng.integers(1, 300))
        r = Request(
            rid=i, prompt_len=plen,
            max_new_tokens=int(rng.integers(0, 64)),  # zero-output legal
            arrival=float(arrivals[i]),
            task_type=TaskType.OFFLINE if cls == "batch"
            else TaskType.ONLINE,
            slo_ttft=float(rng.uniform(0.1, 100.0)),
            slo_tpot=float(rng.uniform(0.01, 5.0)),
            tokens=(rng.integers(0, 32000, plen).astype(np.int32)
                    if has_tokens else None),
            cls=str(cls))
        if rng.random() < 0.2:
            r.session_id = int(rng.integers(0, 5))
            r.turn = int(rng.integers(0, 4))
            r.think_gap = float(rng.uniform(0.0, 3.0))
            ul = int(rng.integers(1, 50))
            r.utterance = rng.integers(0, 32000, ul).astype(np.int32)
            if r.turn > 0:
                r.tokens = None
                r.history_tokens = int(rng.integers(0, 200))
        reqs.append(r)
    return reqs


def _key(r: Request):
    return (r.rid, r.prompt_len, r.max_new_tokens, r.arrival,
            r.task_type, r.slo_ttft, r.slo_tpot, r.cls, r.session_id,
            r.turn, r.think_gap, r.history_tokens,
            None if r.tokens is None else r.tokens.tobytes(),
            None if r.utterance is None else r.utterance.tobytes())


class TestTraceRoundTrip:
    def test_serialize_parse_identity_randomized(self, tmp_path):
        for seed in range(8):
            rng = np.random.default_rng(seed)
            reqs = _random_requests(rng, int(rng.integers(1, 60)))
            p = str(tmp_path / f"t{seed}.jsonl")
            tr.write_trace(p, reqs, meta={"seed": seed})
            header, back = tr.read_trace(p)
            assert header["meta"] == {"seed": seed}, f"seed {seed}"
            assert [_key(r) for r in back] == [_key(r) for r in reqs], \
                f"seed {seed}"
            # float arrivals and SLOs survive EXACTLY (json repr
            # round-trip), not approximately — replay depends on it
            assert [r.arrival for r in back] == [r.arrival for r in reqs]

    def test_token_dtype_restored(self, tmp_path):
        r = Request(rid=0, prompt_len=4, max_new_tokens=2, arrival=0.0,
                    tokens=np.array([1, 2, 3, 4], np.int32))
        p = str(tmp_path / "t.jsonl")
        tr.write_trace(p, [r])
        _, back = tr.read_trace(p)
        assert back[0].tokens.dtype == np.int32

    def test_workload_roundtrip_preserves_class_slos(self, tmp_path):
        """Satellite: per-class SLO budgets ride ON the request through
        record -> replay (the future SLO scheduler reads them there)."""
        spec = WorkloadSpec(n_requests=50, rps=10.0, seed=3,
                            class_mix=DEFAULT_CLASS_MIX, burst_factor=3.0,
                            max_model_len=4096)
        reqs = generate(spec)
        assert {r.cls for r in reqs} <= set(CLASS_SLOS)
        for r in reqs:
            assert (r.slo_ttft, r.slo_tpot) == CLASS_SLOS[r.cls]
        p = str(tmp_path / "w.jsonl")
        tr.write_trace(p, reqs)
        _, back = tr.read_trace(p)
        for r in back:
            assert (r.slo_ttft, r.slo_tpot) == CLASS_SLOS[r.cls]
        assert [r.cls for r in back] == [r.cls for r in reqs]


class TestTraceRejection:
    def test_out_of_order_write_rejected(self, tmp_path):
        a = Request(rid=0, prompt_len=4, max_new_tokens=1, arrival=5.0)
        b = Request(rid=1, prompt_len=4, max_new_tokens=1, arrival=1.0)
        with pytest.raises(tr.TraceError, match="out-of-order"):
            tr.write_trace(str(tmp_path / "x.jsonl"), [a, b])

    def test_out_of_order_read_rejected(self, tmp_path):
        p = str(tmp_path / "x.jsonl")
        recs = [tr.request_to_record(Request(
            rid=i, prompt_len=4, max_new_tokens=1, arrival=t))
            for i, t in ((0, 5.0), (1, 1.0))]
        with open(p, "w") as f:
            f.write(json.dumps({"schema": tr.TRACE_SCHEMA,
                                "version": tr.TRACE_VERSION, "n": 2,
                                "meta": {}}) + "\n")
            for rec in recs:
                f.write(json.dumps(rec) + "\n")
        with pytest.raises(tr.TraceError, match="out-of-order"):
            tr.read_trace(p)

    def test_truncated_trace_fails_loudly(self, tmp_path):
        reqs = _random_requests(np.random.default_rng(0), 10)
        p = str(tmp_path / "t.jsonl")
        tr.write_trace(p, reqs)
        lines = open(p).read().splitlines()
        q = str(tmp_path / "cut.jsonl")
        with open(q, "w") as f:
            f.write("\n".join(lines[:6]) + "\n")
        with pytest.raises(tr.TraceError, match="truncated"):
            tr.read_trace(q)

    def test_corrupt_json_reports_line(self, tmp_path):
        reqs = _random_requests(np.random.default_rng(1), 5)
        p = str(tmp_path / "t.jsonl")
        tr.write_trace(p, reqs)
        lines = open(p).read().splitlines()
        lines[3] = lines[3][: len(lines[3]) // 2]     # chop mid-object
        with open(p, "w") as f:
            f.write("\n".join(lines) + "\n")
        with pytest.raises(tr.TraceError, match=":4:"):
            tr.read_trace(p)

    def test_version_mismatch_is_versioned_error(self, tmp_path):
        reqs = _random_requests(np.random.default_rng(2), 3)
        p = str(tmp_path / "t.jsonl")
        tr.write_trace(p, reqs)
        lines = open(p).read().splitlines()
        hdr = json.loads(lines[0])
        hdr["version"] = tr.TRACE_VERSION + 1
        lines[0] = json.dumps(hdr)
        with open(p, "w") as f:
            f.write("\n".join(lines) + "\n")
        with pytest.raises(tr.TraceError, match="version"):
            tr.read_trace(p)

    def test_wrong_schema_rejected(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        with open(p, "w") as f:
            f.write(json.dumps({"schema": "other.trace", "version": 1,
                                "n": 0, "meta": {}}) + "\n")
        with pytest.raises(tr.TraceError, match="schema"):
            tr.read_trace(p)

    def test_empty_file_rejected(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        open(p, "w").close()
        with pytest.raises(tr.TraceError, match="empty"):
            tr.read_trace(p)


class TestBurstGenerator:
    def test_empirical_rate_tracks_envelope(self):
        """Thinning correctness: binned arrival counts stay within
        tolerance of the integrated lambda(t) envelope."""
        spec = WorkloadSpec(n_requests=4000, rps=40.0, seed=11,
                            class_mix=(("chat", 1.0),),
                            burst_factor=4.0, diurnal_period_s=20.0,
                            burst_every_s=8.0, burst_duration_s=2.0,
                            max_model_len=2048)
        reqs = generate(spec)
        arr = np.array([r.arrival for r in reqs])
        lam = envelope_fn(spec)
        bin_w = 2.0
        edges = np.arange(0.0, arr.max() + bin_w, bin_w)
        counts, _ = np.histogram(arr, bins=edges)
        # integrate lambda over each bin (fine quadrature)
        expected = []
        for lo in edges[:-1]:
            ts = np.linspace(lo, lo + bin_w, 41)
            expected.append(float(np.trapezoid([lam(t) for t in ts], ts)))
        expected = np.array(expected)
        # drop the final partial bin (sampler stops mid-bin at n)
        counts, expected = counts[:-1], expected[:-1]
        err = np.abs(counts - expected) / np.maximum(expected, 1.0)
        assert err.mean() < 0.25, err.mean()
        # the burst actually bursts: peak bin >= 2x the steady rate
        assert counts.max() >= 2.0 * spec.rps * bin_w

    def test_rate_envelope_bounds(self):
        spec = WorkloadSpec(rps=10.0, burst_factor=4.0, seed=0,
                            diurnal_period_s=30.0)
        lam = envelope_fn(spec)
        for t in np.linspace(0, 200, 500):
            assert spec.rps - 1e-9 <= lam(t) <= 4.0 * spec.rps + 1e-9

    def test_seed_stability(self):
        """PR 4 pattern: the same spec regenerates a bit-identical
        stream — across calls, and stable against burst-knob toggles
        only through the dedicated sub-rng (not asserted here)."""
        spec = WorkloadSpec(n_requests=120, rps=8.0, seed=42,
                            class_mix=DEFAULT_CLASS_MIX, burst_factor=4.0,
                            max_model_len=4096, prefix_groups=3,
                            sessions=2, turns=2)
        a, b = generate(spec), generate(spec)
        assert [_key(r) for r in a] == [_key(r) for r in b]

    def test_classes_and_offline_tag(self):
        spec = WorkloadSpec(n_requests=300, rps=8.0, seed=1,
                            class_mix=DEFAULT_CLASS_MIX, burst_factor=2.0,
                            max_model_len=4096)
        reqs = generate(spec)
        seen = {r.cls for r in reqs}
        assert seen == {"chat", "longctx", "batch"}
        for r in reqs:
            assert (r.task_type == TaskType.OFFLINE) == (r.cls == "batch")
            assert r.prompt_len + r.max_new_tokens <= 4096


def _req(cls, ttft=None, tpot_span=None, gen=1, slo=(1e9, 1e9)):
    """Hand-built request: ttft None = never produced a first token."""
    r = Request(rid=0, prompt_len=8, max_new_tokens=max(gen, 1),
                arrival=0.0, slo_ttft=slo[0], slo_tpot=slo[1], cls=cls)
    if ttft is not None:
        r.first_token = ttft
        r.generated = gen
        if tpot_span is not None:
            r.finished = ttft + tpot_span
    else:
        r.dropped = True
    return r


class TestPercentiles:
    def test_nearest_rank_with_ties(self):
        # series [1,1,1,2,10]: p50 -> ceil(.5*5)=3rd = 1; p99 -> 5th = 10
        reqs = [_req("chat", ttft=v) for v in (1.0, 1.0, 1.0, 2.0, 10.0)]
        res = _result(reqs)
        assert res.p50("ttft") == 1.0
        assert res.p95("ttft") == 10.0
        assert res.p99("ttft") == 10.0

    def test_single_sample_class(self):
        res = _result([_req("longctx", ttft=7.0)])
        for q in (res.p50, res.p95, res.p99):
            assert q("ttft", "longctx") == 7.0
        assert math.isnan(res.p99("ttft", "chat"))

    def test_dropped_excluded_from_ttft_counted_incomplete(self):
        reqs = [_req("chat", ttft=1.0), _req("chat", ttft=None),
                _req("batch", ttft=None)]
        res = _result(reqs)
        assert res.ttft_series() == [1.0]
        assert res.incomplete() == 2
        assert res.incomplete("chat") == 1
        assert res.incomplete("batch") == 1

    def test_tpot_needs_two_tokens(self):
        done = _req("chat", ttft=1.0, tpot_span=3.0, gen=4)   # tpot = 1.0
        one = _req("chat", ttft=1.0, tpot_span=0.0, gen=1)    # no interval
        res = _result([done, one])
        assert res.tpot_series() == [1.0]
        assert res.p99("tpot") == 1.0

    def test_per_class_series_partition_overall(self):
        """Regression: per-class TTFT/TPOT series are a PARTITION of
        the overall series (nothing dropped, nothing double-counted)."""
        rng = np.random.default_rng(9)
        reqs = []
        for i in range(200):
            cls = ["chat", "longctx", "batch"][int(rng.integers(3))]
            if rng.random() < 0.1:
                reqs.append(_req(cls, ttft=None))
            else:
                reqs.append(_req(cls, ttft=float(rng.uniform(0.1, 20)),
                                 tpot_span=float(rng.uniform(0.1, 5)),
                                 gen=int(rng.integers(2, 50))))
        res = _result(reqs)
        for series in (res.ttft_series, res.tpot_series):
            per_cls = sorted(x for c in res.classes()
                             for x in series(c))
            assert per_cls == sorted(series())
        assert sum(res.incomplete(c) for c in res.classes()) == \
            res.incomplete()

    def test_slo_attainment_per_class(self):
        ok = _req("chat", ttft=0.5, tpot_span=1.0, gen=11,
                  slo=(1.0, 0.2))                     # tpot 0.1 <= 0.2
        bad = _req("batch", ttft=50.0, tpot_span=1.0, gen=11,
                   slo=(1.0, 0.2))                    # ttft 50 > 1
        res = _result([ok, bad])
        assert res.slo_attainment("chat") == 1.0
        assert res.slo_attainment("batch") == 0.0
        assert res.slo_attainment() == 0.5
