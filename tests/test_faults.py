"""Fault-injection plane + work-preserving recovery (DESIGN.md §9).

Four acceptance surfaces:
  * the injector is a pure function of (seed, site, draw counter) —
    bit-identical replay, no hidden global RNG state;
  * a seeded fault storm through the full serving stack loses and
    duplicates NOTHING: every request terminal, every ledger conserved
    (including the new ``fault_retry`` phase), allocator accounting
    exact;
  * checkpointed drain/resume: a loop drained mid-run and resumed on a
    COLD loop produces bit-identical final transcripts (sim synthetic
    ids AND real engine argmax ids);
  * allocator spill/restore chaos with fault-plane interleavings
    (cancel mid-restore, double restore, release-under-restore) holds
    free + unique-live == n_pages and free-host + spilled == host_pages.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.batcher import MemoryBudget
from repro.core.faults import SITES, FaultInjector, FaultPlan
from repro.core.paging import BlockAllocator
from repro.core.recovery import (CHECKPOINT_VERSION, DEFAULT_RECOVERY,
                                 LoopCheckpoint, RecoveryPolicy)
from repro.core.request import Request, TaskType
from repro.core.scheduler import BucketServeScheduler, SchedulerConfig
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.core.telemetry import PHASES, WAIT_PHASES
from repro.data.workload import DEFAULT_CLASS_MIX, WorkloadSpec, generate

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # the 500-trial fallback below still runs
    HAVE_HYPOTHESIS = False

CFG = get_config("llama2-13b")
PAGE = 128

# every site armed at rates that actually fire on a 40-request burst
STORM = dict(decode_step=0.03, prefill_chunk=0.08, restore_stall=0.3,
             restore_error=0.3, host_corrupt=0.15, maintain_tick=0.05)


def _chaos_sim(plan=None, n=40, recovery=None, restore_timeout=30.0,
               **sim_kw):
    """test_telemetry's burst recipe (spills AND restores fire) with the
    fault plane armed on top."""
    budget = MemoryBudget(hbm_bytes_per_device=40 * 2 ** 30, n_devices=3,
                          weight_bytes=CFG.param_count() * 2)
    sched = BucketServeScheduler(CFG, budget, SchedulerConfig(
        max_batch=8, memory_model="paged", page_size=PAGE))
    sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                    decode_slot_cap=64, paged=True, page_size=PAGE,
                    kv_pool_tokens=16 * 1024, prefix_cache=True,
                    session_ttl=600.0, host_pool_tokens=64 * 1024,
                    fault_plan=plan, recovery=recovery,
                    restore_timeout=restore_timeout, **sim_kw)
    spec = WorkloadSpec(rps=6.0, n_requests=n,
                        max_model_len=CFG.max_seq_len,
                        vocab_size=CFG.vocab_size,
                        class_mix=DEFAULT_CLASS_MIX, burst_factor=4.0,
                        diurnal_period_s=40.0, burst_every_s=15.0,
                        burst_duration_s=4.0, prefix_groups=4,
                        prefix_tokens=2 * PAGE, sessions=8, turns=3,
                        think_time_s=2.0, seed=7)
    return sim, generate(spec)


def _final_states(res):
    return sorted((r.rid, r.finished, r.first_token, r.generated,
                   r.dropped, r.quarantined) for r in res.requests)


def _assert_terminal_conserved(res, reqs):
    """Zero lost / zero duplicated / every ledger closed + conserved."""
    rids = [r.rid for r in res.requests]
    assert len(rids) == len(set(rids)) == len(reqs)
    assert sorted(rids) == sorted(r.rid for r in reqs)
    for r in res.requests:
        assert r.finished >= 0 or r.dropped, r.rid      # terminal
        if r.finished >= 0 and not r.dropped:
            assert r.generated == r.max_new_tokens, r.rid
        led = r.ledger
        assert led is not None and led.closed, r.rid
        assert led.conserved(), (r.rid, led.residual(), led.seq)


def _assert_alloc_exact(sim):
    a = sim.loop.backend.alloc
    assert a.free_pages() + a.live_pages() == a.n_pages
    assert a.free_host_slots() + a.spilled_slots() == a.host_pages


def _transcript(backend, r):
    """Full token path: prompt (slice promotion included) + synthetic
    generated continuation past the promoted boundary."""
    toks = [] if r.tokens is None else \
        [int(t) for t in r.tokens[:r.prompt_len]]
    gen = backend.generated_tokens(r)[r.sliced_tokens:]
    return toks + [int(t) for t in gen]


# ------------------------------------------------------- injector unit ---
class TestInjectorUnit:
    def test_pure_function_of_seed_site_counter(self):
        # same plan, DIFFERENT interleaving of draws across sites: each
        # site's fired-counter list is identical — no cross-site or
        # hidden-global state
        plan = FaultPlan(seed=42, rates={s: 0.2 for s in SITES})
        a, b = FaultInjector(plan), FaultInjector(plan)
        for _ in range(300):
            for s in SITES:
                a.fire(s)
        for s in SITES:                       # site-major, not draw-major
            for _ in range(300):
                b.fire(s)
        for s in SITES:
            assert a.fired(s) == b.fired(s)
        assert a.log != [] and sorted(a.log) == sorted(b.log)

    def test_seed_changes_decisions(self):
        p1 = FaultPlan(seed=1, rates={"decode_step": 0.3})
        p2 = FaultPlan(seed=2, rates={"decode_step": 0.3})
        f1, f2 = FaultInjector(p1), FaultInjector(p2)
        for _ in range(200):
            f1.fire("decode_step")
            f2.fire("decode_step")
        assert f1.fired("decode_step") != f2.fired("decode_step")

    def test_unarmed_site_counts_draws_never_fires(self):
        fi = FaultInjector(FaultPlan(seed=3, rates={"decode_step": 1.0}))
        for _ in range(50):
            assert not fi.fire("prefill_chunk")
            assert fi.fire("decode_step")
        assert fi.draws("prefill_chunk") == 50
        assert fi.fired("prefill_chunk") == []
        assert fi.fired("decode_step") == list(range(50))
        assert fi.fired_count() == 50

    def test_rate_is_respected_statistically(self):
        fi = FaultInjector(FaultPlan(seed=9, rates={"decode_step": 0.1}))
        n = sum(fi.fire("decode_step") for _ in range(4000))
        assert 300 < n < 500, n

    def test_parse_spec_roundtrip(self):
        spec = "seed=7,decode_step=0.02,restore_stall=0.3,stall_s=5"
        plan = FaultPlan.parse(spec)
        assert plan.seed == 7 and plan.stall_s == 5.0
        assert plan.rate("decode_step") == 0.02
        assert plan.rate("restore_stall") == 0.3
        assert plan.rate("prefill_chunk") == 0.0
        assert FaultPlan.parse(plan.spec()) == plan

    def test_parse_rejects_unknown_site(self):
        with pytest.raises((AssertionError, ValueError, KeyError)):
            FaultPlan.parse("seed=1,flux_capacitor=0.5")

    def test_rate_bounds_validated(self):
        with pytest.raises(AssertionError):
            FaultPlan(seed=0, rates={"decode_step": 1.5})

    def test_fault_retry_is_a_ledger_wait_phase(self):
        assert "fault_retry" in PHASES
        assert "fault_retry" in WAIT_PHASES


# ------------------------------------------------------- seeded storm ----
class TestFaultStorm:
    def test_storm_loses_and_duplicates_nothing(self):
        plan = FaultPlan(seed=11, rates=STORM, stall_s=0.4)
        sim, reqs = _chaos_sim(plan)
        res = sim.run(reqs)
        _assert_terminal_conserved(res, reqs)
        _assert_alloc_exact(sim)
        # the storm actually stormed, and the loop actually recovered
        assert res.fault_events > 0
        assert res.fault_retries > 0
        phases = set()
        for r in res.requests:
            phases |= set(r.ledger.phases)
        assert "fault_retry" in phases
        # restore-channel fault surface exercised too
        assert (res.restore_stalls + res.restore_failures
                + res.restore_sheds + res.corruptions) > 0

    def test_storm_is_bit_identical_on_replay(self):
        plan = FaultPlan(seed=11, rates=STORM, stall_s=0.4)
        outs, logs = [], []
        for _ in range(2):
            sim, reqs = _chaos_sim(plan)
            res = sim.run(reqs)
            outs.append(_final_states(res))
            logs.append(list(sim.faults.log))
        assert outs[0] == outs[1]
        assert logs[0] == logs[1] and logs[0]

    def test_decode_kill_preserves_sliced_work(self):
        # decode faults hot enough to exhaust retries: pool kills fire,
        # yet every transcript stays exact (slice-boundary promotion)
        plan = FaultPlan(seed=4, rates={"decode_step": 0.25})
        sim, reqs = _chaos_sim(plan, slice_tokens=32)
        res = sim.run(reqs, time_limit=40000.0)
        _assert_terminal_conserved(res, reqs)
        assert res.fault_kills > 0
        ref_sim, ref_reqs = _chaos_sim(None, slice_tokens=32)
        ref = ref_sim.run(ref_reqs)
        want = {r.rid: _transcript(ref_sim.loop.backend, r)
                for r in ref.requests if not r.dropped}
        for r in res.requests:
            if not r.dropped:
                assert _transcript(sim.loop.backend, r) == want[r.rid], r.rid


# -------------------------------------------- restore-channel recovery ---
class TestRestoreRecovery:
    def test_hard_faults_and_corruption_degrade_to_recompute(self):
        plan = FaultPlan(seed=21, rates={"restore_error": 0.6,
                                         "host_corrupt": 0.5})
        sim, reqs = _chaos_sim(plan)
        res = sim.run(reqs)
        _assert_terminal_conserved(res, reqs)
        _assert_alloc_exact(sim)
        assert (res.restore_failures + res.corruptions) > 0

    def test_stalled_restore_times_out_to_cold_prefill(self):
        # satellite 1 regression: a parked request whose restore stalls
        # past the hold timeout unparks as a cold prefill — the loop
        # NEVER hangs on a dead channel
        plan = FaultPlan(seed=13, rates={"restore_stall": 1.0},
                         stall_s=1.0)
        sim, reqs = _chaos_sim(plan, restore_timeout=0.1)
        res = sim.run(reqs)
        _assert_terminal_conserved(res, reqs)
        _assert_alloc_exact(sim)
        assert res.restore_stalls > 0
        assert res.restore_timeouts > 0

    def test_unwinnable_restore_sheds_instead_of_burning_channel(self):
        # a stall far past every SLO budget: the slack rule sheds the
        # restore up front — nothing ever parks behind the dead channel
        plan = FaultPlan(seed=13, rates={"restore_stall": 1.0},
                         stall_s=1e6)
        sim, reqs = _chaos_sim(plan)
        res = sim.run(reqs)
        _assert_terminal_conserved(res, reqs)
        assert res.restore_sheds > 0
        assert res.makespan < 1e5          # the stall never entered time


# ---------------------------------------------------------- quarantine ---
class TestQuarantine:
    def test_poisoned_requests_never_kill_the_loop(self):
        # EVERY prefill chunk faults: no request can ever complete, yet
        # the loop terminates — retries exhaust, streaks cross the
        # quarantine bar, ledgers close, session cascades drop cleanly
        plan = FaultPlan(seed=5, rates={"prefill_chunk": 1.0})
        sim, reqs = _chaos_sim(plan, n=16)
        res = sim.run(reqs)
        _assert_terminal_conserved(res, reqs)
        assert res.quarantined > 0
        assert all(r.dropped for r in res.requests)
        assert any(r.quarantined for r in res.requests)
        # cascade drops (later session turns) are NOT quarantine drops
        assert res.quarantined <= sum(r.dropped for r in res.requests)

    def test_quarantine_threshold_honored(self):
        plan = FaultPlan(seed=5, rates={"prefill_chunk": 1.0})
        pol = RecoveryPolicy(max_retries=1, quarantine_after=2)
        sim, reqs = _chaos_sim(plan, n=8, recovery=pol)
        res = sim.run(reqs)
        _assert_terminal_conserved(res, reqs)
        assert res.quarantined > 0
        for r in res.requests:
            if r.quarantined:
                assert r.fault_streak >= pol.quarantine_after, r.rid


# ------------------------------------------------------ drain / resume ---
class TestDrainResume:
    def test_checkpoint_json_roundtrip(self):
        sim, reqs = _chaos_sim(None)
        sim.run(reqs, drain_at=4.0)
        ck = sim.loop.drain()
        assert ck.requests or ck.held_turns      # drained mid-run
        ck2 = LoopCheckpoint.from_json(ck.to_json())
        assert ck2.version == CHECKPOINT_VERSION
        assert ck2.now == ck.now
        assert ck2.requests == ck.requests
        assert ck2.held_turns == ck.held_turns
        assert ck2.sessions == ck.sessions
        bad = ck.to_json().replace(f'"version": {CHECKPOINT_VERSION}',
                                   '"version": 999')
        with pytest.raises(AssertionError):
            LoopCheckpoint.from_json(bad)

    def test_drain_resume_transcripts_bit_identical(self):
        # reference: one uninterrupted run
        ref_sim, ref_reqs = _chaos_sim(None, slice_tokens=32)
        ref = ref_sim.run(ref_reqs)
        assert not any(r.dropped for r in ref.requests)
        want = {r.rid: _transcript(ref_sim.loop.backend, r)
                for r in ref.requests}

        # drained run: stop mid-flight, checkpoint through JSON, resume
        # on a COLD loop
        sim1, reqs1 = _chaos_sim(None, slice_tokens=32)
        res1 = sim1.run(reqs1, drain_at=4.0)
        ck = LoopCheckpoint.from_json(sim1.loop.drain().to_json())
        assert ck.requests or ck.held_turns
        _assert_alloc_exact(sim1)                # drain left no leaks
        sim2, _ = _chaos_sim(None, slice_tokens=32)
        res2 = sim2.run(ck.restore_requests(), resume_clock=ck.now)

        done1 = {r.rid: r for r in res1.requests
                 if r.finished >= 0 and not r.dropped}
        done2 = {r.rid: r for r in res2.requests}
        assert not any(r.dropped for r in done2.values())
        assert set(done1) | set(done2) == set(want)
        assert not (set(done1) & set(done2))     # nothing ran twice
        for rid, r in done1.items():
            assert _transcript(sim1.loop.backend, r) == want[rid], rid
        for rid, r in done2.items():
            assert _transcript(sim2.loop.backend, r) == want[rid], rid
        # resumed deadlines kept their pre-drain anchor
        for r in res2.requests:
            if r.t0_anchor >= 0.0:
                assert r.ledger.t0 == pytest.approx(r.t0_anchor)

    def test_resume_clock_continues_at_drain_time(self):
        sim1, reqs1 = _chaos_sim(None)
        sim1.run(reqs1, drain_at=4.0)
        ck = sim1.loop.drain()
        sim2, _ = _chaos_sim(None)
        res2 = sim2.run(ck.restore_requests(), resume_clock=ck.now)
        assert ck.now >= 4.0
        for r in res2.requests:
            if r.finished >= 0:
                assert r.finished >= ck.now


# ------------------------------------------- allocator fault chaos (§3) --
def _chaos_step(a, rng, live, spilled, restoring, committed, rid_ctr):
    """One random op against the allocator, including the fault-plane
    interleavings: cancel mid-restore, restore_begin idempotence under
    a second begin, drop-at-rest, release while other slots restore."""
    op = rng.integers(0, 7)
    if op == 0:                                       # admit
        rid = rid_ctr[0]
        rid_ctr[0] += 1
        if a.alloc(rid, int(rng.integers(1, 5 * PAGE))) is not None:
            live.add(rid)
    elif op == 1 and live:                            # grow
        rid = int(rng.choice(sorted(live)))
        a.extend(rid, a.table_len(rid) * PAGE + int(rng.integers(1, PAGE)))
    elif op == 2 and live:                            # release
        rid = int(rng.choice(sorted(live)))
        live.discard(rid)
        a.release(rid)
    elif op == 3 and live:                            # retire tail to host
        rid = int(rng.choice(sorted(live)))
        page = a.table(rid)[-1]
        if a.refs(page) == 1:                         # sole owner
            a.pin(page)                               # pin outlives table
            live.discard(rid)
            a.release(rid)
            h = a.spill(page)
            if h is not None:
                spilled.add(h)
            else:                                     # host full: drop
                a.unpin(page)
    elif op == 4 and spilled:                         # restore_begin
        h = int(rng.choice(sorted(spilled)))
        page = a.restore_begin(h)
        if page is not None:
            assert a.restore_begin(h) == page         # idempotent
            spilled.discard(h)
            restoring[h] = page
    elif op == 5 and restoring:                       # commit OR fault
        h = int(rng.choice(sorted(restoring)))
        page = restoring.pop(h)
        if rng.random() < 0.5:                        # fault: unwind
            assert a.restore_cancel(h)
            assert not a.restore_cancel(h)            # second is a no-op
            spilled.add(h)
        else:
            assert a.restore_commit(h)
            assert not a.restore_commit(h)            # second is a no-op
            committed.add(page)                       # pinned, restored
    elif op == 6 and spilled:                         # bit-rot drop
        h = int(rng.choice(sorted(spilled)))
        if a.drop_spilled(h):
            spilled.discard(h)


def _chaos_invariants(a):
    assert a.free_pages() + a.live_pages() == a.n_pages
    assert a.free_host_slots() + a.spilled_slots() == a.host_pages


def _run_chaos_trial(seed, steps=60):
    rng = np.random.default_rng(seed)
    a = BlockAllocator(n_pages=int(rng.integers(2, 10)), page_size=PAGE,
                       host_pages=int(rng.integers(1, 8)))
    live, spilled, committed, rid_ctr = set(), set(), set(), [0]
    restoring = {}
    for _ in range(steps):
        _chaos_step(a, rng, live, spilled, restoring, committed, rid_ctr)
        _chaos_invariants(a)
    # teardown: every path back to empty still balances
    for h in sorted(restoring):
        assert a.restore_cancel(h)
        spilled.add(h)
    for rid in sorted(live):
        a.release(rid)
    for page in sorted(committed):
        assert a.unpin(page)                          # frees: sole owner
    for h in sorted(spilled):
        assert a.drop_spilled(h)
    assert a.live_pages() == 0
    _chaos_invariants(a)


class TestAllocatorFaultChaos:
    def test_500_random_fault_interleavings(self):
        for seed in range(500):
            _run_chaos_trial(seed)


if HAVE_HYPOTHESIS:
    class TestAllocatorFaultChaosProperty:
        @settings(deadline=None, max_examples=200)
        @given(seed=st.integers(0, 2 ** 31 - 1),
               steps=st.integers(1, 120))
        def test_any_interleaving_holds_invariants(self, seed, steps):
            _run_chaos_trial(seed, steps=steps)


# ------------------------------------------- real-engine fault surface ---
import math                                                   # noqa: E402

import jax                                                    # noqa: E402

from repro.configs import get_smoke_config                    # noqa: E402
from repro.core.engine import ServingEngine                   # noqa: E402
from repro.models import transformer as tfm                   # noqa: E402


def _smoke_engine(fault_plan=None, slots=4, **kw):
    cfg = get_smoke_config("qwen3-14b", max_seq_len=128)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                          weight_bytes=0)
    sched = BucketServeScheduler(cfg, budget,
                                 SchedulerConfig(max_batch=slots))
    return ServingEngine(cfg, params, sched, max_slots=slots,
                         cache_len=128, fault_plan=fault_plan, **kw)


def _eng_reqs(n=8, seed=3, mnt=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt_len=int(rng.integers(8, 48)),
                    max_new_tokens=mnt, arrival=0.0,
                    task_type=TaskType.OFFLINE) for i in range(n)]


class TestEngineFaults:
    def test_fired_sequences_bit_identical_across_backends(self):
        # the SAME plan drives the real engine and the simulator; per
        # site, decisions at shared draw counters must agree exactly —
        # the injector seam is backend-agnostic (counter streams differ
        # in LENGTH across substrates, never in content)
        plan = FaultPlan(seed=5, rates={"prefill_chunk": 0.15,
                                        "decode_step": 0.05,
                                        "maintain_tick": 0.1})
        eng = _smoke_engine(fault_plan=plan)
        reqs = _eng_reqs()
        eng.submit(reqs)
        done = eng.run(max_wall_s=300)
        assert len(done) + sum(r.dropped for r in reqs) == len(reqs)
        for r in done:
            assert len(eng.outputs[r.rid]) == r.max_new_tokens
        assert eng.result.fault_events > 0

        sim, sreqs = _chaos_sim(plan)
        sim.run(sreqs, time_limit=40000.0)
        for site in SITES:
            k = min(eng.faults.draws(site), sim.faults.draws(site))
            ef = [c for c in eng.faults.fired(site) if c < k]
            sf = [c for c in sim.faults.fired(site) if c < k]
            assert ef == sf, site

    def test_engine_drain_resume_token_ids_identical(self):
        # reference: uninterrupted argmax transcripts
        ref = _smoke_engine(slice_tokens=2)
        reqs = _eng_reqs()
        ref.submit(reqs)
        ref_done = ref.run(max_wall_s=300)
        assert len(ref_done) == len(reqs)
        want = {r.rid: list(ref.outputs[r.rid]) for r in reqs}

        # drain a second engine mid-run (wall clock), resume the JSON
        # checkpoint on a COLD engine: the gate line of serve.py's
        # --drain-after smoke
        eng2 = _smoke_engine(slice_tokens=2)
        reqs2 = _eng_reqs()
        eng2.submit(reqs2)
        eng2.loop.run(reqs2, time_limit=math.inf, max_wall_s=300,
                      drain_at=1.0)
        ck = LoopCheckpoint.from_json(eng2.loop.drain().to_json())
        eng3 = _smoke_engine(slice_tokens=2)
        cold = ck.restore_requests()
        eng3.loop.run(cold, time_limit=math.inf, max_wall_s=300,
                      resume_clock=ck.now)

        done2 = {r.rid for r in reqs2 if r.finished >= 0 and not r.dropped}
        done3 = {r.rid for r in cold if r.finished >= 0 and not r.dropped}
        assert done2 | done3 == set(want)        # nothing lost
        assert not (done2 & done3)               # nothing duplicated
        for rid in done2:
            assert list(eng2.outputs[rid]) == want[rid], rid
        for rid in done3:
            assert list(eng3.outputs[rid]) == want[rid], rid
