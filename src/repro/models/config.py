"""Model configuration for the unified architecture zoo.

One ``ModelConfig`` drives every assigned architecture family:
dense GQA decoders, MoE decoders, RWKV6 (attention-free SSM), RG-LRU
hybrids (recurrentgemma), VLM cross-attention decoders and encoder-only
audio backbones.  The transformer assembly (``repro.models.transformer``)
consumes ``block_groups()`` — a list of ``(pattern, repeats)`` where
``pattern`` is a tuple of block-type strings — and scans over ``repeats``
so that 100-layer configs lower to compact HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

BLOCK_ATTN = "attn"        # self-attention + MLP (dense)
BLOCK_MOE = "moe"          # self-attention + MoE FFN
BLOCK_CROSS = "cross"      # cross-attention (vision KV) + MLP
BLOCK_REC = "rec"          # RG-LRU recurrent block + MLP
BLOCK_RWKV = "rwkv"        # RWKV6 time-mix + channel-mix


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    act: str = "silu"               # silu | sq_relu | gelu
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    shared_expert: bool = False     # llama4-style always-on shared expert
    router_norm_topk: bool = True   # normalize top-k gate probs (qwen3 style)
    capacity_factor: float = 1.25   # EP dispatch capacity

    # --- RWKV6 ---
    rwkv_head_size: int = 64
    rwkv_lora_decay: int = 64       # low-rank dim of data-dependent decay

    # --- RG-LRU hybrid (recurrentgemma / griffin) ---
    hybrid_pattern: Tuple[str, ...] = ()   # e.g. ("rec","rec","attn")
    lru_width: int = 0
    conv_width: int = 4
    local_window: int = 0           # sliding window of the local-attn blocks

    # --- VLM ---
    cross_attn_every: int = 0       # every k-th block is cross-attention
    n_vision_tokens: int = 0
    d_vision: int = 0

    # --- encoder-only (audio) ---
    is_encoder: bool = False        # bidirectional, no decode step

    # --- serving variant ---
    sliding_window: int = 0         # >0: SWA variant for long-context decode
    kv_cache_dtype: str = ""        # "int8": quantized KV cache variant
    max_seq_len: int = 32768

    source: str = ""                # citation (paper / model card)

    # ------------------------------------------------------------------
    @property
    def causal(self) -> bool:
        return not self.is_encoder

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def has_decode(self) -> bool:
        """Encoder-only backbones have no autoregressive decode step."""
        return not self.is_encoder

    def attn_cache_len(self, cache_len: int) -> int:
        """Per-request attention-cache length: ``cache_len`` capped by
        the sliding/local window (ring caches never exceed it).  The ONE
        definition both execution backends size paged pools from — any
        drift here breaks backend parity (DESIGN.md §3)."""
        win = self.sliding_window or (
            self.local_window if self.arch_type == "hybrid" else 0)
        return min(cache_len, win) if win else cache_len

    @property
    def chunkable_prefill(self) -> bool:
        """Chunked prefill needs a POSITIONAL KV cache (chunks written
        contiguously, causal mask hides unwritten slots).  Ring caches
        (sliding-window / hybrid-local) and cross-attention vision KV
        are excluded — those configs fall back to whole-prompt prefill.
        Shared gate for the real engine and the cost model."""
        if self.arch_type == "vlm":
            return False
        win = self.sliding_window or (
            self.local_window if self.arch_type == "hybrid" else 0)
        return win == 0

    @property
    def prefix_cacheable(self) -> bool:
        """Cross-request prefix cache gate (DESIGN.md §3 "Prefix
        sharing").  Skipping prefill after a cached prefix requires (a)
        chunked prefill (resume at an absolute offset — positional,
        non-ring caches only) and (b) that the ENTIRE per-token state
        lives in pageable self-attention KV: recurrent carries (RWKV /
        RG-LRU) and vision cross-KV depend on the whole prefix and
        cannot be restored from shared pages.  Shared gate for the real
        engine and the cost model (backend parity)."""
        if not self.has_decode or not self.chunkable_prefill:
            return False
        return all(b in (BLOCK_ATTN, BLOCK_MOE)
                   for pat, _ in self.block_groups() for b in pat)

    @property
    def subquadratic(self) -> bool:
        """Can this config serve 500k-token contexts?

        True for SSM / hybrid (state or window bounded) and for any config
        running the sliding-window serving variant.
        """
        if self.arch_type == "ssm":
            return True
        if self.arch_type == "hybrid":
            return True  # RG-LRU state + bounded local window
        return self.sliding_window > 0

    def block_groups(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """(pattern, repeats) groups; each group lowers to one lax.scan."""
        if self.arch_type == "ssm":
            return (((BLOCK_RWKV,), self.n_layers),)
        if self.arch_type == "hybrid":
            pat = self.hybrid_pattern or (BLOCK_REC, BLOCK_REC, BLOCK_ATTN)
            reps, rem = divmod(self.n_layers, len(pat))
            groups = []
            if reps:
                groups.append((tuple(pat), reps))
            if rem:
                groups.append((tuple(pat[:rem]), 1))
            return tuple(groups)
        if self.arch_type == "vlm" and self.cross_attn_every > 0:
            k = self.cross_attn_every
            assert self.n_layers % k == 0, "vlm layers must tile the pattern"
            pat = (BLOCK_ATTN,) * (k - 1) + (BLOCK_CROSS,)
            return ((pat, self.n_layers // k),)
        if self.arch_type == "moe":
            return (((BLOCK_MOE,), self.n_layers),)
        # dense / audio
        return (((BLOCK_ATTN,), self.n_layers),)

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """Per-token KV-cache bytes — the `2·L·H·D·B` factor of paper Eq. (1).

        Attention-free layers contribute nothing (their state is O(1) in
        sequence length); windowed layers contribute only up to the window
        (handled by the batcher's memory model, see core/batcher.py).
        """
        n_attn = 0
        for pat, reps in self.block_groups():
            for b in pat:
                if b in (BLOCK_ATTN, BLOCK_MOE):
                    n_attn += reps
        return 2 * n_attn * self.n_kv_heads * self.d_head * bytes_per_el

    def cache_bytes_per_token(self) -> int:
        """Runtime per-token cache bytes honoring the serving variant:
        bf16 (2B) by default, int8 (1B + f32 per-(token,head) scales).
        This is the HOT-tier (device pool) denomination — the spill
        tier's is :meth:`spill_bytes_per_token`."""
        if self.kv_cache_dtype == "int8":
            n_attn = self.kv_bytes_per_token(1) // max(
                2 * self.n_kv_heads * self.d_head, 1)
            return self.kv_bytes_per_token(1) +                 2 * n_attn * self.n_kv_heads * 4
        return self.kv_bytes_per_token(2)

    def spill_bytes_per_token(self, spill_dtype: str = "") -> int:
        """Per-token bytes one KV token occupies in the HOST spill tier
        (DESIGN.md §3 "Tier precision") — precision is a property of
        the tier, so the cold tier may be narrower than the hot pool:

        * ``""``/``"bf16"`` — pass-through: pages spill at the hot
          pool's own width (``cache_bytes_per_token``), bit-exactly;
        * ``"int8"`` — 1 B/element plus f32 per-(token, head) scales
          (for an int8 hot pool this IS the pass-through width — the
          pool's int8 payload and scale planes spill verbatim);
        * ``"int4"`` — two elements packed per byte plus the same f32
          scale planes (the scales don't shrink: they are what bounds
          the dequantization error).

        Both execution backends size host slots and price the modeled
        PCIe channel from this ONE number, so quantized spill counts
        and restore times hold under backend parity."""
        if spill_dtype in ("", "bf16"):
            return self.cache_bytes_per_token()
        n_attn = self.kv_bytes_per_token(1) // max(
            2 * self.n_kv_heads * self.d_head, 1)
        scales = 2 * n_attn * self.n_kv_heads * 4
        if spill_dtype == "int8":
            return self.kv_bytes_per_token(1) + scales
        if spill_dtype == "int4":
            return max(self.kv_bytes_per_token(1) // 2, 1) + scales
        raise ValueError(f"unknown spill dtype {spill_dtype!r}")

    def state_bytes(self, bytes_per_el: int = 2) -> int:
        """Sequence-length-independent per-request state (SSM/hybrid)."""
        total = 0
        for pat, reps in self.block_groups():
            for b in pat:
                if b == BLOCK_RWKV:
                    n_h = self.d_model // self.rwkv_head_size
                    total += reps * (
                        n_h * self.rwkv_head_size ** 2 + 2 * self.d_model
                    ) * bytes_per_el
                elif b == BLOCK_REC:
                    total += reps * (
                        self.lru_width * (1 + self.conv_width - 1)
                    ) * bytes_per_el
        return total

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        emb = self.vocab_size * self.d_model
        total = emb if self.tie_embeddings else 2 * emb
        for pat, reps in self.block_groups():
            for b in pat:
                total += reps * self._block_params(b)
        total += self.d_model  # final norm
        if self.arch_type == "vlm":
            total += self.d_vision * self.d_model  # projector
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.arch_type != "moe":
            return self.param_count()
        total = self.param_count()
        ff = 3 * self.d_model * self.d_ff_expert
        total -= self.n_layers * self.n_experts * ff          # remove all experts
        total += self.n_layers * self.top_k * ff              # add active
        return total

    def _block_params(self, b: str) -> int:
        d, q, kv = self.d_model, self.q_dim, self.kv_dim
        attn = d * q + 2 * d * kv + q * d
        if self.act in ("silu", "gelu"):
            mlp = 3 * d * self.d_ff      # gated
        else:
            mlp = 2 * d * self.d_ff      # squared-relu: up/down only
        norms = 2 * d
        if b == BLOCK_ATTN:
            return attn + mlp + norms
        if b == BLOCK_CROSS:
            return attn + mlp + norms
        if b == BLOCK_MOE:
            router = d * self.n_experts
            experts = self.n_experts * 3 * d * self.d_ff_expert
            shared = 3 * d * self.d_ff if self.shared_expert else 0
            return attn + router + experts + shared + norms
        if b == BLOCK_RWKV:
            n_h = self.d_model // self.rwkv_head_size
            tm = 4 * d * d + d * d  # r,k,v,g,out (square, lru-ish approx)
            tm += self.rwkv_lora_decay * 2 * d  # decay LoRA
            cm = 2 * d * int(3.5 * d)
            return tm + cm + norms + n_h * 0
        if b == BLOCK_REC:
            w = self.lru_width
            rec = d * w * 2 + w * d + 3 * w  # in x2, out, gates/Lambda
            rec += self.conv_width * w
            mlp = 3 * d * self.d_ff
            return rec + mlp + norms
        raise ValueError(b)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    small = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 256),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_head=64,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        max_seq_len=256,
    )
    if cfg.n_experts:
        small.update(
            n_experts=min(cfg.n_experts, 4),
            top_k=min(cfg.top_k, 2),
            d_ff_expert=min(cfg.d_ff_expert, 256),
        )
    if cfg.lru_width:
        small["lru_width"] = min(cfg.lru_width, 256)
    if cfg.arch_type == "hybrid":
        small["n_layers"] = 3          # one full (rec, rec, attn) pattern
        small["local_window"] = min(cfg.local_window, 64)
    if cfg.arch_type == "ssm":
        small["d_model"] = 256         # multiple of rwkv_head_size
    if cfg.arch_type == "vlm":
        small["n_layers"] = 2          # one (attn, cross) pattern
        small["cross_attn_every"] = 2
        small["n_vision_tokens"] = min(cfg.n_vision_tokens, 16)
        small["d_vision"] = min(cfg.d_vision, 128)
    if cfg.sliding_window:
        small["sliding_window"] = min(cfg.sliding_window, 64)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
