"""RG-LRU recurrent block (recurrentgemma / Griffin, arXiv:2402.19427).

Recurrent branch: x -> W_x -> temporal conv (width 4) -> RG-LRU; gate
branch: x -> W_g -> GeLU; output: (h ⊙ gate) @ W_o.

RG-LRU (per channel, diagonal — hence associative-scannable):
    r_t = σ(W_r x_t)                      recurrence gate
    i_t = σ(W_i x_t)                      input gate
    log a_t = -c · softplus(Λ) · r_t      (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)

Prefill uses ``jax.lax.associative_scan`` (parallel over time — the
TPU-native replacement for a CUDA sequential kernel); decode is O(1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig

_C = 8.0


def rglru_init(key, cfg: ModelConfig, dtype):
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    return {
        "wx": layers.dense_init(ks[0], d, w, dtype),
        "wg": layers.dense_init(ks[1], d, w, dtype),
        "wo": layers.dense_init(ks[2], w, d, dtype),
        "conv": (jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32)
                 * (cfg.conv_width * w) ** -0.5).astype(dtype),
        "wr": layers.dense_init(ks[4], w, w, jnp.float32, scale=w ** -0.5),
        "wi": layers.dense_init(ks[5], w, w, jnp.float32, scale=w ** -0.5),
        "lam": jnp.linspace(0.9, 4.0, w, dtype=jnp.float32),  # softplus^-1 spread
    }


def _conv1d(x, kernel, state):
    """Causal temporal conv. x: (B,T,w); kernel: (cw,w); state: (B,cw-1,w)."""
    cw = kernel.shape[0]
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else state
    return out, new_state


def _rglru_gates(p, x):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wr"])
    i = jax.nn.sigmoid(xf @ p["wi"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, gated_x


def rglru_scan(p, x, h0, lengths=None):
    """x: (B,T,w); h0: (B,w). Parallel associative scan over T."""
    a, b = _rglru_gates(p, x)                    # (B,T,w) each, f32
    if lengths is not None:
        valid = (jnp.arange(x.shape[1])[None] < lengths[:, None])[..., None]
        a = jnp.where(valid, a, 1.0)             # identity past the end
        b = jnp.where(valid, b, 0.0)
    # fold h0 into the first step: h_1 = a_1 h0 + b_1
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_step(p, x, h0):
    """One-token update. x: (B,1,w)."""
    a, b = _rglru_gates(p, x)
    h = a[:, 0] * h0.astype(jnp.float32) + b[:, 0]
    return h[:, None], h


def rec_block_forward(cfg: ModelConfig, p, x, state, lengths=None):
    """x: (B,T,d); state: {"h": (B,w), "conv": (B,cw-1,w)}."""
    gate = jax.nn.gelu(x @ p["wg"])
    xr = x @ p["wx"]
    xr_conv, conv_state = _conv1d(xr, p["conv"], state["conv"])
    if lengths is not None:
        # conv state must hold the last cw-1 *valid* inputs of each sequence
        cw1 = conv_state.shape[1]
        T = xr.shape[1]
        xp = jnp.concatenate([state["conv"], xr], axis=1)   # (B, cw-1+T, w)
        idx = jnp.clip(lengths[:, None] + jnp.arange(cw1)[None], 0, cw1 + T - 1)
        conv_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    h, h_last = rglru_scan(p, xr_conv, state["h"], lengths)
    out = (h.astype(x.dtype) * gate) @ p["wo"]
    return out, {"h": h_last, "conv": conv_state}


def rec_block_decode(cfg: ModelConfig, p, x, state):
    gate = jax.nn.gelu(x @ p["wg"])
    xr = x @ p["wx"]
    xr, conv_state = _conv1d(xr, p["conv"], state["conv"])
    h, h_last = rglru_step(p, xr, state["h"])
    out = (h.astype(x.dtype) * gate) @ p["wo"]
    return out, {"h": h_last, "conv": conv_state}


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
    }
