"""Attention: GQA self-attention, cross-attention, decode-with-cache.

Three memory regimes:

* ``full_attention``        — materializes (B,H,T,T) scores; short T only.
* ``blocked_attention``     — exact causal/windowed flash-style attention in
  pure jnp: a lax.scan over the *statically enumerated* lower-triangular
  (q-block, kv-block) pair list with online softmax.  Memory is
  O(blk²·B·H); FLOPs match the causal optimum (no masked-out block is ever
  computed).  This is the reference the Pallas ``flash_prefill`` kernel is
  checked against, and the fallback path on CPU.
* ``decode_attention``      — one query token vs. a (possibly ring-buffer)
  KV cache.

Sliding-window caches are rings: position ``p`` lives at slot ``p % W``;
softmax is permutation-invariant so slot order inside the cache never
matters once RoPE is applied at write time.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig

NEG_INF = -1e30

# Activation pins (see repro.sharding.context): without them GSPMD
# T-shards q/k/v and ALL-GATHERS the full tensors on every blocked-
# attention pair step (measured 252 TB/device on llama-3.2-vision-90b
# prefill_32k — EXPERIMENTS.md §Perf iteration 1).
from repro.sharding import context as shctx


def set_mesh(mesh):   # kept for the dryrun API
    shctx.set_mesh(mesh)


def _pin_heads(x):
    return shctx.pin_heads(x)


# ------------------------------------------------------------------ math --
def _gqa_scores(q, k):
    """q: (B,Tq,H,Dh), k: (B,Tk,Hkv,Dh) -> (B,Hkv,G,Tq,Tk) f32."""
    B, Tq, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Tq, Hkv, G, Dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) * (Dh ** -0.5)


def _gqa_out(p, v):
    """p: (B,Hkv,G,Tq,Tk) f32, v: (B,Tk,Hkv,Dh) -> (B,Tq,H,Dh)."""
    B, Hkv, G, Tq, Tk = p.shape
    Dh = v.shape[-1]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Tq, Hkv * G, Dh)


def full_attention(q, k, v, *, causal: bool, lengths=None, window: int = 0,
                   q_offset=0):
    """Reference attention, O(T²) memory. q_offset: position of q[0]."""
    B, Tq, H, Dh = q.shape
    Tk = k.shape[1]
    s = _gqa_scores(q, k)                              # (B,Hkv,G,Tq,Tk)
    qpos = jnp.arange(Tq) + q_offset
    kpos = jnp.arange(Tk)
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    bias = jnp.where(mask, 0.0, NEG_INF)[None, None, None]
    if lengths is not None:
        kvalid = kpos[None, :] < lengths[:, None]      # (B,Tk)
        bias = bias + jnp.where(kvalid, 0.0, NEG_INF)[:, None, None, None, :]
    p = jax.nn.softmax(s + bias, axis=-1)
    return _gqa_out(p, v).astype(q.dtype)


# -------------------------------------------------- int8 KV quantization --
def quantize_kv(x):
    """Symmetric per-(token, head) int8: x (..., Dh) -> (q int8, scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=False) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype=jnp.float32):
    """Inverse of quantize_kv; on target this happens in the decode
    kernel's VMEM registers (HBM traffic stays int8)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ------------------------------------------- int4 spill-tier compression --
# Host-side (numpy) helpers for the SPILL tier (DESIGN.md §3 "Tier
# precision"): pages crossing the host link may be packed two int4
# values per byte with per-(token, head) f32 scales.  These run on the
# host around the PCIe copy — never inside a jitted computation — so
# they are numpy, not jnp.

def pack_int4(q) -> np.ndarray:
    """Pack int8 values in [-8, 7] two-per-byte along the LAST axis.
    An odd tail is zero-padded — ``unpack_int4(p, n)`` restores the
    exact original length."""
    q = np.asarray(q, np.int8)
    if q.shape[-1] % 2:
        q = np.concatenate(
            [q, np.zeros(q.shape[:-1] + (1,), np.int8)], axis=-1)
    u = (q.astype(np.int16) & 0xF).astype(np.uint8)
    return (u[..., 0::2] | (u[..., 1::2] << 4)).astype(np.uint8)


def unpack_int4(p, n: int) -> np.ndarray:
    """Inverse of :func:`pack_int4`: bytes -> int8 values, trimmed to
    the original last-axis length ``n``."""
    p = np.asarray(p, np.uint8)
    assert n <= 2 * p.shape[-1], (n, p.shape)
    lo = (p & 0xF).astype(np.int16)
    hi = (p >> 4).astype(np.int16)
    out = np.empty(p.shape[:-1] + (2 * p.shape[-1],), np.int16)
    out[..., 0::2] = lo
    out[..., 1::2] = hi
    out = np.where(out >= 8, out - 16, out).astype(np.int8)
    return out[..., :n]


def quantize_kv_int4(x):
    """Symmetric per-(token, head) int4 for spilled pages:
    x (..., Dh) float -> (packed uint8 (..., ceil(Dh/2)), scale f32).
    Mirrors :func:`quantize_kv` with a 4-bit grid (limit 7)."""
    x = np.asarray(x, np.float32)
    scale = np.abs(x).max(axis=-1) / 7.0
    scale = np.maximum(scale, 1e-8).astype(np.float32)
    q = np.clip(np.rint(x / scale[..., None]), -7, 7).astype(np.int8)
    return pack_int4(q), scale


def dequantize_kv_int4(packed, scale, n: int, dtype=np.float32):
    """Inverse of :func:`quantize_kv_int4` (``n`` = original Dh)."""
    q = unpack_int4(packed, n)
    return (q.astype(np.float32) * scale[..., None]).astype(dtype)


# ------------------------------------------------- blocked causal (jnp) ---
def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def blocked_attention(q, k, v, *, causal: bool = True, lengths=None,
                      window: int = 0, blk: int = 512):
    """Exact flash-style attention; scans only live (qb,kb) block pairs."""
    B, T, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    blk = max(1, min(blk, T))
    nb = -(-T // blk)
    Tp = nb * blk
    q, k, v = (_pad_to(x, Tp, 1) for x in (q, k, v))

    wb = -(-window // blk) if window else nb           # kv-block reach
    pairs = [(qb, kb) for qb in range(nb) for kb in range(nb)
             if (kb <= qb if causal else True)
             and (qb - kb <= wb if window else True)]
    qb_idx = jnp.array([p[0] for p in pairs], jnp.int32)
    kb_idx = jnp.array([p[1] for p in pairs], jnp.int32)

    qg = q.reshape(B, Tp, Hkv, G, Dh)
    acc = jnp.zeros((nb, B, Hkv, G, blk, Dh), jnp.float32)
    m = jnp.full((nb, B, Hkv, G, blk, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((nb, B, Hkv, G, blk, 1), jnp.float32)
    scale = Dh ** -0.5
    kpos_all = jnp.arange(blk)

    def body(carry, pair):
        acc, m, l = carry
        qb, kb = pair
        qblk = jax.lax.dynamic_slice_in_dim(qg, qb * blk, blk, 1)
        kblk = jax.lax.dynamic_slice_in_dim(k, kb * blk, blk, 1)
        vblk = jax.lax.dynamic_slice_in_dim(v, kb * blk, blk, 1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        qpos = qb * blk + kpos_all
        kpos = kb * blk + kpos_all
        ok = jnp.ones((blk, blk), bool)
        if causal:
            ok &= qpos[:, None] >= kpos[None, :]
        if window:
            ok &= qpos[:, None] - kpos[None, :] < window
        bias = jnp.where(ok, 0.0, NEG_INF)[None, None, None]
        if lengths is not None:
            kvalid = kpos[None, :] < lengths[:, None]
            bias = bias + jnp.where(kvalid, 0., NEG_INF)[:, None, None, None, :]
        else:
            kvalid_pad = kpos[None, :] < T
            bias = bias + jnp.where(kvalid_pad, 0., NEG_INF)[None, None, None]
        s = s + bias

        m_old = jax.lax.dynamic_index_in_dim(m, qb, 0, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qb, 0, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, qb, 0, keepdims=False)
        m_new = jnp.maximum(m_old, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_old * alpha + p.sum(-1, keepdims=True)
        a_new = a_old * alpha + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qb, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qb, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qb, 0)
        return (acc, m, l), None

    # `vmem_fused:` scope: these intermediates correspond 1:1 to the
    # Pallas flash_prefill kernel's VMEM-resident tiles (validated in
    # tests/test_kernels.py); the roofline parser can model them as fused
    # (hlo_analysis.module_stats(fused_kernels=True)).
    with jax.named_scope("vmem_fused:flash_prefill"):
        (acc, m, l), _ = jax.lax.scan(body, (acc, m, l), (qb_idx, kb_idx))
        out = acc / jnp.maximum(l, 1e-30)
    out = jnp.moveaxis(out, 0, 1).reshape(B, nb, Hkv, G, blk, Dh)
    out = jnp.moveaxis(out, -2, 2).reshape(B, Tp, Hkv * G, Dh)
    return out[:, :T].astype(q.dtype)


# ----------------------------------------------------------------- decode --
# NOTE (§Perf iteration 3, REFUTED): a decode-native (B,Hkv,S,Dh) cache
# layout was hypothesized to remove per-layer transpose+copy pairs; it
# measured 2.4x WORSE (the mid-axis scatter of the token update costs
# more than the transposes it saves).  Reverted to (B,S,Hkv,Dh).
def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """q: (B,1,H,Dh); caches: (B,S,Hkv,Dh); pos: (B,) index of the NEW token
    (already written into the cache)."""
    B, _, H, Dh = q.shape
    S = k_cache.shape[1]
    # maps to the Pallas flash_decode kernel (kernels/decode_attn.py)
    with jax.named_scope("vmem_fused:flash_decode"):
        s = _gqa_scores(q, k_cache)                    # (B,Hkv,G,1,S)
        slot = jnp.arange(S)
        if window:
            # ring cache: slot s holds position pos - ((pos-s) mod S);
            # valid once pos >= S-1, else only slots <= pos.
            valid = (slot[None] <= pos[:, None]) | (pos[:, None] >= S)
        else:
            valid = slot[None] <= pos[:, None]         # (B,S)
        s = s + jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
        p = jax.nn.softmax(s, axis=-1)
        out = _gqa_out(p, v_cache)
    return out.astype(q.dtype)


# ------------------------------------------------------------- paged ------
def gather_paged_kv(pool, block_tables, s_len: int):
    """Reassemble per-request caches from a shared page pool.

    pool: (n_pages, page, ...) — K, V, or int8 scale pool;
    block_tables: (B, pages_per_seq) int32; returns (B, s_len, ...).
    Virtual slot ``s`` of request ``b`` is page ``block_tables[b, s //
    page]`` offset ``s % page`` (DESIGN.md §3).  The gather reconstructs
    the EXACT contiguous layout, so downstream attention is bit-identical
    to the contiguous cache path — page placement cannot change results.
    """
    page = pool.shape[1]
    g = pool[block_tables]                       # (B, n_p, page, ...)
    B, n_p = g.shape[:2]
    g = g.reshape((B, n_p * page) + g.shape[3:])
    return g[:, :s_len]


def paged_decode_attention(q, k_pool, v_pool, block_tables, pos, *,
                           s_len: int, window: int = 0):
    """Decode attention over a paged KV cache (jnp oracle for the Pallas
    ``kernels/paged_decode_attn.py`` kernel): gather pages back into the
    contiguous layout, then run ``decode_attention`` unchanged.  Softmax
    permutation-invariance is what makes page order irrelevant."""
    k_cache = gather_paged_kv(k_pool, block_tables, s_len)
    v_cache = gather_paged_kv(v_pool, block_tables, s_len)
    return decode_attention(q, k_cache, v_cache, pos, window=window)


def self_attn_decode_paged(cfg: ModelConfig, p, x, pos, cache, block_tables,
                           *, page_size: int, s_len: int, window: int = 0):
    """One-token decode against the shared page pool.  Mirrors
    ``self_attn_decode`` except the new token's K/V scatter indirects
    through the block table: virtual slot ``pos`` (``pos % s_len`` for
    ring caches) lands in page ``block_tables[b, slot // page]`` offset
    ``slot % page``.  int8 caches are 4-tuples with scale pools."""
    B = x.shape[0]
    quant = cfg.kv_cache_dtype == "int8"
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    cos, sin = layers.rope_angles(pos[:, None], cfg.d_head, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    if quant:
        k_pool, v_pool, k_s, v_s = cache
        kq, ks_new = quantize_kv(k[:, 0])
        vq, vs_new = quantize_kv(v[:, 0])
    else:
        k_pool, v_pool = cache
        kq, vq = k[:, 0], v[:, 0]
    n_p = block_tables.shape[1]
    slot = (pos % s_len) if window else pos
    # dead slots walk pos past their table; clip keeps the (masked)
    # write in range — their tables point at the trash page anyway
    entry = jnp.take_along_axis(
        block_tables, jnp.clip(slot // page_size, 0, n_p - 1)[:, None],
        axis=1)[:, 0]                                          # (B,)
    off = slot % page_size
    k_pool = k_pool.at[entry, off].set(kq)
    v_pool = v_pool.at[entry, off].set(vq)
    if quant:
        k_s = k_s.at[entry, off].set(ks_new)
        v_s = v_s.at[entry, off].set(vs_new)
        with jax.named_scope("vmem_fused:paged_flash_decode_int8"):
            kd = dequantize_kv(gather_paged_kv(k_pool, block_tables, s_len),
                               gather_paged_kv(k_s, block_tables, s_len),
                               q.dtype)
            vd = dequantize_kv(gather_paged_kv(v_pool, block_tables, s_len),
                               gather_paged_kv(v_s, block_tables, s_len),
                               q.dtype)
        with jax.named_scope("vmem_fused:paged_flash_decode"):
            out = decode_attention(q, kd, vd, pos, window=window)
    else:
        # maps to the Pallas paged kernel (kernels/paged_decode_attn.py)
        with jax.named_scope("vmem_fused:paged_flash_decode"):
            out = paged_decode_attention(q, k_pool, v_pool, block_tables,
                                         pos, s_len=s_len, window=window)
    out = out.reshape(B, 1, cfg.q_dim) @ p["wo"]
    new_cache = (k_pool, v_pool, k_s, v_s) if quant else (k_pool, v_pool)
    return out, new_cache


# ------------------------------------------------------------ sublayers ---
def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    kv_src = cfg.d_model  # vision embeds are projected to d_model first
    p = {
        "wq": layers.dense_init(ks[0], d, cfg.q_dim, dtype),
        "wk": layers.dense_init(ks[1], kv_src, cfg.kv_dim, dtype),
        "wv": layers.dense_init(ks[2], kv_src, cfg.kv_dim, dtype),
        "wo": layers.dense_init(ks[3], cfg.q_dim, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.d_head,), dtype)
        p["k_norm"] = jnp.zeros((cfg.d_head,), dtype)
    if cross:
        p["gate"] = jnp.zeros((), dtype)  # tanh-gated cross-attn (llama3.2v)
    return p


def _project_q(cfg, p, x):
    B, T, _ = x.shape
    q = (x @ p["wq"]).reshape(B, T, cfg.n_heads, cfg.d_head)
    if cfg.qk_norm:
        q = layers.head_rms_norm(q, p["q_norm"], cfg.norm_eps)
    return q


def _project_kv(cfg, p, x):
    B, T, _ = x.shape
    k = (x @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    v = (x @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        k = layers.head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


def self_attn_forward(cfg: ModelConfig, p, x, positions, lengths=None, *,
                      window: int = 0, make_cache: bool = False,
                      cache_len: int = 0):
    """Full-sequence self-attention (train / encoder / prefill).

    positions: (T,) or (B,T) absolute positions for RoPE.
    Returns (out, cache|None); cache K/V hold RoPE'd keys.  With window>0
    the cache is a ring of size min(cache_len or window, window).
    """
    B, T, _ = x.shape
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    cos, sin = layers.rope_angles(
        positions if positions.ndim == 2 else positions[None].repeat(B, 0),
        cfg.d_head, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)

    if T <= 1024:
        out = full_attention(q, k, v, causal=cfg.causal, lengths=lengths,
                             window=window)
    else:
        qa, ka, va = q, k, v
        # §Perf 1: the head pin fixes the prefill T-sharding pathology
        # (463x collective cut) but measured 1.8x WORSE collectives when
        # applied to the TRAINING forward (backward through the expanded
        # KV adds all-reduces) — prefill only.
        if make_cache and shctx.get_mesh() is not None \
                and cfg.n_heads > cfg.n_kv_heads:
            # expand KV to full heads so the head dim divides the model
            # axis, then pin everything head-sharded: every blocked-
            # attention slice is shard-local (no per-step all-gathers).
            G = cfg.n_heads // cfg.n_kv_heads
            ka = jnp.repeat(k, G, axis=2)
            va = jnp.repeat(v, G, axis=2)
        qa = _pin_heads(qa)
        ka = _pin_heads(ka)
        va = _pin_heads(va)
        out = blocked_attention(qa, ka, va, causal=cfg.causal,
                                lengths=lengths, window=window)
    out = out.reshape(B, T, cfg.q_dim) @ p["wo"]

    cache = None
    if make_cache:
        if window and window < (cache_len or T):
            kr, vr = _ring_from_prefill(k, v, lengths, window)
        else:
            S = cache_len or T
            kr = _pad_to(k, S, 1)[:, :S]
            vr = _pad_to(v, S, 1)[:, :S]
        if cfg.kv_cache_dtype == "int8":
            kq, ks = quantize_kv(kr)
            vq, vs = quantize_kv(vr)
            cache = (kq, vq, ks, vs)
        else:
            cache = (kr, vr)
    return out, cache


def _ring_from_prefill(k, v, lengths, W):
    """Gather the last W live positions of each sequence into ring layout."""
    B, T = k.shape[:2]
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    last = lengths[:, None] - 1                                  # (B,1)
    slots = jnp.arange(W)[None]                                  # (1,W)
    src = last - ((last - slots) % W)                            # position at slot
    valid = src >= jnp.maximum(0, lengths[:, None] - W)
    src_c = jnp.clip(src, 0, T - 1)
    kr = jnp.take_along_axis(k, src_c[..., None, None], axis=1)
    vr = jnp.take_along_axis(v, src_c[..., None, None], axis=1)
    kr = jnp.where(valid[..., None, None], kr, 0)
    vr = jnp.where(valid[..., None, None], vr, 0)
    return kr, vr


def self_attn_chunk(cfg: ModelConfig, p, x, start, cache):
    """Chunked-prefill self-attention (DESIGN.md §2): Tc new tokens at
    absolute positions [start, start+Tc) attend causally over the cache
    prefix written by earlier chunks plus themselves.

    x: (B,Tc,d); start: () int32 (traced — one executable serves every
    chunk offset); cache as in self_attn_decode (int8 caches are
    4-tuples).  Requires a POSITIONAL (non-ring) cache: chunks are
    written contiguously from 0, so the causal mask alone hides every
    unwritten slot (kpos > max qpos) — no validity bookkeeping needed.
    Rows whose prompt ended before ``start`` write garbage K/V beyond
    their length; those positions are overwritten by decode before they
    ever become valid (same invariant as padded whole-prompt prefill).
    """
    B, Tc, _ = x.shape
    quant = cfg.kv_cache_dtype == "int8"
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    positions = start + jnp.arange(Tc)[None] + jnp.zeros((B, 1), jnp.int32)
    cos, sin = layers.rope_angles(positions, cfg.d_head, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    if quant:
        k_cache, v_cache, k_s, v_s = cache
        kq, ks_new = quantize_kv(k)
        vq, vs_new = quantize_kv(v)
    else:
        k_cache, v_cache = cache
        kq, vq = k, v
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, kq, start, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vq, start, axis=1)
    if quant:
        k_s = jax.lax.dynamic_update_slice_in_dim(k_s, ks_new, start, axis=1)
        v_s = jax.lax.dynamic_update_slice_in_dim(v_s, vs_new, start, axis=1)
        with jax.named_scope("vmem_fused:flash_prefill_int8"):
            kd = dequantize_kv(k_cache, k_s, q.dtype)
            vd = dequantize_kv(v_cache, v_s, q.dtype)
    else:
        kd, vd = k_cache, v_cache
    out = full_attention(q, kd, vd, causal=True, q_offset=start)
    out = out.reshape(B, Tc, cfg.q_dim) @ p["wo"]
    new_cache = (k_cache, v_cache, k_s, v_s) if quant else (k_cache, v_cache)
    return out, new_cache


def distributed_decode_attention(q, k_cache, v_cache, pos, mesh, *,
                                 window: int = 0):
    """Flash-decode over a SEQUENCE-sharded KV cache (distributed
    segmented softmax — beyond-paper, DESIGN.md §5).

    Each `model` shard holds an S/m slice of the cache (what makes a
    100-layer 32k cache fit a 16 GiB chip); the per-shard partial
    (max, numerator, denominator) triples combine with one pmax + two
    psums on (B,H,Dh)-sized tensors instead of the (B,H,S)-score
    all-gather GSPMD would otherwise insert.  Ring caches work
    unchanged: softmax is permutation-invariant and slot validity is
    computed from GLOBAL slot ids.
    """
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import batch_axes

    B, _, H, Dh = q.shape
    S = k_cache.shape[1]
    baxes = batch_axes(mesh, B)

    def local(q, k, v, pos):
      with jax.named_scope("vmem_fused:flash_decode"):
        s_loc = k.shape[1]
        shard = jax.lax.axis_index("model")
        s = _gqa_scores(q, k)                          # (B,Hkv,G,1,s_loc)
        slot = shard * s_loc + jnp.arange(s_loc)       # global slot ids
        if window:
            valid = (slot[None] <= pos[:, None]) | (pos[:, None] >= S)
        else:
            valid = slot[None] <= pos[:, None]
        s = s + jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
        m_loc = s.max(-1)                              # (B,Hkv,G,1)
        m_glob = jax.lax.pmax(m_loc, "model")
        p_ = jnp.exp(s - m_glob[..., None])
        l_loc = p_.sum(-1)
        num_loc = jnp.einsum("bhgqk,bkhd->bhgqd", p_, v.astype(jnp.float32))
        l_glob = jax.lax.psum(l_loc, "model")
        num_glob = jax.lax.psum(num_loc, "model")
        out = num_glob / jnp.maximum(l_glob[..., None], 1e-30)
        B_, Hkv_, G_ = out.shape[:3]
      return out.reshape(B_, Hkv_ * G_, 1, Dh).swapaxes(1, 2)

    out = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(baxes, None, None, None),
                  P(baxes, "model", None, None),
                  P(baxes, "model", None, None),
                  P(baxes)),
        out_specs=P(baxes, None, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, pos)
    return out.astype(q.dtype)


def _seq_shard_mesh(cfg, S, B):
    """Mesh if the decode cache is sequence-sharded (mirror of the
    sharding/partition.py cache rule), else None."""
    mesh = shctx.get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    msize = mesh.shape["model"]
    if cfg.n_kv_heads % msize == 0:      # head-sharded instead
        return None
    if S >= 2048 and S % msize == 0:
        return mesh
    return None


def self_attn_decode(cfg: ModelConfig, p, x, pos, cache, *, window: int = 0):
    """One-token decode. x: (B,1,d); pos: (B,) position of this token.
    int8 caches are 4-tuples (kq, vq, k_scale, v_scale)."""
    B = x.shape[0]
    quant = cfg.kv_cache_dtype == "int8"
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    cos, sin = layers.rope_angles(pos[:, None], cfg.d_head, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    if quant:
        k_cache, v_cache, k_s, v_s = cache
        kq, ks_new = quantize_kv(k[:, 0])
        vq, vs_new = quantize_kv(v[:, 0])
    else:
        k_cache, v_cache = cache
        kq, vq = k[:, 0], v[:, 0]
    S = k_cache.shape[1]
    slot = (pos % S) if window else pos
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, slot].set(kq)
    v_cache = v_cache.at[bidx, slot].set(vq)
    if quant:
        k_s = k_s.at[bidx, slot].set(ks_new)
        v_s = v_s.at[bidx, slot].set(vs_new)
        # dequant inside the fused scope: an int8 decode kernel dequants
        # in-register; HBM reads stay int8 (see §Perf "beyond" item)
        with jax.named_scope("vmem_fused:flash_decode_int8"):
            kd = dequantize_kv(k_cache, k_s, q.dtype)
            vd = dequantize_kv(v_cache, v_s, q.dtype)
    else:
        kd, vd = k_cache, v_cache
    mesh = _seq_shard_mesh(cfg, S, B)
    if mesh is not None:
        out = distributed_decode_attention(q, kd, vd, pos, mesh,
                                           window=window)
    else:
        out = decode_attention(q, kd, vd, pos, window=window)
    out = out.reshape(B, 1, cfg.q_dim) @ p["wo"]
    new_cache = (k_cache, v_cache, k_s, v_s) if quant else (k_cache, v_cache)
    return out, new_cache


def cross_attn_forward(cfg: ModelConfig, p, x, vis_kv):
    """Cross-attention over fixed vision KV. vis_kv: (k,v) (B,Nv,Hkv,Dh)."""
    B, T, _ = x.shape
    q = _project_q(cfg, p, x)
    k, v = vis_kv
    out = full_attention(q, k, v, causal=False)
    out = out.reshape(B, T, cfg.q_dim) @ p["wo"]
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out


def cross_kv(cfg: ModelConfig, p, vis_embeds):
    """Precompute vision K/V once per request (prefill)."""
    return _project_kv(cfg, p, vis_embeds)
