"""Model assembly: block groups -> lax.scan, forward / prefill / decode.

Every architecture is a sequence of *block groups*; each group is a
repeated block pattern whose parameters are stacked on a leading axis and
executed with ``jax.lax.scan`` (so a 100-layer model lowers to HLO the
size of one pattern).  Caches mirror the grouping: per group, per pattern
slot, a type-specific state stacked on the repeat axis.

Public API (all pure functions of (cfg, params, ...)):

    init_params(cfg, key, dtype)
    forward(cfg, params, ...)            -> logits (B,T,V)   [train/encoder]
    prefill(cfg, params, ...)            -> (last_logits, cache)
    decode_step(cfg, params, token, cache) -> (logits, cache)
    init_cache(cfg, batch, cache_len)    -> zeroed cache pytree
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import context as shctx

from . import attention, layers, moe, rglru, rwkv
from .config import (BLOCK_ATTN, BLOCK_CROSS, BLOCK_MOE, BLOCK_REC,
                     BLOCK_RWKV, ModelConfig)


# ----------------------------------------------------------------- init ---
def _block_init(cfg: ModelConfig, btype: str, key, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if btype == BLOCK_ATTN:
        return {
            "ln1": jnp.zeros((d,), dtype), "ln2": jnp.zeros((d,), dtype),
            "attn": attention.attn_init(ks[0], cfg, dtype),
            "mlp": layers.mlp_init(ks[1], d, cfg.d_ff, cfg.act, dtype),
        }
    if btype == BLOCK_MOE:
        return {
            "ln1": jnp.zeros((d,), dtype), "ln2": jnp.zeros((d,), dtype),
            "attn": attention.attn_init(ks[0], cfg, dtype),
            "moe": moe.moe_init(ks[1], cfg, dtype),
        }
    if btype == BLOCK_CROSS:
        return {
            "ln1": jnp.zeros((d,), dtype), "ln2": jnp.zeros((d,), dtype),
            "attn": attention.attn_init(ks[0], cfg, dtype, cross=True),
            "mlp": layers.mlp_init(ks[1], d, cfg.d_ff, cfg.act, dtype),
        }
    if btype == BLOCK_REC:
        return {
            "ln1": jnp.zeros((d,), dtype), "ln2": jnp.zeros((d,), dtype),
            "rec": rglru.rglru_init(ks[0], cfg, dtype),
            "mlp": layers.mlp_init(ks[1], d, cfg.d_ff, "gelu", dtype),
        }
    if btype == BLOCK_RWKV:
        return {
            "ln1": jnp.zeros((d,), dtype), "ln2": jnp.zeros((d,), dtype),
            "rwkv": rwkv.rwkv_init(ks[0], cfg, dtype),
        }
    raise ValueError(btype)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, 4 + len(cfg.block_groups()))
    params = {"embed": layers.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
              "ln_f": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = layers.embed_init(ks[1], cfg.vocab_size,
                                              cfg.d_model, dtype)
    if cfg.arch_type == "vlm":
        params["vis_proj"] = layers.dense_init(ks[2], cfg.d_vision,
                                               cfg.d_model, dtype)
    groups = []
    for gi, (pattern, reps) in enumerate(cfg.block_groups()):
        gkey = ks[4 + gi]
        slot_params = []
        for j, btype in enumerate(pattern):
            rkeys = jax.random.split(jax.random.fold_in(gkey, j), reps)
            slot_params.append(
                jax.vmap(lambda k: _block_init(cfg, btype, k, dtype))(rkeys))
        groups.append(tuple(slot_params))
    params["groups"] = tuple(groups)
    return params


# ---------------------------------------------------------------- cache ---
def _attn_cache_len(cfg: ModelConfig, cache_len: int) -> int:
    return cfg.attn_cache_len(cache_len)


def attn_cache_len(cfg: ModelConfig, cache_len: int) -> int:
    """Public alias: per-request attention-cache length (window-capped)."""
    return cfg.attn_cache_len(cache_len)


def effective_window(cfg: ModelConfig) -> int:
    if cfg.sliding_window:
        return cfg.sliding_window
    if cfg.arch_type == "hybrid":
        return cfg.local_window
    return 0


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.float32):
    """Zeroed cache; attention caches sized min(cache_len, window)."""
    S = _attn_cache_len(cfg, cache_len)
    groups = []
    for pattern, reps in cfg.block_groups():
        slots = []
        for btype in pattern:
            if btype in (BLOCK_ATTN, BLOCK_MOE):
                kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
                slot = {
                    "k": jnp.zeros((reps, batch, S, cfg.n_kv_heads,
                                    cfg.d_head), kv_dt),
                    "v": jnp.zeros((reps, batch, S, cfg.n_kv_heads,
                                    cfg.d_head), kv_dt),
                }
                if cfg.kv_cache_dtype == "int8":
                    slot["k_s"] = jnp.zeros(
                        (reps, batch, S, cfg.n_kv_heads), jnp.float32)
                    slot["v_s"] = jnp.zeros(
                        (reps, batch, S, cfg.n_kv_heads), jnp.float32)
                slots.append(slot)
            elif btype == BLOCK_CROSS:
                slots.append({
                    "k": jnp.zeros((reps, batch, cfg.n_vision_tokens,
                                    cfg.n_kv_heads, cfg.d_head), dtype),
                    "v": jnp.zeros((reps, batch, cfg.n_vision_tokens,
                                    cfg.n_kv_heads, cfg.d_head), dtype),
                })
            elif btype == BLOCK_REC:
                st = rglru.init_state(cfg, batch, dtype)
                slots.append(jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (reps,) + x.shape), st))
            elif btype == BLOCK_RWKV:
                st = rwkv.init_state(cfg, batch, dtype)
                slots.append(jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (reps,) + x.shape), st))
        groups.append(tuple(slots))
    return {"pos": jnp.zeros((batch,), jnp.int32), "groups": tuple(groups)}


def supports_paged_decode(cfg: ModelConfig) -> bool:
    """Paged KV only applies to self-attention caches: the config must
    have a decode step and at least one ATTN/MOE block.  Attention-free
    (RWKV) and encoder-only configs keep the slot pool — their per-slot
    state is O(1) in sequence length, so paging buys nothing."""
    if not cfg.has_decode:
        return False
    return any(b in (BLOCK_ATTN, BLOCK_MOE)
               for pat, _ in cfg.block_groups() for b in pat)


def init_paged_cache(cfg: ModelConfig, batch: int, cache_len: int,
                     n_pages: int, page_size: int, dtype=jnp.float32):
    """Paged decode-pool cache (DESIGN.md §3): self-attention K/V live in
    a SHARED page pool (reps, n_pages, page_size, Hkv, Dh) indexed
    through per-slot block tables; everything sequence-length-independent
    (recurrent state, cross-attention vision KV, positions) stays a
    per-slot tensor exactly as in ``init_cache``.

    block_tables: (batch, ceil(attn_cache_len/page_size)) int32 — virtual
    slot ``s`` of pool slot ``b`` is page ``block_tables[b, s//page]``
    offset ``s % page``.  The caller (engine) owns table contents.
    """
    S = _attn_cache_len(cfg, cache_len)
    n_p = -(-S // page_size)
    groups = []
    for pattern, reps in cfg.block_groups():
        slots = []
        for btype in pattern:
            if btype in (BLOCK_ATTN, BLOCK_MOE):
                kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
                slot = {
                    "k": jnp.zeros((reps, n_pages, page_size, cfg.n_kv_heads,
                                    cfg.d_head), kv_dt),
                    "v": jnp.zeros((reps, n_pages, page_size, cfg.n_kv_heads,
                                    cfg.d_head), kv_dt),
                }
                if cfg.kv_cache_dtype == "int8":
                    slot["k_s"] = jnp.zeros(
                        (reps, n_pages, page_size, cfg.n_kv_heads),
                        jnp.float32)
                    slot["v_s"] = jnp.zeros(
                        (reps, n_pages, page_size, cfg.n_kv_heads),
                        jnp.float32)
                slots.append(slot)
            elif btype == BLOCK_CROSS:
                slots.append({
                    "k": jnp.zeros((reps, batch, cfg.n_vision_tokens,
                                    cfg.n_kv_heads, cfg.d_head), dtype),
                    "v": jnp.zeros((reps, batch, cfg.n_vision_tokens,
                                    cfg.n_kv_heads, cfg.d_head), dtype),
                })
            elif btype == BLOCK_REC:
                st = rglru.init_state(cfg, batch, dtype)
                slots.append(jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (reps,) + x.shape), st))
            elif btype == BLOCK_RWKV:
                st = rwkv.init_state(cfg, batch, dtype)
                slots.append(jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (reps,) + x.shape), st))
        groups.append(tuple(slots))
    return {"pos": jnp.zeros((batch,), jnp.int32),
            "block_tables": jnp.zeros((batch, n_p), jnp.int32),
            "groups": tuple(groups)}


# ---------------------------------------------------------- block apply ---
def _apply_block(cfg: ModelConfig, btype: str, p, x, *, mode: str,
                 positions=None, lengths=None, cache=None, pos=None,
                 vis=None, moe_impl="local", mesh=None, cache_len=0,
                 chunk_start=None, block_tables=None, page_size=0,
                 paged_len=0):
    """One block. mode: 'fwd' | 'prefill' | 'chunk' | 'decode'.
    Returns (x, new_cache_slot).  'chunk' continues an existing cache
    from absolute position ``chunk_start`` (chunked prefill).  A non-None
    ``block_tables`` switches decode attention to the paged KV pool."""
    win = effective_window(cfg)
    new_cache = cache

    if btype in (BLOCK_ATTN, BLOCK_MOE):
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        if mode in ("decode", "chunk"):
            ctuple = (cache["k"], cache["v"], cache["k_s"], cache["v_s"]) \
                if cfg.kv_cache_dtype == "int8" else \
                (cache["k"], cache["v"])
            if mode == "chunk":
                a, new_cache = attention.self_attn_chunk(
                    cfg, p["attn"], h, chunk_start, ctuple)
            elif block_tables is not None:
                a, new_cache = attention.self_attn_decode_paged(
                    cfg, p["attn"], h, pos, ctuple, block_tables,
                    page_size=page_size, s_len=paged_len, window=win)
            else:
                a, new_cache = attention.self_attn_decode(
                    cfg, p["attn"], h, pos, ctuple, window=win)
        else:
            a, kv = attention.self_attn_forward(
                cfg, p["attn"], h, positions, lengths,
                window=win, make_cache=(mode == "prefill"),
                cache_len=cache_len)
            if mode == "prefill":
                new_cache = {"k": kv[0], "v": kv[1]}
                if cfg.kv_cache_dtype == "int8":
                    new_cache["k_s"], new_cache["v_s"] = kv[2], kv[3]
        x = x + a
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        if btype == BLOCK_ATTN:
            x = x + layers.mlp_apply(p["mlp"], h, cfg.act)
        else:
            x = x + _apply_moe(cfg, p["moe"], h, moe_impl, mesh)
        if mode in ("decode", "chunk"):
            nc = {"k": new_cache[0], "v": new_cache[1]}
            if cfg.kv_cache_dtype == "int8":
                nc["k_s"], nc["v_s"] = new_cache[2], new_cache[3]
            new_cache = nc
        return x, new_cache

    if btype == BLOCK_CROSS:
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        if mode == "prefill" or (mode == "fwd" and vis is not None):
            kv = attention.cross_kv(cfg, p["attn"], vis)
            if mode == "prefill":
                new_cache = {"k": kv[0], "v": kv[1]}
        else:  # decode: reuse cached vision KV
            kv = (cache["k"], cache["v"])
        a = attention.cross_attn_forward(cfg, p["attn"], h, kv)
        x = x + a
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + layers.mlp_apply(p["mlp"], h, cfg.act)
        return x, new_cache

    if btype == BLOCK_REC:
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        state = cache if cache is not None else rglru.init_state(
            cfg, x.shape[0], x.dtype)
        if mode == "decode":
            r, new_state = rglru.rec_block_decode(cfg, p["rec"], h, state)
        else:
            r, new_state = rglru.rec_block_forward(cfg, p["rec"], h, state,
                                                   lengths)
        x = x + r
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + layers.mlp_apply(p["mlp"], h, "gelu")
        return x, (new_state if mode != "fwd" else cache)

    if btype == BLOCK_RWKV:
        state = cache if cache is not None else jax.tree.map(
            lambda s: s, rwkv.init_state(cfg, x.shape[0], x.dtype))
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        tm, x_tm, s_new = rwkv.time_mix(cfg, p["rwkv"], h, state["x_tm"],
                                        state["s"], lengths)
        x = x + tm
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        cm, x_cm = rwkv.channel_mix(cfg, p["rwkv"], h, state["x_cm"], lengths)
        x = x + cm
        new_state = {"s": s_new, "x_tm": x_tm, "x_cm": x_cm}
        return x, (new_state if mode != "fwd" else cache)

    raise ValueError(btype)


def _apply_moe(cfg, p, x, impl, mesh):
    if impl == "ref":
        return moe.moe_dense_ref(cfg, p, x)
    if impl == "local":
        return moe.moe_local(cfg, p, x)
    if impl == "ep":
        from jax.sharding import PartitionSpec as P
        fn = functools.partial(moe.moe_ep, cfg)
        pspec = {
            "router": P(None, None),
            "w_gate": P("data", None, "model"),
            "w_up": P("data", None, "model"),
            "w_down": P("data", "model", None),
        }
        if cfg.shared_expert:
            pspec["shared"] = {"gate": P(None, "model"),
                               "up": P(None, "model"),
                               "down": P("model", None)}
        # batch over (pod, data) when divisible; else replicate (every
        # data shard routes the same tokens to its local experts — the
        # a2a round-trip stays correct, see moe_ep docstring). B=1 decode.
        baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        bsz = 1
        for a in baxes:
            bsz *= mesh.shape[a]
        bspec = (baxes if len(baxes) > 1 else baxes[0]) \
            if x.shape[0] % bsz == 0 else None
        return jax.shard_map(
            fn, mesh=mesh,
            in_specs=(pspec, P(bspec, None, None)),
            out_specs=P(bspec, None, None),
            check_vma=False,
        )(p, x)
    raise ValueError(impl)


# -------------------------------------------------------------- drivers ---
def _embed_input(cfg, params, tokens, embeds):
    if embeds is not None:
        return embeds
    return layers.embed_apply(params["embed"], tokens)


def _project_vision(cfg, params, vision_embeds):
    if vision_embeds is None:
        return None
    return vision_embeds @ params["vis_proj"]


def _run_groups(cfg, params, x, *, mode, positions=None, lengths=None,
                cache=None, pos=None, vis=None, moe_impl="local", mesh=None,
                cache_len=0, remat=False, chunk_start=None,
                block_tables=None, page_size=0, paged_len=0):
    new_groups = []
    for gi, (pattern, reps) in enumerate(cfg.block_groups()):
        gparams = params["groups"][gi]
        gcache = cache["groups"][gi] if cache is not None else None

        def body(carry, scans):
            # (§Perf 1c: a replicated-residual pin here measured WORSE —
            # XLA's weight-gathered sequence-parallel MLP beats
            # replicated-activations TP at 32k tokens; see EXPERIMENTS.md)
            xx = carry
            new_slots = []
            for j in range(len(pattern)):
                p_j = scans[j]
                c_j = scans[len(pattern) + j] if gcache is not None else None
                xx, nc = _apply_block(
                    cfg, pattern[j], p_j, xx, mode=mode, positions=positions,
                    lengths=lengths, cache=c_j, pos=pos, vis=vis,
                    moe_impl=moe_impl, mesh=mesh, cache_len=cache_len,
                    chunk_start=chunk_start, block_tables=block_tables,
                    page_size=page_size, paged_len=paged_len)
                new_slots.append(nc if nc is not None else 0)
            return xx, tuple(new_slots)

        if remat:
            # activation checkpointing per block group: backward recomputes
            # the block from its input — temp memory drops from
            # O(layers x activations) to O(layers x d_model carries).
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        scans = tuple(gparams) + (tuple(gcache) if gcache is not None else ())
        x, new_slot_caches = jax.lax.scan(body, x, scans)
        new_groups.append(new_slot_caches)
    return x, tuple(new_groups)


def forward(cfg: ModelConfig, params, tokens=None, embeds=None,
            vision_embeds=None, lengths=None, moe_impl="local", mesh=None,
            remat=False):
    """Full-sequence forward, no cache (training / encoder inference)."""
    x = _embed_input(cfg, params, tokens, embeds)
    B, T, _ = x.shape
    vis = _project_vision(cfg, params, vision_embeds)
    positions = jnp.arange(T)
    x, _ = _run_groups(cfg, params, x, mode="fwd", positions=positions,
                       lengths=lengths, vis=vis, moe_impl=moe_impl, mesh=mesh,
                       remat=remat)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return layers.unembed_apply(head, x)


def prefill(cfg: ModelConfig, params, tokens=None, embeds=None,
            vision_embeds=None, lengths=None, cache_len: Optional[int] = None,
            moe_impl="local", mesh=None):
    """Process full prompts, return (last-token logits, cache)."""
    x = _embed_input(cfg, params, tokens, embeds)
    B, T, _ = x.shape
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    cache_len = cache_len or cfg.max_seq_len
    vis = _project_vision(cfg, params, vision_embeds)
    positions = jnp.arange(T)
    cache0 = init_cache(cfg, B, cache_len, x.dtype)
    x, new_groups = _run_groups(
        cfg, params, x, mode="prefill", positions=positions, lengths=lengths,
        cache=cache0, vis=vis, moe_impl=moe_impl, mesh=mesh,
        cache_len=cache_len)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    last = jnp.take_along_axis(
        x, jnp.clip(lengths - 1, 0, T - 1)[:, None, None], axis=1)[:, 0]
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(head, last)
    return logits, {"pos": lengths.astype(jnp.int32), "groups": new_groups}


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill needs a POSITIONAL KV cache (chunks written
    contiguously, causal mask hides unwritten slots).  Ring caches
    (sliding-window / hybrid-local) and cross-attention vision KV are
    excluded — those configs fall back to whole-prompt prefill."""
    return cfg.chunkable_prefill


def prefill_chunk(cfg: ModelConfig, params, tokens, cache, start, lengths,
                  moe_impl="local", mesh=None):
    """One chunked-prefill step (DESIGN.md §2): process prompt tokens at
    absolute positions [start, start+Tc) against an existing cache.

    tokens: (B,Tc) — the chunk slice of the padded prompt matrix
    (garbage beyond a row's length is fine); cache: pytree from
    ``init_cache`` threaded through successive chunks; start: () int
    (traced — one executable serves every offset); lengths: (B,) FULL
    prompt lengths.

    Returns (last_logits (B,V), new_cache).  ``last_logits[b]`` is the
    next-token distribution for row ``b`` ONLY when its final prompt
    position lies inside this chunk; the caller gathers first tokens
    chunk by chunk.  Rows already fully processed (length <= start) keep
    their cache state bit-for-bit (recurrent carries are frozen).
    The caller owns ``cache['pos']`` and must set it to ``lengths``
    after the final chunk (mirrors ``prefill``'s returned pos).
    """
    x = layers.embed_apply(params["embed"], tokens)
    B, Tc, _ = x.shape
    rel_len = jnp.clip(lengths - start, 0, Tc)
    x, new_groups = _run_groups(
        cfg, params, x, mode="chunk", lengths=rel_len, cache=cache,
        chunk_start=start, moe_impl=moe_impl, mesh=mesh)
    # freeze every cache leaf of rows that finished in an earlier chunk:
    # recurrent carries (e.g. RWKV token-shift) would otherwise be
    # clobbered by this chunk's garbage tail
    active = lengths > start                               # (B,)
    def _keep(new, old):
        m = active.reshape((1, B) + (1,) * (new.ndim - 2))
        return jnp.where(m, new, old)
    new_groups = jax.tree.map(_keep, new_groups, cache["groups"])
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    idx = jnp.clip(lengths - 1 - start, 0, Tc - 1)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(head, last)
    return logits, {"pos": cache["pos"], "groups": new_groups}


def decode_step(cfg: ModelConfig, params, token, cache, moe_impl="local",
                mesh=None, page_size: int = 0, paged_len: int = 0):
    """token: (B,) int32 (or (B,d) embeds for encoder-less flows).
    Returns (logits (B,V), new cache).

    Caches from ``init_paged_cache`` (detected by their ``block_tables``
    leaf) decode against the shared page pool; ``page_size`` must then be
    the pool's page size and ``paged_len`` the request-level cache length
    (defaults to the block tables' full virtual span) — both static, so
    the jitted executable is shared across table contents."""
    x = layers.embed_apply(params["embed"], token[:, None])
    pos = cache["pos"]
    bt = cache.get("block_tables")
    if bt is not None:
        assert page_size > 0, "paged decode_step needs page_size"
        paged_len = paged_len or bt.shape[1] * page_size
    x, new_groups = _run_groups(cfg, params, x, mode="decode", pos=pos,
                                cache=cache, moe_impl=moe_impl, mesh=mesh,
                                block_tables=bt, page_size=page_size,
                                paged_len=paged_len)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(head, x[:, 0])
    new = {"pos": pos + 1, "groups": new_groups}
    if bt is not None:
        new["block_tables"] = bt
    return logits, new
