"""RWKV6 ("Finch") block — attention-free SSM with data-dependent decay.

Faithful to arXiv:2404.05892 in structure: token-shift interpolation,
per-head WKV state `S ∈ R^{Dk×Dv}` updated with a *data-dependent* diagonal
decay `w_t = exp(-exp(ŵ_t))` where `ŵ_t` is produced by a low-rank (LoRA)
projection of the shifted input — the headline v6 feature.  Simplification
(noted in DESIGN.md): the r/k/v/g token-shift mixes use static learned
lerp coefficients (v5-style) rather than the five-way data-dependent
ddlerp; the decay keeps full data dependence.

Recurrence per head (Dk = Dv = head_size):
    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

Prefill runs a lax.scan over time (the Pallas ``wkv6`` kernel is the
TPU-optimized time-blocked version); decode is O(1) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig


def rwkv_n_heads(cfg: ModelConfig) -> int:
    assert cfg.d_model % cfg.rwkv_head_size == 0
    return cfg.d_model // cfg.rwkv_head_size


def rwkv_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = rwkv_n_heads(cfg)
    r = cfg.rwkv_lora_decay
    ks = jax.random.split(key, 12)
    dcm = int(3.5 * d)  # channel-mix hidden (v6 uses 3.5x)
    return {
        # time-mix
        "mix_r": jnp.full((d,), 0.5, dtype), "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype), "mix_g": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": layers.dense_init(ks[0], d, d, dtype),
        "wk": layers.dense_init(ks[1], d, d, dtype),
        "wv": layers.dense_init(ks[2], d, d, dtype),
        "wg": layers.dense_init(ks[3], d, d, dtype),
        "wo": layers.dense_init(ks[4], d, d, dtype),
        # data-dependent decay LoRA: w_hat = w0 + tanh(x @ A) @ B
        "w0": (jnp.zeros((d,), jnp.float32) - 0.5).astype(jnp.float32),
        "wA": layers.dense_init(ks[5], d, r, jnp.float32),
        "wB": (jax.random.normal(ks[6], (r, d), jnp.float32) * 0.01),
        "u": (jax.random.normal(ks[7], (H, hs), jnp.float32) * 0.1),
        "ln_x": jnp.zeros((d,), dtype),  # group-norm scale on wkv output
        # channel-mix
        "cmix_r": jnp.full((d,), 0.5, dtype), "cmix_k": jnp.full((d,), 0.5, dtype),
        "cr": layers.dense_init(ks[8], d, d, dtype),
        "ck": layers.dense_init(ks[9], d, dcm, dtype),
        "cv": layers.dense_init(ks[10], dcm, d, dtype),
    }


def _shift(x, x_prev):
    """Token shift: prepend x_prev, drop last. x: (B,T,d), x_prev: (B,d)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def wkv_scan(r, k, v, w, u, s0):
    """Sequential WKV recurrence.

    r,k,v,w: (B,T,H,hs) (w = decay in (0,1), f32); u: (H,hs);
    s0: (B,H,hs,hs) initial state.  Returns (y (B,T,H,hs) f32, sT).
    """
    B, T, H, hs = r.shape

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                       # (B,H,hs)
        kv = kt[..., :, None] * vt[..., None, :]    # (B,H,hs,hs)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[..., None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    # maps to the Pallas wkv6 kernel (state stays VMEM-resident)
    with jax.named_scope("vmem_fused:wkv6"):
        sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), sT


def _group_norm(y, scale, H, eps=1e-5):
    """Per-head LayerNorm of the wkv output. y: (B,T,H,hs) f32."""
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    B, T = y.shape[:2]
    return yn.reshape(B, T, -1) * (1.0 + scale.astype(jnp.float32))


def _last_valid(x, lengths):
    """x: (B,T,d) -> (B,d) at index lengths-1 (or x[:,-1] if lengths None)."""
    if lengths is None:
        return x[:, -1]
    idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def time_mix(cfg: ModelConfig, p, x, x_prev, s0, lengths=None):
    """x: (B,T,d); x_prev: (B,d) last token of previous chunk; s0 state.
    Right-padded positions (>= lengths) are masked so the carried state is
    exactly that of the unpadded sequence.  Returns (out, x_last, sT)."""
    B, T, d = x.shape
    H, hs = rwkv_n_heads(cfg), cfg.rwkv_head_size
    xs = _shift(x, x_prev)
    xr = _mix(x, xs, p["mix_r"]); xk = _mix(x, xs, p["mix_k"])
    xv = _mix(x, xs, p["mix_v"]); xg = _mix(x, xs, p["mix_g"])
    xw = _mix(x, xs, p["mix_w"])
    r = (xr @ p["wr"]).reshape(B, T, H, hs)
    k = (xk @ p["wk"]).reshape(B, T, H, hs)
    v = (xv @ p["wv"]).reshape(B, T, H, hs)
    g = jax.nn.silu(xg @ p["wg"])
    w_hat = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    w = jnp.exp(-jnp.exp(w_hat)).reshape(B, T, H, hs)      # (0,1) decay
    if lengths is not None:
        valid = (jnp.arange(T)[None] < lengths[:, None])[..., None, None]
        k = jnp.where(valid, k, 0.0)           # no kv injection when padded
        w = jnp.where(valid, w, 1.0)           # identity decay when padded
    y, sT = wkv_scan(r, k, v, w, p["u"], s0)
    y = _group_norm(y, p["ln_x"], H)
    out = (y.astype(x.dtype) * g) @ p["wo"]
    return out, _last_valid(x, lengths), sT


def time_mix_decode(cfg: ModelConfig, p, x, x_prev, s0):
    """One-token time-mix. x: (B,1,d). O(1) state update."""
    out, x_last, sT = time_mix(cfg, p, x, x_prev, s0)
    return out, x_last, sT


def channel_mix(cfg: ModelConfig, p, x, x_prev, lengths=None):
    xs = _shift(x, x_prev)
    xr = _mix(x, xs, p["cmix_r"]); xk = _mix(x, xs, p["cmix_k"])
    r = jax.nn.sigmoid(xr @ p["cr"])
    k = jnp.maximum(xk @ p["ck"], 0.0)
    return r * ((k * k) @ p["cv"]), _last_valid(x, lengths)


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, hs = rwkv_n_heads(cfg), cfg.rwkv_head_size
    return {
        "s": jnp.zeros((batch, H, hs, hs), jnp.float32),
        "x_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "x_cm": jnp.zeros((batch, cfg.d_model), dtype),
    }
