"""Mixture-of-Experts FFN with three execution paths.

* ``moe_dense_ref``   — computes every expert for every token and combines
  with the top-k gate one-hot.  O(E) FLOPs; only sane for tiny smoke/test
  configs.  This is the correctness oracle.
* ``moe_local``       — sort-based: replicate-free grouped matmul via
  ``jax.lax.ragged_dot`` after an argsort of (token, expert) pairs.
  Active-FLOPs only.  Used on a single device and *inside* the EP path.
* ``moe_ep``          — expert-parallel shard_map: experts sharded over the
  ``data`` mesh axis (EP), each expert's d_ff sharded over ``model`` (TP).
  Tokens are routed with a fixed-capacity ``all_to_all`` over ``data``,
  computed with ragged_dot, partial-summed over ``model``, and routed
  back.  This is the TPU-native adaptation of GPU MoE all-to-all
  (DESIGN.md §5): per-device weight bytes drop by dp·tp and the dispatch
  collective is a true ICI all-to-all, not an emulated NCCL pattern.

Routing is top-k softmax with optional top-k re-normalization (qwen3) and
an optional always-on shared expert (llama4-scout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig


def moe_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    d, f, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    scale = d ** -0.5
    p = {
        "router": layers.dense_init(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) * (f ** -0.5)).astype(dtype),
    }
    if cfg.shared_expert:
        p["shared"] = layers.mlp_init(ks[4], d, cfg.d_ff, "silu", dtype)
    return p


def router_topk(cfg: ModelConfig, p, x):
    """x: (T,d) -> gates (T,k) f32, idx (T,k) i32, router probs (T,E)."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def load_balance_loss(cfg: ModelConfig, probs, idx):
    """Switch-style auxiliary loss (substrate for MoE training)."""
    E = cfg.n_experts
    me = probs.mean(0)                                     # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(idx.size, 1)
    return E * jnp.sum(me * ce)


def _expert_ffn_dense(p, x):
    """x: (T,d) -> (T,E,d): every expert applied to every token."""
    g = jnp.einsum("td,edf->tef", x, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x, p["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("tef,efd->ted", h, p["w_down"])


def moe_dense_ref(cfg: ModelConfig, p, x):
    """Oracle path. x: (B,T,d)."""
    B, T, d = x.shape
    xt = x.reshape(B * T, d)
    gates, idx, _ = router_topk(cfg, p, xt)
    all_out = _expert_ffn_dense(p, xt)                      # (N,E,d)
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32)
    comb = jnp.einsum("tk,tke->te", gates, onehot)          # (N,E)
    out = jnp.einsum("te,ted->td", comb, all_out.astype(jnp.float32))
    out = out.astype(x.dtype)
    if cfg.shared_expert:
        out = out + layers.mlp_apply(p["shared"], xt, "silu")
    return out.reshape(B, T, d)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def ragged_matmul(x, w, gs):
    """ragged_dot with a grouped backward.

    The default VJP of ragged_dot on XLA:CPU materializes dense
    (E, rows, d) mask tensors (≈85 TB/device each on qwen3-moe train_4k —
    §Perf iteration 2b); this custom VJP expresses both grads as ragged
    primitives instead:
        dx = ragged_dot(dy, wᵀ, gs)
        dw = ragged_dot_general(x, dy, gs)   (ragged contracting dim)
    """
    with jax.named_scope(f"grouped_mm:{w.shape[0]}"):
        return jax.lax.ragged_dot(x, w, gs)


def _rmm_fwd(x, w, gs):
    return ragged_matmul(x, w, gs), (x, w, gs)


def _rmm_bwd(res, dy):
    x, w, gs = res
    with jax.named_scope(f"grouped_mm:{w.shape[0]}"):
        dx = jax.lax.ragged_dot(dy, jnp.swapaxes(w, 1, 2), gs)
        dims = jax.lax.RaggedDotDimensionNumbers(
            dot_dimension_numbers=(((0,), (0,)), ((), ())),
            lhs_ragged_dimensions=[0], rhs_group_dimensions=[])
        dw = jax.lax.ragged_dot_general(x, dy, gs, dims).astype(w.dtype)
    return dx.astype(x.dtype), dw, None


ragged_matmul.defvjp(_rmm_fwd, _rmm_bwd)


def _grouped_ffn(wg, wu, wd, xs, group_sizes):
    """ragged grouped FFN: xs sorted by expert, group_sizes (E_loc,).

    The ``grouped_mm:E`` scope tells the roofline parser that XLA:CPU's
    dense lowering of ragged_dot (every row x every expert) overcounts
    FLOPs by E — the TPU grouped-matmul kernel does active rows only
    (verified: CPU HLO flops = E x analytic; EXPERIMENTS.md §Perf 2)."""
    g = ragged_matmul(xs, wg, group_sizes)
    u = ragged_matmul(xs, wu, group_sizes)
    h = (jax.nn.silu(g.astype(jnp.float32))
         * u.astype(jnp.float32)).astype(xs.dtype)
    return ragged_matmul(h, wd, group_sizes)


def moe_local(cfg: ModelConfig, p, x):
    """Single-device sort + ragged_dot path (active FLOPs only)."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(B * T, d)
    N = xt.shape[0]
    gates, idx, _ = router_topk(cfg, p, xt)

    flat_e = idx.reshape(-1)                                # (N*k,)
    order = jnp.argsort(flat_e)
    tok_of = jnp.arange(N * k) // k
    xs = xt[tok_of[order]]                                  # (N*k, d)
    group_sizes = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    ys = _grouped_ffn(p["w_gate"], p["w_up"], p["w_down"], xs, group_sizes)

    inv = jnp.argsort(order)
    ys = ys[inv].reshape(N, k, d).astype(jnp.float32)
    out = (ys * gates[..., None]).sum(1).astype(x.dtype)
    if cfg.shared_expert:
        out = out + layers.mlp_apply(p["shared"], xt, "silu")
    return out.reshape(B, T, d)


# ------------------------------------------------------------------ EP ----
def moe_ep(cfg: ModelConfig, p, x, *, axis_ep: str = "data",
           axis_tp: str = "model"):
    """Expert-parallel body — call INSIDE shard_map.

    Per-device views:
      x       : (B_loc, T, d)          tokens of this data shard
      router  : (d, E) replicated
      w_gate  : (E_loc, d, f_loc)      E over `data`, f over `model`
      w_up    : (E_loc, d, f_loc)
      w_down  : (E_loc, f_loc, d)
    """
    dp = jax.lax.axis_size(axis_ep)
    my = jax.lax.axis_index(axis_ep)
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // dp
    xt = x.reshape(B * T, d)
    N = xt.shape[0]
    gates, idx, _ = router_topk(cfg, p, xt)

    # --- dispatch: fixed capacity per destination shard -------------------
    flat_e = idx.reshape(-1)                               # (N*k,)
    dest = flat_e // E_loc                                 # owner data-shard
    cap = int(max(8, round(cfg.capacity_factor * N * k / dp)))
    order = jnp.argsort(dest)                              # stable
    dest_s = dest[order]
    tok_of = (jnp.arange(N * k) // k)[order]
    eloc_s = (flat_e % E_loc)[order]
    # slot within destination bucket
    pos_in_dest = jnp.arange(N * k) - jnp.searchsorted(dest_s, dest_s, side="left")
    keep = pos_in_dest < cap                               # overflow -> dropped
    send_x = jnp.zeros((dp, cap, d), xt.dtype)
    send_e = jnp.zeros((dp, cap), jnp.int32)               # default: expert 0,
    send_src = jnp.full((dp, cap), -1, jnp.int32)          # zero input, dropped
    rows = jnp.where(keep, dest_s, dp)                     # OOB row -> dropped
    cols = jnp.minimum(pos_in_dest, cap - 1)
    send_x = send_x.at[rows, cols].set(xt[tok_of], mode="drop")
    send_e = send_e.at[rows, cols].set(eloc_s, mode="drop")
    send_src = send_src.at[rows, cols].set(order, mode="drop")

    recv_x = jax.lax.all_to_all(send_x, axis_ep, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, axis_ep, 0, 0, tiled=False)
    # recv_*: (dp, cap, ...) tokens sent TO my experts, from each source.

    # --- grouped compute on local experts ---------------------------------
    # Unused slots carry expert id 0 with zero inputs: they flow through the
    # grouped FFN as zero rows (correct, slightly wasteful) and their results
    # are dropped at combine time via send_src == -1.
    xs_all = recv_x.reshape(dp * cap, d)
    es_all = recv_e.reshape(dp * cap)
    o2 = jnp.argsort(es_all)
    xs = xs_all[o2]
    gs = jnp.zeros((E_loc,), jnp.int32).at[es_all].add(1)  # sums to dp*cap
    ys = _grouped_ffn(p["w_gate"], p["w_up"], p["w_down"], xs, gs)
    ys = jnp.zeros_like(ys).at[o2].set(ys)                 # unsort
    ys = ys.reshape(dp, cap, d)
    # TP partial sums over f_loc:
    ys = jax.lax.psum(ys.astype(jnp.float32), axis_tp).astype(xt.dtype)

    back = jax.lax.all_to_all(ys, axis_ep, 0, 0, tiled=False)
    # back[s, c] corresponds to send slot (s, c) of THIS shard.

    # --- combine -----------------------------------------------------------
    flat_out = jnp.zeros((N * k, d), jnp.float32)
    src = send_src.reshape(dp * cap)
    upd = back.reshape(dp * cap, d).astype(jnp.float32)
    flat_out = flat_out.at[jnp.where(src >= 0, src, N * k)].add(
        upd, mode="drop")
    ys_tok = flat_out.reshape(N, k, d)
    out = (ys_tok * gates[..., None]).sum(1).astype(x.dtype)
    if cfg.shared_expert:
        shared = layers.mlp_apply(p["shared"], xt, "silu")
        shared = jax.lax.psum(shared.astype(jnp.float32), axis_tp).astype(x.dtype)
        out = out + shared
    return out.reshape(B, T, d)
