"""Shared layer primitives: norms, RoPE, MLPs, embeddings, init helpers.

Parameters are plain nested dicts of jnp arrays (pytrees).  Attention
projection weights are stored with FUSED head dims — ``(d_model, H*Dh)``
— so every assigned architecture's projections shard evenly on a 16-way
``model`` mesh axis (40- and 10-head configs do not divide 16, but their
fused dims do; see DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def head_rms_norm(x, w, eps: float = 1e-6):
    """Per-head RMSNorm over d_head (qwen3 qk-norm). x: (..., H, Dh)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------- RoPE ----
def rope_angles(positions, d_head: int, theta: float):
    """positions: (...,) int -> cos,sin (..., d_head//2) f32."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, T, H, Dh); cos/sin: (B, T, Dh//2) or (T, Dh//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :], sin[..., None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x1f * sin + x2f * cos], axis=-1
    ).astype(dt)


# ----------------------------------------------------------------- MLP ----
def mlp_init(key, d_model: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    if act == "sq_relu":
        return {
            "up": dense_init(ks[0], d_model, d_ff, dtype),
            "down": dense_init(ks[1], d_ff, d_model, dtype),
        }
    return {
        "gate": dense_init(ks[0], d_model, d_ff, dtype),
        "up": dense_init(ks[1], d_model, d_ff, dtype),
        "down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def mlp_apply(p, x, act: str):
    if act == "sq_relu":
        h = jnp.maximum(x @ p["up"], 0.0)
        return (h * h) @ p["down"]
    h = x @ p["up"]
    g = x @ p["gate"]
    if act == "silu":
        g = jax.nn.silu(g)
    elif act == "gelu":
        g = jax.nn.gelu(g)
    else:
        raise ValueError(act)
    return (g * h) @ p["down"]


# ----------------------------------------------------------- embedding ----
def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * d_model ** -0.5).astype(dtype)


def embed_apply(emb, tokens):
    return jnp.take(emb, tokens, axis=0)


def unembed_apply(w, x):
    """w: (vocab, d) head (possibly tied); returns logits f32."""
    return (x.astype(jnp.float32) @ w.astype(jnp.float32).T)
