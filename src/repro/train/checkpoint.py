"""Checkpointing: pytree <-> .npz with path-encoded keys (no orbax)."""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, params, opt_state=None, meta: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blobs = {f"p/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        blobs.update({f"o/{k}": v for k, v in _flatten(opt_state).items()})
    if meta:
        blobs.update({f"m/{k}": np.asarray(v) for k, v in meta.items()})
    np.savez(path, **blobs)


def restore(path: str, params_template, opt_template=None):
    """Restores into the structure of the given templates."""
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}

    def fill(template, prefix):
        flat = _flatten(template)
        leaves, tdef = jax.tree_util.tree_flatten(template)
        keys = list(flat.keys())
        assert len(keys) == len(leaves)
        restored = [data[f"{prefix}/{k}"] for k in keys]
        return jax.tree_util.tree_unflatten(
            tdef, [r.astype(l.dtype) for r, l in zip(restored, leaves)])

    params = fill(params_template, "p")
    if opt_template is None:
        return params
    return params, fill(opt_template, "o")
