"""AdamW + cosine schedule + global-norm clipping (hand-rolled; no optax).

State is a pytree mirroring params: {"m": ..., "v": ..., "step": int}.
All moments are f32 regardless of param dtype (mixed-precision safe).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat, vhat = m / bc1, v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # decoupled decay on matrices
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
