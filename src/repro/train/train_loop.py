"""Causal-LM training step and loop.

``make_train_step(cfg, opt_cfg)`` builds the pure (params, opt_state,
batch) -> (params, opt_state, metrics) function used by the launcher, the
multi-pod dry-run (train_4k shape) and the smoke tests.  Batches are
dicts: {"tokens": (B,T) i32, "loss_mask": (B,T) f32 or None, and for
audio: "embeds" (B,T,d), "labels" (B,T); for vlm: + "vision_embeds"}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig
from . import optimizer


def lm_loss(cfg: ModelConfig, params, batch, moe_impl="local", mesh=None,
            remat=False):
    """Next-token cross entropy (or frame CE for encoders)."""
    if cfg.is_encoder:
        logits = tfm.forward(cfg, params, embeds=batch["embeds"],
                             moe_impl=moe_impl, mesh=mesh, remat=remat)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
    else:
        tokens = batch["tokens"]
        logits = tfm.forward(
            cfg, params, tokens=tokens[:, :-1],
            vision_embeds=batch.get("vision_embeds"),
            moe_impl=moe_impl, mesh=mesh, remat=remat)
        labels = tokens[:, 1:]
        mask = batch.get("loss_mask")
        mask = mask[:, 1:] if mask is not None else None
    # Vocab-sharded-safe CE: logsumexp reduces the sharded vocab axis with
    # partial sums, and the correct-class logit comes from a one-hot
    # masked reduce (fuses — no (B,T,V) gather or one-hot materializes).
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    correct = jnp.sum(logits * onehot, axis=-1)
    nll = lse - correct
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss, "tokens": mask.sum()}


def make_train_step(cfg: ModelConfig, opt_cfg: optimizer.AdamWConfig,
                    moe_impl="local", mesh=None, data_axes=None,
                    remat=False):
    """data_axes: mesh axis name(s) to psum gradients over (None = no psum;
    under pjit/GSPMD the all-reduce is induced by sharding instead)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, moe_impl, mesh, remat),
            has_aux=True
        )(params)
        if data_axes:
            grads = jax.lax.pmean(grads, data_axes)
        params, opt_state, opt_metrics = optimizer.apply(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def train(cfg: ModelConfig, steps: int, batch_iter, key=None,
          opt_cfg: optimizer.AdamWConfig | None = None, params=None,
          log_every: int = 10, callback=None, moe_impl="local"):
    """Single-host training loop (CPU example / smoke scale)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    opt_cfg = opt_cfg or optimizer.AdamWConfig(total_steps=steps)
    if params is None:
        params = tfm.init_params(cfg, key)
    opt_state = optimizer.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, moe_impl=moe_impl))
    history = []
    for step in range(steps):
        batch = next(batch_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = step
            history.append(rec)
            if callback:
                callback(rec)
    return params, opt_state, history
