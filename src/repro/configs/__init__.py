"""Architecture registry.

``get_config(arch_id)`` returns the exact assigned full config;
``get_config(arch_id, variant="swa")`` returns the sliding-window serving
variant used for long_500k on full-attention archs (DESIGN.md §4);
``get_smoke_config(arch_id)`` returns the reduced same-family variant used
by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, reduced

_MODULES = {
    "yi-6b": "yi_6b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "stablelm-1.6b": "stablelm_1_6b",
    "hubert-xlarge": "hubert_xlarge",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen3-14b": "qwen3_14b",
    "llama2-13b": "llama2_13b",          # the paper's own model
}

ASSIGNED = tuple(k for k in _MODULES if k != "llama2-13b")
SWA_WINDOW = 8192


def get_config(arch: str, variant: str = "") -> ModelConfig:
    """variant: "" | "swa" | "int8" | "swa+int8" (serving variants)."""
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg: ModelConfig = mod.CONFIG
    for v in (p for p in variant.split("+") if p):
        if v == "swa":
            if cfg.arch_type in ("ssm", "hybrid"):
                continue  # already sub-quadratic
            cfg = dataclasses.replace(cfg, sliding_window=SWA_WINDOW,
                                      name=cfg.name + "+swa")
        elif v == "int8":
            cfg = dataclasses.replace(cfg, kv_cache_dtype="int8",
                                      name=cfg.name + "+int8")
        else:
            raise ValueError(f"unknown variant {v!r}")
    return cfg


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


def list_archs():
    return list(_MODULES)
