"""Nemotron-4 340B — dense decoder, GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", arch_type="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_head=192,
    d_ff=73728, vocab_size=256000, act="sq_relu", rope_theta=1e4,
    source="arXiv:2402.16819",
)
