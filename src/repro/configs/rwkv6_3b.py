"""RWKV6 "Finch" 3B — attention-free SSM, data-dependent decay [arXiv:2404.05892]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", arch_type="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_head=64,
    d_ff=8960, vocab_size=65536, rwkv_head_size=64, rwkv_lora_decay=64,
    source="arXiv:2404.05892",
)
