"""Qwen3-MoE 235B-A22B — 128 experts, top-8, GQA, qk-norm [hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", arch_type="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab_size=151936, act="silu", qk_norm=True,
    n_experts=128, top_k=8, d_ff_expert=1536, router_norm_topk=True,
    source="hf:Qwen/Qwen3-30B-A3B",
)
