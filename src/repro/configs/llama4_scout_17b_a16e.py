"""Llama-4-Scout 17B-A16E — MoE 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=202048, act="silu",
    n_experts=16, top_k=1, d_ff_expert=8192, shared_expert=True,
    router_norm_topk=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
