"""Yi-6B — llama-architecture dense decoder with GQA [arXiv:2403.04652]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=11008, vocab_size=64000, act="silu", rope_theta=5e6,
    source="arXiv:2403.04652",
)
