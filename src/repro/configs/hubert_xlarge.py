"""HuBERT-XLarge — encoder-only audio backbone [arXiv:2106.07447].

The conv/mel frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, T, 1280).  Encoder-only: no decode step (decode shapes are
skipped — DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", arch_type="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_head=80,
    d_ff=5120, vocab_size=504, act="gelu", is_encoder=True,
    source="arXiv:2106.07447",
)
