"""Llama-3.2-Vision 90B — dense decoder with gated cross-attention image
layers every 5th block [hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision encoder is a STUB: input_specs() provides patch embeddings
(B, 1600, 1280); the projector (d_vision -> d_model) is part of this model.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", arch_type="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=28672, vocab_size=128256, act="silu",
    cross_attn_every=5, n_vision_tokens=1600, d_vision=1280,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
