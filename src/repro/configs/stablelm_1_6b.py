"""StableLM-2 1.6B — dense decoder, MHA (kv=32) [hf:stabilityai/stablelm-2-1_6b]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", arch_type="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=5632, vocab_size=100352, act="silu", rope_theta=1e4,
    source="hf:stabilityai/stablelm-2-1_6b",
)
