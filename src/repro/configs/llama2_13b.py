"""Llama-2-13B — the paper's own evaluation model [arXiv:2307.09288]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-13b", arch_type="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=40, d_head=128,
    d_ff=13824, vocab_size=32000, act="silu", rope_theta=1e4,
    max_seq_len=4096,
    source="arXiv:2307.09288",
)
