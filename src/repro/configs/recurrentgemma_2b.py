"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, 1 attn : 2 rec
[arXiv:2402.19427]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab_size=256000, act="gelu",
    hybrid_pattern=("rec", "rec", "attn"), lru_width=2560, conv_width=4,
    local_window=2048, rope_theta=1e4,
    source="arXiv:2402.19427",
)
