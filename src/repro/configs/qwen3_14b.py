"""Qwen3-14B — dense decoder, GQA, qk-norm [hf:Qwen/Qwen3-8B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", arch_type="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=17408, vocab_size=151936, act="silu", qk_norm=True,
    source="hf:Qwen/Qwen3-8B",
)
