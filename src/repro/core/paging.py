"""Paged KV-cache accounting: fixed-size page pool + block tables.

This is the host-side bookkeeping half of the paged decode pool
(DESIGN.md §3).  A :class:`BlockAllocator` owns a free list of
fixed-size pages and a per-request block table; both execution
backends (real JAX engine and the analytic cost model) drive the SAME
allocator logic through :func:`admit_blocks` / :func:`extend_for_decode`
so their admission decisions cannot drift (the backend-parity
invariant).

The paper's Eq. (6) becomes an EXACT block budget here: a request
holding ``t`` live tokens pins ``ceil(t / page_size)`` pages — no
per-slot ``cache_len`` preallocation, which is what lets a 40-token
Alpaca request and a 32k LongBench request share one HBM pool without
the short request paying for the long one's worst case.

Pages are REFCOUNTED (PR 3): a page may appear in several live block
tables at once (cross-request prefix sharing, core/prefix_cache.py)
and may additionally be pinned by the prefix cache itself.  A page
returns to the free list only when its reference count hits zero, so
releasing one sharer can never corrupt another's cache.

Pages can SPILL to a host-RAM tier (PR 5): a page whose only reference
is its retention pin may move device->host — the HBM page returns to
the free list and a HOST SLOT records where the content went
(``spill``); a later hit restores it through a reserved device page
(``restore_begin``/``restore_commit``, split so the copy can complete
asynchronously while the slot stays accounted).  The allocator is pure
bookkeeping — actual byte movement is the execution backend's job
(core/engine.py gathers/scatters real KV; the cost model only prices
the transfer).

Invariants (property-tested in tests/test_paging.py):
  * a page's refcount always equals (#live tables holding it) + (#pins);
  * free + unique-live + spilled-slots == accounted, i.e. device pages
    still satisfy free + unique-live == n_pages (a spilled page's HBM
    is genuinely freed) and host slots satisfy free-host + spilled ==
    host_pages — no tier leaks, no double-assigned slot in either;
  * a shared page NEVER spills (spill is refused unless the caller's
    pin is the LAST reference);
  * restore is idempotent: ``restore_begin`` on an already-restoring
    slot returns the same reserved page; a second ``restore_commit``
    is a no-op;
  * a live request's table holds exactly ``ceil(tokens / page_size)``
    pages;
  * alloc/extend are all-or-nothing; release is idempotent per rid.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence


class BlockAllocator:
    """Free-list allocator of fixed-size KV pages with refcounts and
    block tables.

    Token-level API: callers say how many tokens a request holds and the
    allocator keeps its table at exactly ``ceil(tokens / page_size)``
    pages.  ``alloc``/``extend`` are all-or-nothing — on exhaustion they
    return None and the allocator state is unchanged (no partial grabs),
    so callers can preempt and retry without unwinding.

    ``alloc(..., shared=pages)`` prepends already-live pages (a cached
    prefix) to the new table, bumping their refcounts instead of popping
    the free list — the request pays only for its private suffix pages.
    ``pin``/``unpin`` are the prefix cache's own references.
    """

    def __init__(self, n_pages: int, page_size: int, host_pages: int = 0,
                 page_bytes: int = 0, host_slot_bytes: int = 0):
        assert n_pages > 0 and page_size > 0, (n_pages, page_size)
        assert host_pages >= 0, host_pages
        self.n_pages = n_pages
        self.page_size = page_size
        # byte denomination of each tier (0 = caller doesn't track
        # bytes): a device page holds page_size tokens at the HOT
        # cache width; a host slot holds the same tokens at the SPILL
        # width — the tiers may differ (DESIGN.md §3 "Tier precision")
        self.page_bytes = page_bytes
        self.host_slot_bytes = host_slot_bytes
        # LIFO free list: released pages are reused first (locality)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._refs: Dict[int, int] = {}          # page -> live refcount
        self._pins: Dict[int, int] = {}          # page -> cache-pin count
        # ---- host spill tier (0 host pages = disabled) ----
        self.host_pages = host_pages
        self._free_host: List[int] = list(range(host_pages - 1, -1, -1))
        self._spilled: Dict[int, None] = {}      # hslot, content at rest
        self._restoring: Dict[int, int] = {}     # hslot -> reserved page

    # ----------------------------------------------------------- queries --
    def pages_for(self, tokens: int) -> int:
        return -(-max(tokens, 0) // self.page_size)

    def free_pages(self) -> int:
        return len(self._free)

    def live_pages(self) -> int:
        """UNIQUE live pages (shared pages counted once): the quantity
        that satisfies free + live == total."""
        return len(self._refs)

    def refs(self, page: int) -> int:
        return self._refs.get(page, 0)

    def shared_pages(self) -> int:
        """Pages referenced more than once (table+table or table+pin)."""
        return sum(1 for c in self._refs.values() if c >= 2)

    def reclaimable(self, rid: int) -> int:
        """Pages that would actually return to the free list if ``rid``
        were released NOW (refcount 1 — no other sharer, no cache pin)."""
        return sum(1 for p in self._tables.get(rid, ())
                   if self._refs.get(p) == 1)

    def table(self, rid: int) -> List[int]:
        return list(self._tables.get(rid, ()))

    def table_len(self, rid: int) -> int:
        """O(1) page count of ``rid``'s table (0 if not live) — lets the
        engine's block-table mirror detect growth without copying the
        whole table per dispatch."""
        return len(self._tables.get(rid, ()))

    def table_tail(self, rid: int, start: int) -> List[int]:
        """Pages appended past index ``start`` — O(growth), the
        incremental half of the mirror sync."""
        return list(self._tables.get(rid, ())[start:])

    def holds(self, rid: int) -> bool:
        return rid in self._tables

    # ----------------------------------------------------- host-tier state --
    def spilled_slots(self) -> int:
        """Host slots in use: content at rest + restores in flight."""
        return len(self._spilled) + len(self._restoring)

    def free_host_slots(self) -> int:
        return len(self._free_host)

    def is_spilled(self, hslot: int) -> bool:
        return hslot in self._spilled or hslot in self._restoring

    def device_bytes_in_use(self) -> int:
        """HBM bytes the live pages pin (hot-tier width)."""
        return self.live_pages() * self.page_bytes

    def host_bytes_in_use(self) -> int:
        """Host-RAM bytes the spilled slots pin (spill-tier width —
        compressed when the spill dtype is narrower than the pool)."""
        return self.spilled_slots() * self.host_slot_bytes

    # ------------------------------------------------------------- edits --
    def _pop_free(self) -> int:
        p = self._free.pop()
        self._refs[p] = 1
        return p

    def _unref(self, page: int) -> bool:
        """Drop one reference; True if the page returned to the free
        list (count hit zero)."""
        c = self._refs[page] - 1
        if c == 0:
            del self._refs[page]
            self._free.append(page)
            return True
        self._refs[page] = c
        return False

    def alloc(self, rid: int, tokens: int,
              shared: Optional[Sequence[int]] = None) -> Optional[List[int]]:
        """Admit ``rid`` with ``tokens`` live tokens.  ``shared`` pages
        (a cached prefix, already live/pinned) are prepended to the table
        by reference — only the remaining pages come from the free list.
        Returns the block table, or None if the pool cannot hold it
        (state unchanged, including refcounts)."""
        assert rid not in self._tables, f"rid {rid} already live"
        shared = list(shared or ())
        need = self.pages_for(tokens)
        assert need >= len(shared), \
            f"shared prefix ({len(shared)} pages) exceeds need ({need})"
        if need - len(shared) > len(self._free):
            return None
        for p in shared:
            assert self._refs.get(p, 0) > 0, \
                f"shared page {p} is not live (evicted prefix?)"
            self._refs[p] += 1
        pages = shared + [self._pop_free()
                          for _ in range(need - len(shared))]
        self._tables[rid] = pages
        return list(pages)

    def extend(self, rid: int, tokens: int) -> Optional[List[int]]:
        """Grow ``rid``'s table to cover ``tokens`` tokens.  Returns the
        NEWLY added pages ([] if already covered), or None on exhaustion
        (state unchanged).  Tables never shrink mid-flight.  New pages
        are always private (refcount 1) — growth happens past the
        prompt, where no sharing is possible."""
        assert rid in self._tables, f"rid {rid} not live"
        have = self._tables[rid]
        need = max(self.pages_for(tokens), len(have))
        grow = need - len(have)
        if grow > len(self._free):
            return None
        new = [self._pop_free() for _ in range(grow)]
        have.extend(new)
        return new

    def release(self, rid: int) -> int:
        """Drop ``rid``'s references; returns how many pages actually
        returned to the free list (0 if unknown — release is idempotent
        so preemption/finish races are harmless; shared pages survive
        their co-owners)."""
        pages = self._tables.pop(rid, None)
        if pages is None:
            return 0
        return sum(1 for p in pages if self._unref(p))

    # ------------------------------------------------- prefix-cache pins --
    def pin(self, page: int) -> None:
        """Extra reference held by the prefix cache: the page survives
        its writer's release and stays addressable for future hits."""
        assert self._refs.get(page, 0) > 0, \
            f"pin target {page} is not live"
        self._refs[page] += 1
        self._pins[page] = self._pins.get(page, 0) + 1

    def unpin(self, page: int) -> bool:
        """Drop a cache pin; True if the page was freed (no live table
        referenced it)."""
        assert self._refs.get(page, 0) > 0, f"unpin of dead page {page}"
        assert self._pins.get(page, 0) > 0, f"unpin without pin: {page}"
        if self._pins[page] == 1:
            del self._pins[page]
        else:
            self._pins[page] -= 1
        return self._unref(page)

    # ----------------------------------------------- host spill tier (§3) --
    def spill(self, page: int) -> Optional[int]:
        """Move ``page`` to the host tier: the caller's PIN must be the
        LAST reference — a page referenced by any live block table (or
        another pin) is refused, the sharer would read freed HBM.  On
        success the device page returns to the free list and the
        returned host slot records where the content went.  None when
        refused or the host pool is full (state unchanged) — the
        caller falls back to a destructive drop."""
        if (self._refs.get(page, 0) != 1 or self._pins.get(page, 0) != 1
                or not self._free_host):
            return None
        hslot = self._free_host.pop()
        del self._pins[page]                 # the pin moves to the slot
        freed = self._unref(page)
        assert freed, "sole-reference page did not free on spill"
        self._spilled[hslot] = None
        return hslot

    def restore_begin(self, hslot: int) -> Optional[int]:
        """Reserve a device page for ``hslot``'s content to return to.
        The page carries the caller's pin (refcount 1); the host slot
        stays accounted until ``restore_commit`` — the copy may still
        be reading it (double-buffer rule).  Idempotent: a slot already
        restoring returns its reserved page.  None when no device page
        is free (state unchanged; the caller evicts and retries)."""
        if hslot in self._restoring:
            return self._restoring[hslot]
        assert hslot in self._spilled, f"restore of unspilled slot {hslot}"
        if not self._free:
            return None
        page = self._pop_free()
        self._pins[page] = 1                 # the slot's pin moves back
        del self._spilled[hslot]
        self._restoring[hslot] = page
        return page

    def restore_commit(self, hslot: int) -> bool:
        """The copy landed: release the host slot.  Idempotent — a slot
        not in flight is a no-op returning False."""
        if hslot not in self._restoring:
            return False
        del self._restoring[hslot]
        self._free_host.append(hslot)
        return True

    def restore_cancel(self, hslot: int) -> bool:
        """Abort a restore in flight (channel hard-fault, recovery shed
        — core/recovery.py): the reserved device page returns to the
        free list and the slot's content is back AT REST — the copy
        never landed, so the host bytes are still the truth.  Inverse
        of ``restore_begin``; both two-tier invariants hold across the
        round trip.  False if no restore was in flight."""
        if hslot not in self._restoring:
            return False
        page = self._restoring.pop(hslot)
        assert self._pins.get(page) == 1 and self._refs.get(page) == 1, \
            f"reserved restore page {page} grew references mid-flight"
        del self._pins[page]
        freed = self._unref(page)
        assert freed, "reserved restore page did not free on cancel"
        self._spilled[hslot] = None
        return True

    def drop_spilled(self, hslot: int) -> bool:
        """Destroy spilled content (host-budget LRU, expiry of a demoted
        session): the slot returns to the host free list.  A slot with a
        restore in flight is refused — the copy is reading it."""
        if hslot in self._restoring:
            return False
        assert hslot in self._spilled, f"drop of unspilled slot {hslot}"
        del self._spilled[hslot]
        self._free_host.append(hslot)
        return True


# ------------------------------------------------- tier byte denomination --
def device_pool_pages(cfg, pool_tokens: int, page_size: int) -> int:
    """Device pages a hot-pool budget of ``pool_tokens`` REFERENCE
    (bf16-width) KV tokens buys at the pool's actual cache dtype.

    The budget is a byte quantity expressed in bf16-token units —
    ``pool_tokens × kv_bytes_per_token(2)`` bytes of HBM — and each
    page costs ``page_size × cache_bytes_per_token()`` of it, so an
    int8 pool genuinely holds ~2× the pages of a bf16 pool under the
    SAME budget instead of only shifting the Eq.-(6) token cap.  For a
    bf16 pool this reduces exactly to ``pool_tokens // page_size``
    (the pre-quantized-tiers rule).  THE one sizing rule both
    execution backends share (backend parity)."""
    pool_bytes = max(pool_tokens, 0) * cfg.kv_bytes_per_token(2)
    page_cost = page_size * max(cfg.cache_bytes_per_token(), 1)
    return pool_bytes // page_cost


def host_tier_geometry(cfg, host_pool_tokens: Optional[int],
                       page_size: int, spill_dtype: str = ""):
    """(host_slots, bytes_per_slot) of the host spill tier for a budget
    of ``host_pool_tokens`` reference (bf16-width) KV tokens.

    A slot stores one page at the SPILL dtype's width
    (``cfg.spill_bytes_per_token``), so the same host budget retains
    ~2× (int8) / ~3.5× (int4) more transcript pages than a bf16 spill
    — and ``bytes_per_slot`` is what one page transfer moves over the
    PCIe link, which both backends price identically
    (``bytes_per_slot / spill_bw`` seconds per page)."""
    slot_bytes = page_size * max(cfg.spill_bytes_per_token(spill_dtype), 1)
    budget_bytes = (host_pool_tokens or 0) * cfg.kv_bytes_per_token(2)
    return budget_bytes // slot_bytes, slot_bytes


# ------------------------------------------------------- shared policies --
def admit_blocks(alloc: BlockAllocator, requests: Sequence,
                 insert_tokens: Callable[[object], int],
                 cache=None, tokens_of=None) -> int:
    """Admission gate: allocate insert-time pages for a PREFIX of the
    batch; returns how many requests were admitted.  ``insert_tokens``
    maps a request to the tokens its cache holds right after prefill
    (prompt + the first decode write, window-capped).  The loop re-queues
    the rest — the block analogue of the decode-slot clamp.

    ``cache`` (+ ``tokens_of``) is any retention object speaking the
    shared cache protocol — a bare
    :class:`~repro.core.prefix_cache.PrefixCache` or the full
    :class:`~repro.core.retention.KvRetention` layer.  Each request's
    prompt is first matched against it: matched pages (a cached radix
    run, plus the session's pinned partial tail when the prompt
    continues a retained transcript) are attached by REFERENCE
    (refcount++) and only the uncached suffix is charged to the free
    list.  On exhaustion the cache's ordered eviction policy (expired
    sessions → LRU cold prefixes → live sessions, each rung SPILLING
    to host before it destroys when a spill tier is configured) runs
    before giving up — admission starvation reclaims retained cache
    before it blocks.  A request whose hit continues into spilled
    pages is HELD (``Request.spill_wait`` set by the lookup): it is
    not admitted this pass and the loop re-queues it for when the
    restore lands.  ``note_admit`` commits a session claim on
    success; ``abort`` rolls it back on failure."""
    n = 0
    for r in requests:
        shared: List[int] = []
        hit_tokens = 0
        if cache is not None:
            shared, hit_tokens = cache.lookup(tokens_of(r), req=r,
                                              alloc=alloc)
            if getattr(r, "spill_wait", -1.0) >= 0.0:
                # the hit continues into SPILLED pages and a host->device
                # restore is in flight: HOLD the request (the loop parks
                # it until spill_wait) instead of admitting it to
                # re-prefill work whose KV is coming back over the bus
                cache.abort(r)
                break
        while True:
            got = alloc.alloc(r.rid, insert_tokens(r), shared=shared)
            if got is not None or cache is None:
                break
            short = (alloc.pages_for(insert_tokens(r)) - len(shared)
                     - alloc.free_pages())
            if cache.evict(alloc, short, protect=shared) == 0:
                break
        if got is None:
            if cache is not None:
                cache.abort(r)
            break
        if cache is not None:
            r.prefix_hit_tokens = hit_tokens
            cache.note_admit(alloc, r, hit_tokens)
        n += 1
    return n


def extend_for_decode(alloc: BlockAllocator, pool: Sequence,
                      decode_tokens: Callable[[object], int],
                      cache=None, slack_of=None) -> List:
    """Pre-decode page extension with preemption: grow every pooled
    request's table to cover its next token write; on exhaustion free
    pages in cheapness order — (1) the cache's ordered retention
    policy (expired session tails, then LRU zero-ref cached prefixes,
    then live session tails — nobody in flight loses work, see
    ``KvRetention.evict``), then (2) preempt a pooled request LATER in
    the processing order, preferring the one whose release RECLAIMS the
    most pages (a victim whose pages are all shared frees nothing and
    is never picked).  If the starving request is last in order — or no
    later victim can free a page — it preempts itself rather than
    robbing an earlier request.  Front-of-order-first processing
    therefore guarantees the head of the pool always progresses (no
    livelock).  Returns the victims (their pages already released); the
    caller re-queues them.

    Processing order is the policy knob (DESIGN.md §8):

    * default — oldest first ``(arrival, rid)``; victims prefer
      (most reclaimable pages, youngest) — the legacy youngest-first
      preemption every pre-goodput gate was built on;
    * ``slack_of`` set (slack-aware schedulers) — least deadline slack
      first; victims prefer (MOST slack, most reclaimable).  The
      sacrificed request is the one whose class budget tolerates the
      restart best.  ``slack_of`` must be CLOCK-FREE
      (``Request.sacrifice_slack``) or preemption decisions would
      diverge between the wall- and virtual-clock backends.

    Victim membership is tracked in a rid-keyed set — the old
    ``c not in victims`` list scan made this O(n^2) in pool size."""
    if slack_of is None:
        def key(r):
            return (r.arrival, r.rid)

        def vkey(c):
            return (alloc.reclaimable(c.rid), c.arrival, c.rid)
    else:
        def key(r):
            return (slack_of(r), r.rid)

        def vkey(c):
            return (slack_of(c), alloc.reclaimable(c.rid), c.rid)
    victims: List = []
    victim_rids = set()
    order = sorted(pool, key=key)
    for r in order:
        if r.rid in victim_rids:
            continue
        while alloc.extend(r.rid, decode_tokens(r)) is None:
            if cache is not None and cache.evict_one(alloc):
                continue                     # freed a cached page; retry
            later = [c for c in order if c.rid not in victim_rids
                     and c is not r and alloc.holds(c.rid)
                     and key(c) > key(r)
                     and alloc.reclaimable(c.rid) > 0]
            if not later:
                # r is last in the processing order (or nobody after it
                # can free a page) and still starves: it preempts
                # ITSELF — never one ahead of it (those are either
                # older or tighter on deadline)
                alloc.release(r.rid)
                victims.append(r)
                victim_rids.add(r.rid)
                break
            v = max(later, key=vkey)
            alloc.release(v.rid)
            victims.append(v)
            victim_rids.add(v.rid)
    return victims
