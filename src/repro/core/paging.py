"""Paged KV-cache accounting: fixed-size page pool + block tables.

This is the host-side bookkeeping half of the paged decode pool
(DESIGN.md §3).  A :class:`BlockAllocator` owns a free list of
fixed-size pages and a per-request block table; both execution
backends (real JAX engine and the analytic cost model) drive the SAME
allocator logic through :func:`admit_blocks` / :func:`extend_for_decode`
so their admission decisions cannot drift (the backend-parity
invariant).

The paper's Eq. (6) becomes an EXACT block budget here: a request
holding ``t`` live tokens pins ``ceil(t / page_size)`` pages — no
per-slot ``cache_len`` preallocation, which is what lets a 40-token
Alpaca request and a 32k LongBench request share one HBM pool without
the short request paying for the long one's worst case.

Invariants (property-tested in tests/test_paging.py):
  * a page is never assigned to two live requests at once;
  * free + live == total (no leaks);
  * a live request's table holds exactly ``ceil(tokens / page_size)``
    pages.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence


class BlockAllocator:
    """Free-list allocator of fixed-size KV pages with block tables.

    Token-level API: callers say how many tokens a request holds and the
    allocator keeps its table at exactly ``ceil(tokens / page_size)``
    pages.  ``alloc``/``extend`` are all-or-nothing — on exhaustion they
    return None and the allocator state is unchanged (no partial grabs),
    so callers can preempt and retry without unwinding.
    """

    def __init__(self, n_pages: int, page_size: int):
        assert n_pages > 0 and page_size > 0, (n_pages, page_size)
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list: released pages are reused first (locality)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}

    # ----------------------------------------------------------- queries --
    def pages_for(self, tokens: int) -> int:
        return -(-max(tokens, 0) // self.page_size)

    def free_pages(self) -> int:
        return len(self._free)

    def live_pages(self) -> int:
        return sum(len(t) for t in self._tables.values())

    def table(self, rid: int) -> List[int]:
        return list(self._tables.get(rid, ()))

    def holds(self, rid: int) -> bool:
        return rid in self._tables

    # ------------------------------------------------------------- edits --
    def alloc(self, rid: int, tokens: int) -> Optional[List[int]]:
        """Admit ``rid`` with ``tokens`` live tokens.  Returns its block
        table, or None if the pool cannot hold it (state unchanged)."""
        assert rid not in self._tables, f"rid {rid} already live"
        need = self.pages_for(tokens)
        if need > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(need)]
        self._tables[rid] = pages
        return list(pages)

    def extend(self, rid: int, tokens: int) -> Optional[List[int]]:
        """Grow ``rid``'s table to cover ``tokens`` tokens.  Returns the
        NEWLY added pages ([] if already covered), or None on exhaustion
        (state unchanged).  Tables never shrink mid-flight."""
        assert rid in self._tables, f"rid {rid} not live"
        have = self._tables[rid]
        need = max(self.pages_for(tokens), len(have))
        grow = need - len(have)
        if grow > len(self._free):
            return None
        new = [self._free.pop() for _ in range(grow)]
        have.extend(new)
        return new

    def release(self, rid: int) -> int:
        """Free all of ``rid``'s pages; returns how many (0 if unknown —
        release is idempotent so preemption/finish races are harmless)."""
        pages = self._tables.pop(rid, None)
        if pages is None:
            return 0
        self._free.extend(pages)
        return len(pages)


# ------------------------------------------------------- shared policies --
def admit_blocks(alloc: BlockAllocator, requests: Sequence,
                 insert_tokens: Callable[[object], int]) -> int:
    """Admission gate: allocate insert-time pages for a PREFIX of the
    batch; returns how many requests were admitted.  ``insert_tokens``
    maps a request to the tokens its cache holds right after prefill
    (prompt + the first decode write, window-capped).  The loop re-queues
    the rest — the block analogue of the decode-slot clamp."""
    n = 0
    for r in requests:
        if alloc.alloc(r.rid, insert_tokens(r)) is None:
            break
        n += 1
    return n


def extend_for_decode(alloc: BlockAllocator, pool: Sequence,
                      decode_tokens: Callable[[object], int]) -> List:
    """Pre-decode page extension with preemption: grow every pooled
    request's table to cover its next token write; on exhaustion evict
    the YOUNGEST pooled request (latest arrival, then highest rid) and
    retry.  Only requests strictly younger than the one being extended
    are eviction candidates — if the starving request IS the youngest,
    it preempts itself rather than robbing an older request of its
    pages.  Oldest-first processing therefore guarantees the head of
    the pool always progresses (no livelock).  Returns the victims
    (their pages already released); the caller re-queues them."""
    victims: List = []
    order = sorted(pool, key=lambda r: (r.arrival, r.rid))
    for r in order:
        if r in victims:
            continue
        while alloc.extend(r.rid, decode_tokens(r)) is None:
            younger = [c for c in order if c not in victims and c is not r
                       and alloc.holds(c.rid)
                       and (c.arrival, c.rid) > (r.arrival, r.rid)]
            if not younger:
                # r is the youngest live request and still starves: it
                # preempts ITSELF (never an older one — they are closer
                # to finishing and have consumed more work)
                alloc.release(r.rid)
                victims.append(r)
                break
            v = max(younger, key=lambda c: (c.arrival, c.rid))
            alloc.release(v.rid)
            victims.append(v)
    return victims
