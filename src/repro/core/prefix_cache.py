"""Cross-request prefix cache: radix index over refcounted KV pages.

BucketServe's bucket batching cuts padding waste; under realistic
agentic traffic (shared system prompts, few-shot headers) the biggest
waste LEFT is re-prefilling identical prefixes per request.  PR 2's
block tables already let two requests point at the same physical page —
this module adds the machinery that exploits it (DESIGN.md §3, "Prefix
sharing"; Apt-Serve arXiv 2504.07494 reports large admission gains from
exactly this reuse):

* a RADIX/TRIE index keyed on token-id chunks of ``page_size``: node
  depth d holds the physical page whose KV covers prompt positions
  ``[d*page, (d+1)*page)`` for that exact token path.  Page content is
  a pure function of the token prefix (RoPE is applied at write time
  with absolute positions), so any request whose prompt walks the same
  path can reference the same page;
* only FULL pages are ever indexed — the final partial page of a prompt
  is always a private page written by the owner's prefill.  This is the
  copy-on-write rule degenerate-cased away: a shared page is immutable
  by construction, and the mutable tail is never shared;
* the cache holds its own PIN (refcount) on every indexed page, so a
  cached prefix survives its writer's release.  LRU eviction (leaf
  first, zero-external-ref only) returns pages to the allocator when
  admission or decode starves.

Hit capping: a lookup never matches a request's ENTIRE prompt — at
least one suffix token must run through prefill to produce the first
output logits — so the usable match is
``min(matched_pages, (prompt_len - 1) // page_size)``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class PrefixStats:
    """Admission-side accounting (mirrored into ServeResult)."""

    lookups: int = 0           # admitted requests matched against the index
    hits: int = 0              # ... of which matched >= 1 full page
    hit_tokens: int = 0        # total prompt tokens served from cache
    inserted_pages: int = 0    # pages ever pinned into the index
    evictions: int = 0         # pages unpinned by LRU pressure
    peak_shared: int = 0       # max simultaneously shared pages observed


class _Node:
    """One full-page chunk on a token path.  ``key`` is the raw bytes of
    the page's token ids; ``page`` the physical page holding its KV.

    Spill states (PR 5, host tier): LIVE (``hslot is None`` — ``page``
    is a pinned device page), SPILLED (``hslot`` set, ``page`` == -1 —
    content lives in host slot ``hslot``), RESTORING (``hslot`` set AND
    ``page`` >= 0 — a host->device copy into the reserved ``page`` is
    in flight, done at ``ready_at``).  The trie keeps spilled nodes so
    lookups can find — and restore — a spilled continuation of a live
    run.  Structural invariant: every ancestor of a LIVE or RESTORING
    node is LIVE or RESTORING (spill moves leaf-inward, restore moves
    root-outward), so a hit path is always a LIVE prefix followed by at
    most one spilled/restoring run."""

    __slots__ = ("key", "page", "children", "parent", "stamp", "hslot",
                 "ready_at")

    def __init__(self, key: bytes, page: int, parent: "_Node"):
        self.key = key
        self.page = page
        self.children: Dict[bytes, _Node] = {}
        self.parent = parent
        self.stamp = 0
        self.hslot: Optional[int] = None
        self.ready_at: float = -1.0

    @property
    def live(self) -> bool:
        return self.hslot is None

    @property
    def restoring(self) -> bool:
        return self.hslot is not None and self.page >= 0


class PrefixCache:
    """Radix index + LRU eviction over a :class:`BlockAllocator`.

    The cache never owns device memory — it pins allocator pages and
    maps token paths to them.  Both execution backends drive one of
    these through the shared ``paging.admit_blocks`` policy, so hit
    accounting cannot drift between the engine and the cost model."""

    def __init__(self, page_size: int):
        assert page_size > 0
        self.page_size = page_size
        self.root = _Node(b"", -1, None)  # sentinel, never holds a page
        # dict-as-ordered-set (O(1) removal, insertion-ordered
        # iteration): eviction scans once for the LRU evictable leaf
        # but never pays a list.remove on top
        self._nodes: Dict[_Node, None] = {}
        self._clock = 0
        self.stats = PrefixStats()
        # host-drop hook (retention wires the backend copier + stats
        # into it); None for a bare radix with no spill tier
        self.on_host_drop = None

    # ----------------------------------------------------------- helpers --
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _chunk(self, tokens: np.ndarray, j: int) -> bytes:
        p = self.page_size
        return np.ascontiguousarray(
            tokens[j * p:(j + 1) * p], dtype=np.int32).tobytes()

    def __len__(self) -> int:
        return len(self._nodes)

    def pinned_pages(self) -> List[int]:
        return [n.page for n in self._nodes if n.live]

    def spilled_nodes(self) -> int:
        return sum(1 for n in self._nodes if not n.live)

    # ------------------------------------------------------------ lookup --
    def lookup(self, tokens, req=None, alloc=None) -> Tuple[List[int], int]:
        """Longest cached page run for ``tokens``, capped so at least
        one suffix token remains to prefill.  Returns (pages, tokens
        matched); touches the path for LRU.  ``req`` and ``alloc`` are
        part of the shared cache protocol (core/retention.py keys
        session state on the request and reserves restore pages from
        the allocator) and are unused here."""
        pages, _ = self.lookup_run(tokens)
        return pages, len(pages) * self.page_size

    def lookup_run(self, tokens) -> Tuple[List[int], List[_Node]]:
        """The full cached walk for ``tokens``: the LIVE page run, plus
        the SPILLED/RESTORING nodes that continue the same token path
        (the structural invariant guarantees the walk is live-prefix
        then spilled-suffix — a live node can never hide behind a
        spilled one).  The retention layer turns the continuation into
        a restore; a bare radix caller just takes the live run.
        Touches the whole walked path for LRU (spilled nodes too: the
        host-budget LRU ranks them by the same stamps)."""
        tokens = np.asarray(tokens)
        usable_cap = (len(tokens) - 1) // self.page_size
        node, pages, cont = self.root, [], []
        stamp = self._tick()
        for j in range(usable_cap):
            child = node.children.get(self._chunk(tokens, j))
            if child is None:
                break
            if child.live and cont:
                break            # unreachable under the invariant
            child.stamp = stamp
            if child.live:
                pages.append(child.page)
            else:
                cont.append(child)
            node = child
        return pages, cont

    # ---------------------------------------------------------- register --
    def register(self, alloc, tokens, table: List[int]) -> int:
        """Index a freshly prefilled request's FULL prompt pages.  Walks
        the trie along the token path; chunks already present keep their
        canonical page (first-wins — a concurrent cold duplicate's page
        simply stays private); new chunks pin the request's own page.
        A SPILLED chunk on the path is REVIVED for free: the releasing
        request just recomputed the identical KV (page content is a
        pure function of the token path), so the node adopts the fresh
        device page and the host copy is discarded.  A RESTORING chunk
        is left alone — its reserved page's copy is still in flight.
        Returns how many new pages were pinned."""
        tokens = np.asarray(tokens)
        n_full = len(tokens) // self.page_size
        node, added = self.root, 0
        stamp = self._tick()
        for j in range(n_full):
            key = self._chunk(tokens, j)
            child = node.children.get(key)
            if child is None:
                page = table[j]
                alloc.pin(page)
                child = _Node(key, page, node)
                node.children[key] = child
                self._nodes[child] = None
                self.stats.inserted_pages += 1
                added += 1
            elif not child.live and not child.restoring:
                # spilled: adopt the recomputed page, free the host slot
                alloc.pin(table[j])
                self._drop_host(alloc, child.hslot, revived=True)
                child.page = table[j]
                child.hslot = None
                child.ready_at = -1.0
            child.stamp = stamp
            node = child
        return added

    # ---------------------------------------------------------- eviction --
    def _drop_host(self, alloc, hslot: int, revived: bool = False) -> None:
        """Discard one host slot's content; ``on_host_drop`` (wired by
        the retention layer) forwards to the backend copier and the
        spill-drop stats.  ``revived``: the content came back to device
        by recompute, not destruction."""
        ok = alloc.drop_spilled(hslot)
        assert ok, f"host slot {hslot} had a restore in flight"
        if self.on_host_drop is not None:
            self.on_host_drop(hslot, revived)

    def _drop_spilled_subtree(self, alloc, node: _Node) -> None:
        """Remove a node's all-SPILLED subtree (no device pages — only
        host slots return).  Descendants of a spilled node are spilled
        by the structural invariant."""
        for child in list(node.children.values()):
            self._drop_spilled_subtree(alloc, child)
            assert not child.live and not child.restoring, \
                "live/restoring node below a drop point"
            self._drop_host(alloc, child.hslot)
            self._nodes.pop(child, None)
        node.children.clear()

    def _evict_node(self, alloc, node: _Node) -> bool:
        self._drop_spilled_subtree(alloc, node)
        freed = alloc.unpin(node.page)
        assert freed, "evictable leaf had refcount 1 but did not free"
        del node.parent.children[node.key]
        self._nodes.pop(node, None)
        self.stats.evictions += 1
        return freed

    def _evictable(self, alloc, protect) -> List[_Node]:
        """Evictable (destructive drop): a LIVE node with refcount
        exactly 1 (only our pin — no live block table), not in
        ``protect`` (pages matched for the admission in progress), and
        no LIVE or RESTORING child — an interior node on a live path is
        still an ancestor the path needs, but a node whose children are
        all SPILLED is the frontier (dropping it takes its dead spilled
        subtree along)."""
        return [n for n in self._nodes
                if n.live and n.page not in protect
                and alloc.refs(n.page) == 1
                and all(not c.live and not c.restoring
                        for c in n.children.values())]

    # ------------------------------------------------- spill transitions --
    def spill_candidates(self, alloc, protect) -> List[_Node]:
        """Nodes that may move device->host, LRU first: the same
        frontier rule as ``_evictable`` (spill is eviction minus the
        data loss)."""
        return sorted(self._evictable(alloc, set(protect)),
                      key=lambda n: n.stamp)

    def mark_spilled(self, node: _Node, hslot: int) -> None:
        node.page = -1
        node.hslot = hslot
        node.ready_at = -1.0

    def mark_restoring(self, node: _Node, page: int,
                       ready_at: float) -> None:
        node.page = page
        node.ready_at = ready_at

    def mark_live(self, node: _Node) -> None:
        node.hslot = None
        node.ready_at = -1.0

    def lru_spilled_leaf(self) -> Optional[_Node]:
        """LRU candidate for a host-budget drop: a SPILLED node with no
        children at all (dropping an interior spilled node would orphan
        its — equally spilled — descendants)."""
        cands = [n for n in self._nodes
                 if not n.live and not n.restoring and not n.children]
        return min(cands, key=lambda n: n.stamp) if cands else None

    def drop_spilled_node(self, alloc, node: _Node) -> None:
        assert not node.live and not node.restoring and not node.children
        self._drop_host(alloc, node.hslot)
        del node.parent.children[node.key]
        self._nodes.pop(node, None)

    def evict_one(self, alloc, protect=()) -> bool:
        """Evict the least-recently-used evictable leaf; True if a page
        went back to the free list."""
        cands = self._evictable(alloc, set(protect))
        if not cands:
            return False
        return self._evict_node(alloc, min(cands, key=lambda n: n.stamp))

    def evict(self, alloc, need: int, protect=()) -> int:
        """Free up to ``need`` pages, harvesting the evictable leaves
        oldest-stamp-first from ONE scan per generation (evicting a
        whole leaf generation may expose parents as new leaves — the
        outer loop rescans only then).  Returns pages actually freed;
        reclaiming k pages costs O(generations · nodes), not k full
        scans."""
        protect = set(protect)
        freed = 0
        while freed < need:
            cands = self._evictable(alloc, protect)
            if not cands:
                break
            for n in sorted(cands, key=lambda c: c.stamp):
                if freed >= need:
                    break
                freed += self._evict_node(alloc, n)
        return freed

    def clear(self, alloc) -> int:
        """Unpin everything (leaf-first; spilled nodes give back host
        slots, in-flight restores are committed first).  Returns device
        pages freed."""
        freed = 0
        while self._nodes:
            progressed = False
            for n in list(self._nodes):
                if n.children:
                    continue
                if n.restoring:
                    alloc.restore_commit(n.hslot)
                    freed += bool(alloc.unpin(n.page))
                elif n.live:
                    freed += bool(alloc.unpin(n.page))
                else:
                    self._drop_host(alloc, n.hslot)
                del n.parent.children[n.key]
                self._nodes.pop(n, None)
                progressed = True
            assert progressed, "cycle in prefix trie"
        return freed

    # ------------------------------------------------------------- stats --
    def note_admit(self, alloc, req, hit_tokens: int) -> None:
        """Called by ``paging.admit_blocks`` once per ADMITTED request
        (counting only admissions keeps engine/cost-model hit counts
        comparable — both admit identical batches under parity).
        ``req`` is part of the shared cache protocol (the retention
        layer commits its session claim here) and is unused."""
        self.stats.lookups += 1
        if hit_tokens > 0:
            self.stats.hits += 1
            self.stats.hit_tokens += hit_tokens
        self.stats.peak_shared = max(self.stats.peak_shared,
                                     alloc.shared_pages())

    def abort(self, req) -> None:
        """Admission failed after ``lookup`` — nothing to roll back for
        the bare radix (protocol hook for the retention layer)."""

    def pages_saved(self) -> int:
        return self.stats.hit_tokens // self.page_size
