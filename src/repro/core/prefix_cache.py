"""Cross-request prefix cache: radix index over refcounted KV pages.

BucketServe's bucket batching cuts padding waste; under realistic
agentic traffic (shared system prompts, few-shot headers) the biggest
waste LEFT is re-prefilling identical prefixes per request.  PR 2's
block tables already let two requests point at the same physical page —
this module adds the machinery that exploits it (DESIGN.md §3, "Prefix
sharing"; Apt-Serve arXiv 2504.07494 reports large admission gains from
exactly this reuse):

* a RADIX/TRIE index keyed on token-id chunks of ``page_size``: node
  depth d holds the physical page whose KV covers prompt positions
  ``[d*page, (d+1)*page)`` for that exact token path.  Page content is
  a pure function of the token prefix (RoPE is applied at write time
  with absolute positions), so any request whose prompt walks the same
  path can reference the same page;
* only FULL pages are ever indexed — the final partial page of a prompt
  is always a private page written by the owner's prefill.  This is the
  copy-on-write rule degenerate-cased away: a shared page is immutable
  by construction, and the mutable tail is never shared;
* the cache holds its own PIN (refcount) on every indexed page, so a
  cached prefix survives its writer's release.  LRU eviction (leaf
  first, zero-external-ref only) returns pages to the allocator when
  admission or decode starves.

Hit capping: a lookup never matches a request's ENTIRE prompt — at
least one suffix token must run through prefill to produce the first
output logits — so the usable match is
``min(matched_pages, (prompt_len - 1) // page_size)``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass
class PrefixStats:
    """Admission-side accounting (mirrored into ServeResult)."""

    lookups: int = 0           # admitted requests matched against the index
    hits: int = 0              # ... of which matched >= 1 full page
    hit_tokens: int = 0        # total prompt tokens served from cache
    inserted_pages: int = 0    # pages ever pinned into the index
    evictions: int = 0         # pages unpinned by LRU pressure
    peak_shared: int = 0       # max simultaneously shared pages observed


class _Node:
    """One full-page chunk on a token path.  ``key`` is the raw bytes of
    the page's token ids; ``page`` the physical page holding its KV."""

    __slots__ = ("key", "page", "children", "parent", "stamp")

    def __init__(self, key: bytes, page: int, parent: "_Node"):
        self.key = key
        self.page = page
        self.children: Dict[bytes, _Node] = {}
        self.parent = parent
        self.stamp = 0


class PrefixCache:
    """Radix index + LRU eviction over a :class:`BlockAllocator`.

    The cache never owns device memory — it pins allocator pages and
    maps token paths to them.  Both execution backends drive one of
    these through the shared ``paging.admit_blocks`` policy, so hit
    accounting cannot drift between the engine and the cost model."""

    def __init__(self, page_size: int):
        assert page_size > 0
        self.page_size = page_size
        self.root = _Node(b"", -1, None)  # sentinel, never holds a page
        # dict-as-ordered-set (O(1) removal, insertion-ordered
        # iteration): eviction scans once for the LRU evictable leaf
        # but never pays a list.remove on top
        self._nodes: Dict[_Node, None] = {}
        self._clock = 0
        self.stats = PrefixStats()

    # ----------------------------------------------------------- helpers --
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _chunk(self, tokens: np.ndarray, j: int) -> bytes:
        p = self.page_size
        return np.ascontiguousarray(
            tokens[j * p:(j + 1) * p], dtype=np.int32).tobytes()

    def __len__(self) -> int:
        return len(self._nodes)

    def pinned_pages(self) -> List[int]:
        return [n.page for n in self._nodes]

    # ------------------------------------------------------------ lookup --
    def lookup(self, tokens, req=None) -> Tuple[List[int], int]:
        """Longest cached page run for ``tokens``, capped so at least
        one suffix token remains to prefill.  Returns (pages, tokens
        matched); touches the path for LRU.  ``req`` is part of the
        shared cache protocol (core/retention.py keys session state on
        it) and is unused here."""
        tokens = np.asarray(tokens)
        usable_cap = (len(tokens) - 1) // self.page_size
        node, pages = self.root, []
        stamp = self._tick()
        for j in range(usable_cap):
            child = node.children.get(self._chunk(tokens, j))
            if child is None:
                break
            child.stamp = stamp
            pages.append(child.page)
            node = child
        return pages, len(pages) * self.page_size

    # ---------------------------------------------------------- register --
    def register(self, alloc, tokens, table: List[int]) -> int:
        """Index a freshly prefilled request's FULL prompt pages.  Walks
        the trie along the token path; chunks already present keep their
        canonical page (first-wins — a concurrent cold duplicate's page
        simply stays private); new chunks pin the request's own page.
        Returns how many new pages were pinned."""
        tokens = np.asarray(tokens)
        n_full = len(tokens) // self.page_size
        node, added = self.root, 0
        stamp = self._tick()
        for j in range(n_full):
            key = self._chunk(tokens, j)
            child = node.children.get(key)
            if child is None:
                page = table[j]
                alloc.pin(page)
                child = _Node(key, page, node)
                node.children[key] = child
                self._nodes[child] = None
                self.stats.inserted_pages += 1
                added += 1
            child.stamp = stamp
            node = child
        return added

    # ---------------------------------------------------------- eviction --
    def _evict_node(self, alloc, node: _Node) -> bool:
        freed = alloc.unpin(node.page)
        assert freed, "evictable leaf had refcount 1 but did not free"
        del node.parent.children[node.key]
        self._nodes.pop(node, None)
        self.stats.evictions += 1
        return freed

    def _evictable(self, alloc, protect) -> List[_Node]:
        """Evictable: a LEAF (an interior node is still an ancestor on
        live paths) whose page has refcount exactly 1 (only our pin — no
        live block table) and is not in ``protect`` (pages matched for
        the admission in progress)."""
        return [n for n in self._nodes
                if not n.children and n.page not in protect
                and alloc.refs(n.page) == 1]

    def evict_one(self, alloc, protect=()) -> bool:
        """Evict the least-recently-used evictable leaf; True if a page
        went back to the free list."""
        cands = self._evictable(alloc, set(protect))
        if not cands:
            return False
        return self._evict_node(alloc, min(cands, key=lambda n: n.stamp))

    def evict(self, alloc, need: int, protect=()) -> int:
        """Free up to ``need`` pages, harvesting the evictable leaves
        oldest-stamp-first from ONE scan per generation (evicting a
        whole leaf generation may expose parents as new leaves — the
        outer loop rescans only then).  Returns pages actually freed;
        reclaiming k pages costs O(generations · nodes), not k full
        scans."""
        protect = set(protect)
        freed = 0
        while freed < need:
            cands = self._evictable(alloc, protect)
            if not cands:
                break
            for n in sorted(cands, key=lambda c: c.stamp):
                if freed >= need:
                    break
                freed += self._evict_node(alloc, n)
        return freed

    def clear(self, alloc) -> int:
        """Unpin everything (leaf-first).  Returns pages freed."""
        freed = 0
        while self._nodes:
            progressed = False
            for n in list(self._nodes):
                if n.children:
                    continue
                freed += bool(alloc.unpin(n.page))
                del n.parent.children[n.key]
                self._nodes.pop(n, None)
                progressed = True
            assert progressed, "cycle in prefix trie"
        return freed

    # ------------------------------------------------------------- stats --
    def note_admit(self, alloc, req, hit_tokens: int) -> None:
        """Called by ``paging.admit_blocks`` once per ADMITTED request
        (counting only admissions keeps engine/cost-model hit counts
        comparable — both admit identical batches under parity).
        ``req`` is part of the shared cache protocol (the retention
        layer commits its session claim here) and is unused."""
        self.stats.lookups += 1
        if hit_tokens > 0:
            self.stats.hits += 1
            self.stats.hit_tokens += hit_tokens
        self.stats.peak_shared = max(self.stats.peak_shared,
                                     alloc.shared_pages())

    def abort(self, req) -> None:
        """Admission failed after ``lookup`` — nothing to roll back for
        the bare radix (protocol hook for the retention layer)."""

    def pages_saved(self) -> int:
        return self.stats.hit_tokens // self.page_size
