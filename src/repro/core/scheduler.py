"""P/D scheduler: bucket-aware prefill batching + continuous-batching
decode, with prefill->decode KV transfer (paper §III "P/D Scheduler").

The scheduler is pure policy — no clocks, no devices.  The unified
ServingLoop (core/serving_loop.py) drives it against either execution
backend (cost model or real JAX engine):

    on_arrival(req, now[, requeue])  assign to bucket (Algorithm 1 insert)
    next_prefill_batch(now, ...)     adjust buckets, pick bucket, form batch
    (decode admission is slot-based continuous batching in the loop)

Bucket choice: ONLINE requests first (bucket holding the earliest-arrived
online request — paper: "online tasks prioritize buckets based on
earliest request arrival time"); otherwise offline buckets ordered by the
configured within-bucket policy (SJF for RPS, LJF for token throughput).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import ModelConfig
from .batcher import DynamicBatchController, FormedBatch, MemoryBudget
from .bucket import Bucket, BucketManager
from .monitor import GlobalMonitor
from .request import Request, TaskType
from .telemetry import NULL_TRACER


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    offline_policy: str = "sjf"          # sjf | ljf  (paper §II-B)
    theta: float = 0.5                   # Algorithm 1 split threshold
    assignment: str = "linear"           # linear (paper) | bisect (beyond)
    refine: str = "midpoint"             # midpoint (paper) | eq4 (beyond)
    trigger: str = "majority"            # majority (paper) | waste (beyond)
    memory_model: str = "sum"            # sum (Eq. 6) | padded | paged
    page_size: int = 128                 # KV page (memory_model="paged")
    max_batch: int = 512
    decode_reserve: float = 0.5
    kv_transfer_bw: float = 50e9         # ICI per link (TPU adaptation)


class SchedulerBase:
    """Loop-facing scheduler surface (DESIGN.md §2): everything the
    ServingLoop drives — arrival/requeue bookkeeping, decode-pool
    accounting, OOM retry backoff — lives here ONCE; policies supply the
    queue structure (``_enqueue``/``queued``) and batch formation
    (``next_prefill_batch``).  Pure policy: no clocks, no devices."""

    name = "base"
    #: Deadline-slack scheduling (DESIGN.md §8): when True, the
    #: ServingLoop arms slack-aware sacrifice ordering in the backend
    #: (extend_for_decode victims, retention rungs, restore-hold
    #: release).  Base schedulers keep the legacy youngest-first/LRU
    #: orderings so existing gates are untouched.
    slack_aware = False

    def __init__(self, cfg: ModelConfig, budget: MemoryBudget, *,
                 memory_model: str = "sum", max_batch: int = 512,
                 decode_reserve: float = 0.5, page_size: int = 128):
        self.cfg = cfg
        self.batcher = DynamicBatchController(
            cfg, budget, memory_model=memory_model, max_batch=max_batch,
            decode_reserve=decode_reserve, page_size=page_size)
        self.monitor = GlobalMonitor()
        self.monitor.kv_budget_tokens = self.batcher.token_budget()
        # event-timeline seam (core/telemetry.py): the ServingLoop
        # overwrites this with its live Tracer when tracing is on
        self.tracer = NULL_TRACER
        self._last_n_max = -1

    # ------------------------------------------------------------ events --
    def _enqueue(self, req: Request) -> None:
        raise NotImplementedError

    def queued(self) -> int:
        raise NotImplementedError

    def next_prefill_batch(self, now: float) -> Optional[FormedBatch]:
        raise NotImplementedError

    def on_arrival(self, req: Request, now: float,
                   requeue: bool = False) -> None:
        """Queue a request.  ``requeue=True`` marks a re-admission (OOM
        eviction, slot clamp): the request re-enters the queue but the
        monitor's arrival-rate / seq-len workload stats are NOT
        re-counted."""
        self._enqueue(req)
        if requeue:
            self.monitor.on_requeue()
        else:
            self.monitor.on_arrival(now, req.prompt_len)

    # ----------------------------------------------------- OOM backoff ----
    def notify_oom(self) -> None:
        """Retry backoff every real system has: shrink the admission cap."""
        self._oom_shrink = max(0.4, getattr(self, "_oom_shrink", 1.0) * 0.85)

    def notify_dispatch(self) -> None:
        """A batch actually dispatched: step the backoff recovery.  The
        loop calls this ONCE per successful dispatch — recovery must not
        advance on ticks that form no batch (the old ``_cap_scale``
        mutated on every read, so idle polling silently restored the cap
        while nothing had been proven safe)."""
        self._oom_shrink = min(1.0, getattr(self, "_oom_shrink", 1.0) * 1.02)

    def _cap_scale(self) -> float:
        """Pure read of the current OOM-shrink factor."""
        return getattr(self, "_oom_shrink", 1.0)

    # -------------------------------------------------- decode admission --
    def _pressure_tokens(self) -> int:
        """Restore-aware admission pricing: Eq.-(6) token-equivalents
        of the in-flight host-tier restore state (reserved device pages
        + compressed channel backlog) the monitor's plain in-flight sum
        misses.  Added to ``in_flight_tokens`` wherever Eq. (6) is
        consulted, so admission leaves headroom for restores about to
        land instead of racing them for the same pages."""
        return self.batcher.admission_pressure_tokens(
            self.monitor.restore_pages_in_flight,
            self.monitor.restore_backlog_bytes)

    def _live_tokens(self, req: Request) -> int:
        """In-flight KV tokens a live request is charged: prompt +
        output, capped at the sliding/local window (a ring cache never
        holds more than the window — EVERY scheduler serves windowed
        configs, so the cap lives here in the base, not in a
        policy-specific override that baselines silently miss),
        page-granular under the paged memory model, discounted by the
        shared prefix-cache hit."""
        tokens = req.prompt_len + req.max_new_tokens
        win = self.cfg.sliding_window or (
            self.cfg.local_window if self.cfg.arch_type == "hybrid" else 0)
        if win:
            tokens = min(tokens, win)
        return self.batcher.charge_tokens(tokens, req.prefix_hit_tokens)

    def admit_decode(self, req: Request) -> None:
        self.monitor.decode_pool += 1
        self.monitor.in_flight_tokens += self._live_tokens(req)

    def release_decode(self, req: Request) -> None:
        self.monitor.decode_pool -= 1
        self.monitor.in_flight_tokens -= self._live_tokens(req)


class BucketServeScheduler(SchedulerBase):
    """The paper's middleware: Bucketing Manager + Batching Controller."""

    name = "bucketserve"

    def __init__(self, cfg: ModelConfig, budget: MemoryBudget,
                 sched: SchedulerConfig = SchedulerConfig()):
        super().__init__(cfg, budget, memory_model=sched.memory_model,
                         max_batch=sched.max_batch,
                         decode_reserve=sched.decode_reserve,
                         page_size=sched.page_size)
        self.sched = sched
        self.buckets = BucketManager(
            l_max=cfg.max_seq_len, theta=sched.theta,
            assignment=sched.assignment, refine=sched.refine,
            trigger=sched.trigger)

    # ------------------------------------------------------------ events --
    def _enqueue(self, req: Request) -> None:
        self.buckets.add(req)            # Algorithm 1 insert

    def queued(self) -> int:
        return self.buckets.total()

    # -------------------------------------------------------- scheduling --
    def _n_max(self) -> int:
        return self.batcher.n_max(
            self.monitor.mean_seq_len(),
            self.monitor.in_flight_tokens + self._pressure_tokens())

    def _pick_bucket(self, now: float) -> Optional[Bucket]:
        """Bucket choice per scheduling tick.  The earliest-online
        arrival per bucket is maintained INCREMENTALLY by the
        BucketManager (O(1) on add, recomputed only for buckets that
        lose members) — the old ``min(r.arrival for r in b.requests)``
        here rescanned every queued request in every bucket on every
        tick, O(total queued) per tick."""
        nonempty = self.buckets.nonempty()
        if not nonempty:
            return None
        online = [b for b in nonempty if b.earliest_online() is not None]
        if online:
            return min(online, key=lambda b: b.earliest_online())
        if self.sched.offline_policy == "sjf":
            return min(nonempty, key=lambda b: b.low)
        return max(nonempty, key=lambda b: b.up)

    def _order_bucket(self, b: Bucket, now: float):
        """Within-bucket candidate ordering (the form_batch greedy packs
        a prefix of this list) — the policy hook subclasses override."""
        has_online = b.earliest_online() is not None
        policy = "fcfs" if has_online else self.sched.offline_policy
        return self.buckets.order_bucket(b, policy)

    def next_prefill_batch(self, now: float) -> Optional[FormedBatch]:
        """One scheduling tick: Algorithm 1 adjust + batch formation."""
        n_max = self._n_max()
        if self.tracer.enabled and n_max != self._last_n_max:
            self.tracer.counter("controller", "n_max", now,
                                {"n_max": n_max})
            self._last_n_max = n_max
        self.buckets.adjust(n_max)
        self.monitor.n_buckets = len(self.buckets.buckets)
        b = self._pick_bucket(now)
        if b is None:
            return None
        ordered = self._order_bucket(b, now)
        batch = self.batcher.form_batch(
            ordered, self.monitor.in_flight_tokens + self._pressure_tokens())
        if not batch.requests:
            return None
        batch.bucket = b
        self.buckets.pop(batch.requests)
        self.monitor.queue_len -= len(batch.requests)
        return batch

    # ------------------------------------------------------- KV transfer --
    def kv_transfer_seconds(self, batch: FormedBatch) -> float:
        """Prefill->decode cache move over ICI (TPU adaptation of the
        paper's NVLink transfer)."""
        bytes_ = sum(r.prompt_len for r in batch.requests) * \
            self.batcher.kv_per_tok
        return bytes_ / self.sched.kv_transfer_bw


class GoodputScheduler(BucketServeScheduler):
    """Deadline-slack goodput scheduler (DESIGN.md §8).

    Same Bucketing Manager + Eq.-(6) Batching Controller as BucketServe
    — batches stay size-homogeneous — but candidate ORDER inside the
    picked bucket (and the bucket pick itself) is driven by per-request
    deadline urgency instead of arrival order:

        urgency  = waited / slo_ttft        (class-normalized queue age)
        bonus    = 1 - tokens_left / ref    (short jobs retire SLOs fast)
        priority = urgency + bonus

    the SLA-constrained priority-scheduler shape (arXiv 2503.05248):
    normalizing the wait by the CLASS budget is what lets a 2 s-TTFT
    chat request overtake a 120 s-budget batch job that arrived first.
    ``waited`` anchors on ``Request.t0()`` (the ledger's first-arrival
    stamp), so OOM/preempt requeues cannot silently reset urgency.

    Force-include SLA protection, in three tiers.  A request whose
    remaining slack has shrunk below ``force_frac`` of its class budget
    but is STILL WINNABLE sorts ahead of every unforced candidate
    regardless of score — the form_batch greedy packs a prefix of the
    ordering, so forced requests can only be excluded by the memory
    bound itself.  A request already PAST its deadline is the
    opposite case: it can never earn goodput again, so it demotes
    below every winnable candidate instead of clogging the front of
    the queue (it still gets served — whenever no winnable work is
    queued — so throughput is shed last, not first).

    ``slack_aware = True`` additionally flips every sacrifice point the
    ServingLoop arms (extend_for_decode victims, retention rungs,
    restore-hold release) to slack ordering — see
    ``Request.sacrifice_slack`` for why those use a clock-free proxy.
    """

    name = "goodput"
    slack_aware = True
    #: tokens_left normalizer for the short-job bonus: one full
    #: normalizer of remaining decode work cancels one full TTFT budget
    #: of queue age (the exemplar's ``(10 - tokens_left)/10`` shape,
    #: scaled to this repo's output lengths).
    short_job_ref = 256.0
    #: force-include threshold: a winnable request whose remaining
    #: slack is below this fraction of its class budget jumps every
    #: unforced candidate.
    force_frac = 0.3

    # ------------------------------------------------------------ scoring --
    def _priority(self, r: Request, now: float) -> float:
        waited = max(now - r.t0(), 0.0)
        urgency = waited / max(r.slo_ttft, 1e-9)
        left = max(r.max_new_tokens - r.generated, 0)
        bonus = max(0.0, 1.0 - left / self.short_job_ref)
        return urgency + bonus

    def _tier(self, r: Request, now: float) -> int:
        """+1 forced (winnable, nearly late), 0 normal, -1 past its
        deadline (can never earn goodput — served when nothing winnable
        queues).  The budget normalizing the slack is the phase's own:
        TTFT before the first token, the remaining-token TPOT budget
        after (a slice-yielded request re-queues mid-generation)."""
        budget = r.slo_ttft if r.first_token < 0 \
            else r.slo_tpot * max(r.max_new_tokens - 1, 1)
        ratio = r.slack(now) / max(budget, 1e-9)
        if ratio <= 0.0:
            return -1
        return 1 if ratio <= self.force_frac else 0

    def _score_key(self, r: Request, now: float):
        # rid tiebreak keeps the ordering fully deterministic (and
        # backend-independent when scores tie)
        return (self._tier(r, now), self._priority(r, now), -r.rid)

    # ----------------------------------------------------------- ordering --
    def _order_bucket(self, b: Bucket, now: float):
        return sorted(b.requests,
                      key=lambda r: self._score_key(r, now), reverse=True)

    def _pick_bucket(self, now: float) -> Optional[Bucket]:
        """The bucket holding the most urgent candidate wins — batches
        stay homogeneous (one bucket per batch), urgency just decides
        WHICH bucket forms next."""
        nonempty = self.buckets.nonempty()
        if not nonempty:
            return None
        return max(nonempty,
                   key=lambda b: max(self._score_key(r, now)
                                     for r in b.requests))

    # ------------------------------------------------------------- gauges --
    def next_prefill_batch(self, now: float) -> Optional[FormedBatch]:
        slacks = [r.slack(now)
                  for b in self.buckets.nonempty() for r in b.requests]
        if slacks:
            self.monitor.on_slack(min(slacks))
        return super().next_prefill_batch(now)

    def _pressure_tokens(self) -> int:
        """Slack-aware restore pricing: when the queue's minimum slack
        is tight, the restore-backlog admission throttle is relaxed —
        protecting a restore's resume-TTFT is pointless while a
        deadline-critical request starves in the queue."""
        return self.batcher.admission_pressure_tokens(
            self.monitor.restore_pages_in_flight,
            self.monitor.restore_backlog_bytes,
            min_slack=self.monitor.min_slack_s)
