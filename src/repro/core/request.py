"""Request model for the serving system."""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from .telemetry import LatencyLedger


class TaskType(enum.Enum):
    ONLINE = "online"     # latency-sensitive, SLO-bound
    OFFLINE = "offline"   # throughput-oriented


@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt_len: int                      # S in the paper
    max_new_tokens: int
    arrival: float                       # seconds
    task_type: TaskType = TaskType.ONLINE
    slo_ttft: float = 2.0                # time-to-first-token SLO (s)
    slo_tpot: float = 0.2                # time-per-output-token SLO (s)
    tokens: Optional[np.ndarray] = None  # actual token ids (real engine)
    # request class tag for heterogeneous traffic (data/workload.py /
    # data/trace.py): "chat" | "longctx" | "batch" | "" (untagged
    # classic workloads).  The SLO budgets above are per-class under a
    # heterogeneous mix and travel WITH the request through trace
    # record/replay, so tail gates and the SLO scheduler read budgets
    # off the request, never off a workload-global spec.
    cls: str = ""

    # --- multi-turn sessions (core/retention.py) ---
    # Turn t (> 0) of a conversation: its prompt is the FULL transcript
    # of turns 0..t-1 (prompt + generated tokens) followed by this
    # turn's new user ``utterance``.  The transcript part cannot be
    # known until the previous turn finishes, so ``tokens`` stays None
    # and the ServingLoop composes it at unlock time; ``prompt_len`` IS
    # known up front (the loop always generates exactly
    # ``max_new_tokens``), which keeps batch formation deterministic.
    session_id: Optional[int] = None     # conversation key (None = one-shot)
    turn: int = 0                        # 0-based turn index in the session
    think_gap: float = 0.0               # arrival delay after prior finish
    utterance: Optional[np.ndarray] = None  # this turn's NEW user tokens
    history_tokens: int = 0              # leading prompt tokens that are
    #                                      prior transcript (0 for turn 0)

    # --- lifecycle (filled by scheduler/engine) ---
    # prompt tokens served from the cross-request prefix cache at the
    # LAST admission (page-aligned unless a session tail was restored;
    # 0 = cold).  Set by paging.admit_blocks, reset when a preemption
    # re-queues the request.
    prefix_hit_tokens: int = 0
    # transcript tokens restored from the SESSION table at the last
    # admission (includes the pinned partial tail; 0 = no session hit)
    session_hit_tokens: int = 0
    # host-spill restore in flight (core/retention.py): the clock time
    # when the pages this request's hit continues into finish their
    # host->device copy.  >= 0 means HELD — the loop parks the request
    # instead of admitting it to re-prefill restorable KV; reset to -1
    # when it re-enters the queue.
    spill_wait: float = -1.0
    # padded prompt tokens this request actually ran through the
    # prefill executor (accumulates across preemption restarts)
    prefilled_tokens: int = 0
    # slice-boundary preemption (DESIGN.md §8): generated tokens that
    # were PROMOTED into the prompt when a mid-generation yield
    # preserved work — ``tokens[:prompt_len]`` then ends with
    # ``sliced_tokens`` already-generated ids, and the true user prompt
    # is ``prompt_len - sliced_tokens`` tokens.  0 = never sliced.
    sliced_tokens: int = 0
    # per-request phase attribution (core/telemetry.py): the ServingLoop
    # installs a fresh ledger at run start and stamps every transition;
    # phase durations sum to (retirement - first arrival) — the
    # conservation invariant the observability tests assert.  ``arrival``
    # above is OVERWRITTEN on requeue/preempt; the ledger's ``t0`` keeps
    # the original.
    ledger: Optional[LatencyLedger] = None
    prefill_start: float = -1.0
    first_token: float = -1.0
    finished: float = -1.0
    generated: int = 0
    dropped: bool = False
    # --- fault/recovery bookkeeping (core/faults.py, core/recovery.py) ---
    # consecutive faults this request absorbed since its last clean
    # chunk (reset on success); reaching RecoveryPolicy.quarantine_after
    # marks it poisoned and it is dropped with a closed ledger rather
    # than allowed to wedge the loop
    fault_streak: int = 0
    quarantined: bool = False
    # drain/resume (core/recovery.py LoopCheckpoint): original ledger t0
    # carried across the checkpoint so deadlines do NOT reset on the
    # cold loop — ``run()`` re-anchors the fresh ledger here (< 0: none)
    t0_anchor: float = -1.0

    @property
    def S(self) -> int:
        return self.prompt_len

    def t0(self) -> float:
        """FIRST arrival — the deadline anchor.  Requeue paths
        (OOM/preempt restart penalties) overwrite ``arrival``; anchoring
        SLOs there would silently extend every deadline a requeue
        touches.  The ledger keeps the original stamp."""
        if self.ledger is not None and self.ledger.started:
            return self.ledger.t0
        return self.arrival

    def ttft(self) -> float:
        return self.first_token - self.t0() if self.first_token >= 0 \
            else float("inf")

    def tpot(self) -> float:
        if self.finished < 0 or self.generated <= 1:
            return 0.0
        return (self.finished - self.first_token) / max(self.generated - 1, 1)

    def e2e(self) -> float:
        return self.finished - self.t0() if self.finished >= 0 \
            else float("inf")

    # ------------------------------------------------ deadline slack ------
    def ttft_slack(self, now: float) -> float:
        """Seconds until the TTFT budget is blown (negative = late)."""
        return self.slo_ttft - (now - self.t0())

    def tpot_slack(self, now: float) -> float:
        """Seconds of per-token budget remaining: the class allows
        ``slo_tpot`` per generated token after the first."""
        budget = self.slo_tpot * max(self.generated - 1, 1)
        return budget - (now - self.first_token)

    def slack(self, now: float) -> float:
        """Live deadline slack: TTFT slack before the first token,
        per-token TPOT slack after."""
        if self.first_token < 0:
            return self.ttft_slack(now)
        return self.tpot_slack(now)

    def sacrifice_slack(self) -> float:
        """CLOCK-FREE slack proxy for victim/eviction ordering.

        Live ``slack(now)`` depends on the backend clock (wall vs
        virtual seconds), so ordering sacrifices by it would break
        engine-vs-sim parity on preemption decisions.  This proxy ranks
        by how much budget the CLASS still grants — the full TTFT
        budget before the first token, the remaining-token TPOT budget
        after — which depends only on class budgets and token counts,
        both parity-equal.  Larger = more tolerant of being sacrificed.
        """
        if self.first_token < 0:
            return self.slo_ttft
        return self.slo_tpot * max(self.max_new_tokens - self.generated, 1)

    def slo_met(self) -> bool:
        """SLO attainment: both TTFT and per-token latency within bound."""
        if self.finished < 0 or self.dropped:
            return False
        return self.ttft() <= self.slo_ttft and self.tpot() <= self.slo_tpot

    def materialize_tokens(self, vocab_size: int) -> None:
        """Fill in concrete prompt token ids when the workload supplied
        none.  THE one seeding rule shared by every execution backend —
        the prefix cache's radix index keys on these ids, so any drift
        between backends would silently break hit-count parity.

        A later session turn (``utterance`` set, ``tokens`` None) is
        deliberately left alone: its prompt is the prior transcript +
        utterance, composed by the ServingLoop when the previous turn
        finishes — random ids here would break transcript reuse."""
        if self.tokens is None and self.utterance is None:
            rng = np.random.default_rng(self.rid)
            self.tokens = rng.integers(
                0, vocab_size, self.prompt_len).astype(np.int32)
