"""Request model for the serving system."""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np


class TaskType(enum.Enum):
    ONLINE = "online"     # latency-sensitive, SLO-bound
    OFFLINE = "offline"   # throughput-oriented


@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt_len: int                      # S in the paper
    max_new_tokens: int
    arrival: float                       # seconds
    task_type: TaskType = TaskType.ONLINE
    slo_ttft: float = 2.0                # time-to-first-token SLO (s)
    slo_tpot: float = 0.2                # time-per-output-token SLO (s)
    tokens: Optional[np.ndarray] = None  # actual token ids (real engine)

    # --- lifecycle (filled by scheduler/engine) ---
    # prompt tokens served from the cross-request prefix cache at the
    # LAST admission (page-aligned; 0 = cold).  Set by
    # paging.admit_blocks, reset when a preemption re-queues the request.
    prefix_hit_tokens: int = 0
    prefill_start: float = -1.0
    first_token: float = -1.0
    finished: float = -1.0
    generated: int = 0
    dropped: bool = False

    @property
    def S(self) -> int:
        return self.prompt_len

    def ttft(self) -> float:
        return self.first_token - self.arrival if self.first_token >= 0 else float("inf")

    def tpot(self) -> float:
        if self.finished < 0 or self.generated <= 1:
            return 0.0
        return (self.finished - self.first_token) / max(self.generated - 1, 1)

    def e2e(self) -> float:
        return self.finished - self.arrival if self.finished >= 0 else float("inf")

    def slo_met(self) -> bool:
        """SLO attainment: both TTFT and per-token latency within bound."""
        if self.finished < 0 or self.dropped:
            return False
        return self.ttft() <= self.slo_ttft and self.tpot() <= self.slo_tpot

    def materialize_tokens(self, vocab_size: int) -> None:
        """Fill in concrete prompt token ids when the workload supplied
        none.  THE one seeding rule shared by every execution backend —
        the prefix cache's radix index keys on these ids, so any drift
        between backends would silently break hit-count parity."""
        if self.tokens is None:
            rng = np.random.default_rng(self.rid)
            self.tokens = rng.integers(
                0, vocab_size, self.prompt_len).astype(np.int32)
