"""Recovery policies + checkpointed drain/resume (DESIGN.md §9).

Companion to core/faults.py: the injector decides WHEN the substrate
fails, this module decides WHAT the loop does about it —

* :class:`RecoveryPolicy` — bounded retry with exponential backoff for
  transient faults, the deadline-slack shed rule (a retry that cannot
  beat the request's remaining SLO budget sheds to cold recompute
  instead of burning the restore channel), poisoned-request quarantine
  after K consecutive faults, and the restore-hold timeout that keeps a
  stalled PCIe channel from parking requests forever.

* :class:`LoopCheckpoint` — the serializable drain artifact: every
  unfinished request (with slice-boundary work promoted into its
  prompt), held future session turns, the retention layer's session
  transcripts, the radix spill inventory, and the drain clock.  A COLD
  loop resumes from it: requests re-enter in original arrival order
  with their deadline anchors (``Request.t0_anchor``) preserved —
  requeues and drains never extend a deadline — and continuation token
  ids are bit-identical because preserved work re-enters as prompt
  prefix at identical absolute positions (the PR 9 slice-resume
  argument, applied across a process boundary).

The checkpoint is plain JSON: nothing in it references live objects,
device memory, or clocks other than the recorded drain time, so it can
cross a process/replica boundary — the failover primitive the
multi-replica ROADMAP item composes.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

import numpy as np

from .request import Request, TaskType

CHECKPOINT_VERSION = 1


# ---------------------------------------------------------------- policy --
@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Knobs for every recovery decision.  Frozen so a policy can be
    shared between the loop and the retention layer without aliasing
    surprises."""

    max_retries: int = 3           # bounded retry per faulted operation
    backoff_base: float = 0.05     # first retry delay (virtual seconds)
    backoff_factor: float = 2.0
    backoff_cap: float = 2.0       # ceiling on any single backoff
    quarantine_after: int = 6      # consecutive faults -> poisoned request
    restore_timeout: float = 30.0  # max restore-hold before cold re-prefill

    def backoff(self, attempt: int) -> float:
        """Delay before retry number ``attempt`` (0-based)."""
        return min(self.backoff_cap,
                   self.backoff_base * self.backoff_factor ** attempt)

    def should_shed(self, slack_remaining: float, eta: float) -> bool:
        """The slack rule: shed (fall back to recompute / drop the
        retry) when the operation's completion ``eta`` seconds from now
        cannot beat the request's remaining SLO budget.  A request
        already past its budget sheds unconditionally — burning the
        channel for it steals bandwidth from winnable work."""
        return eta > max(slack_remaining, 0.0)


DEFAULT_RECOVERY = RecoveryPolicy()


# ------------------------------------------------------- (de)serialization --
def _arr(x: Optional[np.ndarray]) -> Optional[List[int]]:
    return None if x is None else [int(v) for v in np.asarray(x)]


def _req_to_dict(r: Request, now: float) -> Dict[str, Any]:
    """Snapshot one unfinished request for the checkpoint.  Execution
    state (pages, slots, outputs) is deliberately ABSENT: preserved
    work lives in the prompt (slice promotion ran before this), so a
    cold backend rebuilds everything from token ids."""
    return {
        "rid": int(r.rid), "prompt_len": int(r.prompt_len),
        "max_new_tokens": int(r.max_new_tokens),
        # past arrivals resume immediately; future ones (think-time
        # gaps, unreleased turns) keep their stamp
        "arrival": float(max(r.arrival, 0.0)),
        "task_type": r.task_type.value,
        "slo_ttft": float(r.slo_ttft), "slo_tpot": float(r.slo_tpot),
        "cls": r.cls,
        "tokens": _arr(r.tokens),
        "session_id": r.session_id, "turn": int(r.turn),
        "think_gap": float(r.think_gap),
        "utterance": _arr(r.utterance),
        "history_tokens": int(r.history_tokens),
        "sliced_tokens": int(r.sliced_tokens),
        "generated": int(r.generated),
        # deadline anchor: first arrival from the ledger when it
        # started, else the (possibly future) arrival itself
        "t0_anchor": float(r.ledger.t0 if r.ledger is not None
                           and r.ledger.started else -1.0),
    }


def _req_from_dict(d: Dict[str, Any]) -> Request:
    toks = d["tokens"]
    utt = d["utterance"]
    return Request(
        rid=d["rid"], prompt_len=d["prompt_len"],
        max_new_tokens=d["max_new_tokens"], arrival=d["arrival"],
        task_type=TaskType(d["task_type"]),
        slo_ttft=d["slo_ttft"], slo_tpot=d["slo_tpot"], cls=d["cls"],
        tokens=None if toks is None else np.asarray(toks, dtype=np.int32),
        session_id=d["session_id"], turn=d["turn"],
        think_gap=d["think_gap"],
        utterance=None if utt is None else np.asarray(utt, dtype=np.int32),
        history_tokens=d["history_tokens"],
        sliced_tokens=d["sliced_tokens"],
        generated=d["generated"],
        t0_anchor=d["t0_anchor"],
    )


# ------------------------------------------------------------ checkpoint --
@dataclasses.dataclass
class LoopCheckpoint:
    """Serializable drain state (see module docstring)."""

    now: float                                  # drain clock time
    requests: List[Dict[str, Any]]              # unfinished, work promoted
    held_turns: List[Dict[str, Any]]            # future session turns
    sessions: List[Dict[str, Any]]              # retention transcripts
    radix_spilled: int                          # spilled nodes at drain
    tails_demoted: int                          # tails pushed host-ward
    version: int = CHECKPOINT_VERSION

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, s: str) -> "LoopCheckpoint":
        d = json.loads(s)
        assert d.get("version") == CHECKPOINT_VERSION, d.get("version")
        return cls(**d)

    def restore_requests(self) -> List[Request]:
        """Materialize the cold-loop request set: queued/in-flight
        requests plus the held future turns, in one list the loop's
        ``run()`` accepts (it re-splits held turns itself)."""
        reqs = [_req_from_dict(d) for d in self.requests]
        reqs += [_req_from_dict(d) for d in self.held_turns]
        reqs.sort(key=lambda r: (r.arrival, r.rid))
        return reqs


def build_checkpoint(loop, now: float) -> LoopCheckpoint:
    """Assemble a :class:`LoopCheckpoint` from a quiesced loop (every
    in-flight request already reset/promoted by ``ServingLoop.drain``).
    Separated from the loop so the serialization surface stays in one
    reviewable place."""
    held_keys = set()
    held = []
    for (sid, turn), r in sorted(loop._held.items()):
        held_keys.add(r.rid)
        held.append(_req_to_dict(r, now))
    live = [r for r in loop._requests
            if r.finished < 0 and not r.dropped and r.rid not in held_keys]
    live.sort(key=lambda r: (r.arrival, r.rid))
    sessions = []
    rt = getattr(loop.backend, "retention", None)
    spilled_nodes = 0
    if rt is not None:
        for sid, e in sorted(rt.sessions.items()):
            sessions.append({
                "sid": int(sid), "turn": int(e.turn),
                "path": _arr(e.path),
                "full_tokens": int(e.full_tokens),
                "slo_ttft": float(e.slo_ttft),
            })
        pc = getattr(rt, "prefix", None)
        if pc is not None:
            spilled_nodes = pc.spilled_nodes()
    return LoopCheckpoint(
        now=float(now), requests=[_req_to_dict(r, now) for r in live],
        held_turns=held, sessions=sessions,
        radix_spilled=spilled_nodes,
        tails_demoted=getattr(loop, "_drain_demoted", 0))
