"""Real JAX execution backend — BucketServe policies driving actual models.

This is the execution layer the cost model (core/simulator.py) stands in
for at paper scale: at tiny-model scale (CPU) it runs the *same*
scheduler objects against real jitted prefill/decode computations, token
for token.  All orchestration — arrivals, batch formation, OOM/slot
re-queue, chunk interleaving, timing — lives in core/serving_loop.py;
this module only executes.

TPU-native continuous batching (DESIGN.md §3): the decode pool is a
FIXED-CAPACITY slot tensor — cache pytree with a leading slot axis, an
alive mask, and per-slot next-token ids.  Each iteration decodes all
slots (dead slots compute garbage that is masked); completed requests
free their slot and new prefilled requests are scattered in with ONE
batched gather/scatter per cache leaf (not a device round-trip per
request).  Static shapes throughout: one compiled executable per bucket
pad-shape for prefill (bucketing bounds the executable count — the
recompilation argument for bucketing on TPU), one per chunk shape when
chunked prefill is on, one for decode.

Chunked prefill (DESIGN.md §2): long prompts are split into
``chunk_tokens``-sized spans; the serving loop interleaves decode
iterations between spans, so a 2k-token prefill no longer stalls every
live decode stream.  The chunk offset is a traced scalar — one compiled
executable serves every offset of a given (chunk_len, batch) shape.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from .batcher import FormedBatch
from .request import Request
from .serving_loop import (LoopConfig, PrefillJob, ServeResult, ServingLoop,
                           WallClock, plan_chunks)


class JaxEngineBackend:
    """ExecutionBackend over jitted prefill/decode on the local device."""

    prefill_needs_slots = True

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 cache_len: Optional[int] = None, moe_impl: str = "local",
                 time_scale: float = 1.0,
                 chunk_tokens: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len or cfg.max_seq_len
        self.moe_impl = moe_impl
        self.chunk_tokens = chunk_tokens
        self.clock = WallClock(time_scale)
        self.supports_decode = cfg.has_decode
        self.flops_per_token = 2.0 * cfg.active_param_count()

        self.pool_cache = tfm.init_cache(cfg, max_slots, self.cache_len)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self._slot_of: Dict[int, int] = {}
        self.next_tok = jnp.zeros((max_slots,), jnp.int32)
        self.outputs: Dict[int, List[int]] = {}
        self._prefill_fns: Dict[tuple, callable] = {}
        self._decode_fn = jax.jit(
            lambda p, t, c: tfm.decode_step(cfg, p, t, c,
                                            moe_impl=moe_impl))
        self.n_prefill_shapes = 0

    # ------------------------------------------------------------- jits --
    def _prefill_fn(self, pad_to: int, bsz: int):
        key = ("prefill", pad_to, bsz)
        if key not in self._prefill_fns:
            cfg, moe_impl = self.cfg, self.moe_impl

            def fn(p, tokens, lengths):
                return tfm.prefill(cfg, p, tokens=tokens, lengths=lengths,
                                   cache_len=self.cache_len,
                                   moe_impl=moe_impl)
            self._prefill_fns[key] = jax.jit(fn)
            self.n_prefill_shapes += 1
        return self._prefill_fns[key]

    def _chunk_fn(self, chunk_len: int, bsz: int):
        key = ("chunk", chunk_len, bsz)
        if key not in self._prefill_fns:
            cfg, moe_impl = self.cfg, self.moe_impl

            def fn(p, tokens, cache, start, lengths):
                return tfm.prefill_chunk(cfg, p, tokens, cache, start,
                                         lengths, moe_impl=moe_impl)
            self._prefill_fns[key] = jax.jit(fn)
            self.n_prefill_shapes += 1
        return self._prefill_fns[key]

    # --------------------------------------------------------- protocol --
    def begin(self, requests: Sequence[Request]) -> None:
        for r in requests:
            if r.tokens is None:
                rng = np.random.default_rng(r.rid)
                r.tokens = rng.integers(
                    0, self.cfg.vocab_size, r.prompt_len).astype(np.int32)
            self.outputs[r.rid] = []
        self.clock.start()

    def kv_budget_tokens(self) -> float:
        # slot caches are preallocated at cache_len: memory safety is
        # structural, the loop's admission control is slot-based
        return math.inf

    def free_slots(self) -> int:
        return sum(1 for r in self.slot_req if r is None)

    def chunk_plan(self, batch: FormedBatch) -> List[Tuple[int, int]]:
        total = max(batch.pad_to, 8)     # min real-tensor prompt width
        c = self.chunk_tokens if tfm.supports_chunked_prefill(self.cfg) \
            else None
        return plan_chunks(total, c)

    def transfer_seconds(self, batch: FormedBatch) -> float:
        return 0.0            # prefill writes straight into the slot pool

    def prefill_chunk(self, job: PrefillJob, idx: int) -> float:
        reqs = job.batch.requests
        B = len(reqs)
        start, clen = job.chunks[idx]
        h = job.handle
        if h is None:
            total = job.chunks[-1][0] + job.chunks[-1][1]
            toks = np.zeros((B, total), np.int32)
            lens = np.zeros((B,), np.int32)
            for i, r in enumerate(reqs):
                L = min(r.prompt_len, total)
                toks[i, :L] = r.tokens[:L]
                lens[i] = L
            h = job.handle = {
                "toks": toks, "lens": jnp.asarray(lens), "np_lens": lens,
                "cache": (tfm.init_cache(self.cfg, B, self.cache_len)
                          if len(job.chunks) > 1 else None),
                "first": np.zeros((B,), np.int64),
            }
        if len(job.chunks) == 1:
            fn = self._prefill_fn(clen, B)
            logits, cache = fn(self.params, jnp.asarray(h["toks"]), h["lens"])
            h["first"][:] = np.asarray(jnp.argmax(logits, -1))
            h["cache"] = cache
        else:
            fn = self._chunk_fn(clen, B)
            logits, h["cache"] = fn(
                self.params, jnp.asarray(h["toks"][:, start:start + clen]),
                h["cache"], start, h["lens"])
            last = h["np_lens"] - 1
            fin = (last >= start) & (last < start + clen)
            if fin.any():
                h["first"][fin] = np.asarray(jnp.argmax(logits, -1))[fin]
        if idx == len(job.chunks) - 1:
            if len(job.chunks) > 1:
                h["cache"] = {"pos": h["lens"].astype(jnp.int32),
                              "groups": h["cache"]["groups"]}
            self._finish_prefill(job)
        return 0.0            # wall backend: the loop reads the clock

    def _finish_prefill(self, job: PrefillJob) -> None:
        """First tokens out; batched slot insertion for continuing rows."""
        h = job.handle
        slots, rows, firsts = [], [], []
        free = iter(i for i, r in enumerate(self.slot_req) if r is None)
        for i, r in enumerate(job.batch.requests):
            tok = int(h["first"][i])
            self.outputs[r.rid].append(tok)
            if r.max_new_tokens <= 1 or not self.cfg.has_decode:
                continue
            slot = next(free)
            self.slot_req[slot] = r
            self._slot_of[r.rid] = slot
            slots.append(slot)
            rows.append(i)
            firsts.append(tok)
        if slots:
            self._insert_slots(h["cache"], slots, rows, firsts)
        job.handle = None

    def _insert_slots(self, batch_cache, slots: List[int], rows: List[int],
                      firsts: List[int]) -> None:
        """Scatter batch rows into pool slots: ONE gather/scatter per
        cache leaf for the whole batch (vs. a per-request device
        round-trip pre-refactor)."""
        sl = jnp.asarray(slots, jnp.int32)
        rw = jnp.asarray(rows, jnp.int32)
        pos = self.pool_cache["pos"].at[sl].set(batch_cache["pos"][rw])
        groups = jax.tree.map(
            lambda pl, bc: pl.at[:, sl].set(bc[:, rw]),
            self.pool_cache["groups"], batch_cache["groups"])
        self.pool_cache = {"pos": pos, "groups": groups}
        self.next_tok = self.next_tok.at[sl].set(
            jnp.asarray(firsts, jnp.int32))

    def decode_iter(self, pool: Sequence[Request],
                    context_tokens: int) -> float:
        logits, self.pool_cache = self._decode_fn(
            self.params, self.next_tok, self.pool_cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        self.next_tok = nxt
        toks = np.asarray(nxt)
        for slot, r in enumerate(self.slot_req):
            if r is not None:
                self.outputs[r.rid].append(int(toks[slot]))
        return 0.0

    def release(self, req: Request) -> None:
        slot = self._slot_of.pop(req.rid, None)
        if slot is not None:
            self.slot_req[slot] = None


class ServingEngine:
    """Facade: schedule + serve a request set on the JAX backend.

    Thin wiring only — the run loop is core/serving_loop.ServingLoop in
    ``disagg`` topology (prefill chunks interleave with slot decode)."""

    def __init__(self, cfg: ModelConfig, params, scheduler, *,
                 max_slots: int = 8, cache_len: Optional[int] = None,
                 moe_impl: str = "local", time_scale: float = 1.0,
                 chunk_tokens: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.sched = scheduler
        self.backend = JaxEngineBackend(
            cfg, params, max_slots=max_slots, cache_len=cache_len,
            moe_impl=moe_impl, time_scale=time_scale,
            chunk_tokens=chunk_tokens)
        self.loop = ServingLoop(scheduler, self.backend, LoopConfig(
            mode="disagg", decode_slot_cap=max_slots))
        self.result: Optional[ServeResult] = None

    @property
    def outputs(self) -> Dict[int, List[int]]:
        return self.backend.outputs

    @property
    def n_prefill_shapes(self) -> int:
        return self.backend.n_prefill_shapes

    @property
    def interleaved_decode_steps(self) -> int:
        return self.result.interleaved_decode_steps if self.result else 0

    def submit(self, requests: List[Request]) -> None:
        self._pending = list(requests)

    def run(self, max_wall_s: float = 600.0) -> List[Request]:
        self.result = self.loop.run(self._pending, time_limit=math.inf,
                                    max_wall_s=max_wall_s)
        return [r for r in self._pending
                if r.finished >= 0 and not r.dropped]
