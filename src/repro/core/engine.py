"""Real JAX execution backend — BucketServe policies driving actual models.

This is the execution layer the cost model (core/simulator.py) stands in
for at paper scale: at tiny-model scale (CPU) it runs the *same*
scheduler objects against real jitted prefill/decode computations, token
for token.  All orchestration — arrivals, batch formation, OOM/slot
re-queue, chunk interleaving, timing — lives in core/serving_loop.py;
this module only executes.

TPU-native continuous batching (DESIGN.md §3): the decode pool is a
FIXED-CAPACITY slot tensor — cache pytree with a leading slot axis, an
alive mask, and per-slot next-token ids.  Each iteration decodes all
slots (dead slots compute garbage that is masked); completed requests
free their slot and new prefilled requests are scattered in with ONE
batched gather/scatter per cache leaf (not a device round-trip per
request).  Static shapes throughout: one compiled executable per bucket
pad-shape for prefill (bucketing bounds the executable count — the
recompilation argument for bucketing on TPU), one per chunk shape when
chunked prefill is on, one for decode.

Paged decode pool (``paged=True``, DESIGN.md §3): slot KV caches are no
longer preallocated at ``cache_len`` — self-attention K/V live in a
SHARED page pool indexed through per-slot block tables
(``transformer.init_paged_cache`` + ``attention.self_attn_decode_paged``,
Pallas kernel in ``kernels/paged_decode_attn.py``).  A
:class:`~repro.core.paging.BlockAllocator` hands out pages at
prefill-insert, extends tables page by page as decode advances, and
frees on release; the ServingLoop gates admission on free PAGES
(``admit_blocks``) and preempts the youngest pooled request when pages
run out mid-decode (``decode_preempt`` -> requeue).  Dead slots point
at a dedicated trash page so their masked garbage writes can never
corrupt a live request's pages.  Shapes stay static: the pool and the
(slots, pages_per_seq) block table are fixed tensors, so ONE decode
executable serves every allocation layout.

Cross-request prefix cache (``prefix_cache=True``, DESIGN.md §3
"Prefix sharing"): admission matches each prompt against a radix index
of token-id page chunks (``core/prefix_cache.py``); matched FULL pages
are attached to the request's block table BY REFERENCE (the allocator
refcounts pages) and chunked prefill resumes after the cached prefix —
the batch cache is seeded from the pool with the exact inverse of the
insert scatter, so hit-path token ids are bit-identical to a cold run.
At insert, shared prefix pages are never re-scattered; freshly
prefilled full prompt pages are pinned into the index for future hits,
and LRU zero-ref prefixes are evicted when admission or decode
starves.

Session retention (``session_ttl``, DESIGN.md §3 "Session retention"):
release routes through :class:`~repro.core.retention.KvRetention`
instead of freeing unconditionally — a finished request's FULL
transcript pages (prompt AND generated: page content is a pure
function of the token path) extend the radix index, and the partial
tail page stays pinned under the session key with a TTL.  The next
turn of the same conversation re-sends the transcript as its prompt
prefix, matches it at admission (the pinned tail transfers to its
block table at the right virtual index), seeds the batch cache up to
the EXACT unaligned token, and resumes chunked prefill past the
restored transcript — decode then continues into the reused tail page
without a re-scatter of the transcript's pages.

Host spill tier (``host_pool_tokens``, DESIGN.md §3 "Host spill
tier"): retention eviction SPILLS cold retained pages to a host-RAM
pool instead of destroying them — ``_EngineCopier`` captures the
page's K/V as immutable device-side slices at eviction time and
materializes them to host on the next ``maintain`` poll (double
buffered, overlapping decode); a later hit on a spilled path restores
the bytes into a reserved pool page while the ServingLoop parks the
request, so the next turn pays a PCIe copy instead of a re-prefill,
bit-identically.

Chunked prefill (DESIGN.md §2): long prompts are split into
``chunk_tokens``-sized spans; the serving loop interleaves decode
iterations between spans, so a 2k-token prefill no longer stalls every
live decode stream.  The chunk offset is a traced scalar — one compiled
executable serves every offset of a given (chunk_len, batch) shape.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.attention import dequantize_kv_int4, quantize_kv_int4
from repro.models.config import BLOCK_ATTN, BLOCK_MOE, ModelConfig
from . import paging
from .batcher import FormedBatch
from .faults import FaultInjector
from .prefix_cache import PrefixCache
from .request import Request
from .retention import KvRetention, maintain_backend
from .serving_loop import (LoopConfig, PrefillJob, ServeResult, ServingLoop,
                           WallClock, batch_prefix_skip, plan_chunks)


class _BlockTableMirror:
    """Host mirror of the device block-table tensor.

    ``decode_preempt`` used to rescan every pooled request's FULL table
    with ``np.array_equal`` on every dispatch — O(pool x pages_per_seq)
    int32 compares per decode iteration whether or not anything grew.
    The mirror tracks how many pages per rid are already uploaded and
    writes only the newly appended suffix, so a steady-state iteration
    where one request crosses a page boundary costs ONE cell write.
    ``writes`` counts int32 cells written — the timing-free regression
    hook tests compare against the rescanning reference."""

    def __init__(self, n_slots: int, pages_per_seq: int, trash: int):
        self.host = np.full((n_slots, pages_per_seq), trash, np.int32)
        self.trash = trash
        self.dirty = False
        self._uploaded: Dict[int, int] = {}     # rid -> pages uploaded
        self.writes = 0

    def insert(self, slot: int, rid: int, table: Sequence[int]) -> None:
        """A freshly prefilled request lands in ``slot``: full-row
        write (its pages are all new to the device tensor)."""
        self.host[slot] = self.trash
        self.host[slot, :len(table)] = table
        self.writes += self.host.shape[1]
        self._uploaded[rid] = len(table)
        self.dirty = True

    def clear(self, slot: int, rid: int) -> None:
        self.host[slot] = self.trash
        self._uploaded.pop(rid, None)
        self.writes += self.host.shape[1]
        self.dirty = True

    def forget(self, rid: int) -> None:
        self._uploaded.pop(rid, None)

    def sync(self, slot: int, rid: int, alloc) -> None:
        """Write only the pages appended since the last upload —
        O(growth), not O(table)."""
        n0 = self._uploaded.get(rid, 0)
        n1 = alloc.table_len(rid)
        if n1 > n0:
            self.host[slot, n0:n1] = alloc.table_tail(rid, n0)
            self.writes += n1 - n0
            self._uploaded[rid] = n1
            self.dirty = True


class _EngineCopier:
    """Host<->device KV page mover for the real engine — the data half
    of the spill tier (the retention layer makes every DECISION; this
    object only moves bytes bit-exactly).

    Double-buffered spill: ``spill`` captures the page's K/V as
    device-side slices (JAX arrays are immutable values, so the capture
    is safe the moment it is dispatched — the freed page can be
    reallocated and overwritten without corrupting it) and the
    device->host materialization into the preallocated host pool is
    deferred to ``poll``, which the retention tick calls once per loop
    iteration — so the copy overlaps decode instead of blocking the
    step that evicted the page.  ``restore`` scatters the host copy
    back into the reserved pool page at initiation (a functional
    ``.at[].set`` — by the time the held request prefills, the gather
    in ``_seed_prefix`` reads values bit-identical to the ones
    spilled).

    Quantized spill (``spill_dtype``, DESIGN.md §3 "Tier precision"):
    the device->host materialization COMPRESSES the K/V payload leaves
    ("k"/"v") to the spill dtype — int8 (one scale per token-head row,
    same rule as ``attention.quantize_kv``) or int4 (two values packed
    per byte, ``attention.quantize_kv_int4``) — with the f32 per-page
    scale planes stored alongside the slot; the restore path
    dequantizes back to the pool leaf's dtype.  Two lossless special
    cases anchor the bit-accuracy story: bf16 spill is a raw
    pass-through of every leaf (pre-quantization behavior), and an
    int8 HOT pool's already-int8 leaves pass through an int8 spill
    tier untouched (re-quantizing integer codes would NOT round-trip).
    The pool's own "k_s"/"v_s" scale planes are always raw — they ARE
    the precision bookkeeping."""

    _Q_KEYS = ("k", "v")                    # payload leaves; scales raw

    def __init__(self, backend: "JaxEngineBackend", host_pages: int,
                 spill_dtype: str = ""):
        self.be = backend
        self.host_pages = host_pages
        self.spill_dtype = spill_dtype
        self._host: Dict[tuple, np.ndarray] = {}
        self._scales: Dict[tuple, np.ndarray] = {}  # compressed leaves only
        self._staged: Dict[int, list] = {}      # hslot -> [(leafkey, slice)]
        self._pending: List[Tuple[int, int]] = []   # (hslot, dest page)

    def _attn_leaves(self):
        for gi, (pattern, reps) in enumerate(self.be.cfg.block_groups()):
            for j, btype in enumerate(pattern):
                if btype in (BLOCK_ATTN, BLOCK_MOE):
                    slot = self.be.pool_cache["groups"][gi][j]
                    for k, leaf in slot.items():
                        yield (gi, j, k), leaf

    def _host_leaf(self, lk: tuple, like) -> np.ndarray:
        h = self._host.get(lk)
        if h is None:
            h = np.zeros((like.shape[0], self.host_pages) + like.shape[1:],
                         dtype=like.dtype)
            self._host[lk] = h
        return h

    def _scale_leaf(self, lk: tuple, like) -> np.ndarray:
        s = self._scales.get(lk)
        if s is None:
            s = np.zeros((like.shape[0], self.host_pages) + like.shape[1:],
                         np.float32)
            self._scales[lk] = s
        return s

    def _quantizes(self, lk: tuple, dtype) -> bool:
        """Does this leaf compress on spill?  Deterministic per leaf for
        the whole run — the restore path keys off the same rule."""
        if self.spill_dtype in ("", "bf16") or lk[2] not in self._Q_KEYS:
            return False
        if self.spill_dtype == "int8" and dtype == np.int8:
            return False                    # int8 pool: lossless pass-through
        return True

    def _materialize(self, hslot: int, lk: tuple, sl) -> None:
        arr = np.asarray(sl)
        if not self._quantizes(lk, arr.dtype):
            self._host_leaf(lk, arr)[:, hslot] = arr
            return
        x = arr.astype(np.float32)
        if self.spill_dtype == "int4":
            payload, scale = quantize_kv_int4(x)
        else:                               # int8 spill of a float pool
            scale = np.maximum(np.abs(x).max(axis=-1) / 127.0,
                               1e-8).astype(np.float32)
            payload = np.clip(np.rint(x / scale[..., None]),
                              -127, 127).astype(np.int8)
        self._host_leaf(lk, payload)[:, hslot] = payload
        self._scale_leaf(lk, scale)[:, hslot] = scale

    def _decompress(self, lk: tuple, src: np.ndarray, hslots: List[int],
                    leaf) -> np.ndarray:
        """Invert ``_materialize`` for a batch of host slots; the
        target is the pool leaf's own dtype (int4->int8 re-expands the
        integer codes, everything else lands on the float cache
        dtype)."""
        if lk not in self._scales:
            return src                      # raw pass-through leaf
        scale = self._scales[lk][:, hslots]
        if self.spill_dtype == "int4":
            x = dequantize_kv_int4(src, scale, leaf.shape[-1])
        else:
            x = src.astype(np.float32) * scale[..., None]
        if leaf.dtype == np.int8:
            return np.clip(np.rint(x), -127, 127).astype(np.int8)
        return x.astype(leaf.dtype)

    def spill(self, page: int, hslot: int) -> None:
        self._staged[hslot] = [(lk, leaf[:, page])
                               for lk, leaf in self._attn_leaves()]

    def poll(self) -> None:
        """Drain both directions (called by the retention tick, between
        device steps): staged spills materialize to host RAM, then
        pending restores scatter back with ONE batched pool update per
        leaf — a per-page functional ``.at[].set`` would copy the whole
        pool once per restored page.  The retention layer guarantees a
        restore's pages are never read before its modeled completion,
        and completion is polled through this same tick, so the scatter
        always lands before the held request's prefill gathers it."""
        for hslot, slices in self._staged.items():
            for lk, sl in slices:
                self._materialize(hslot, lk, sl)
        self._staged.clear()
        if not self._pending:
            return
        hslots = [h for h, _ in self._pending]
        dst = jnp.asarray([p for _, p in self._pending], jnp.int32)
        self._pending = []
        be = self.be
        new_groups = []
        for gi, (pattern, reps) in enumerate(be.cfg.block_groups()):
            slots_out = []
            for j, btype in enumerate(pattern):
                slot = be.pool_cache["groups"][gi][j]
                if btype in (BLOCK_ATTN, BLOCK_MOE):
                    out = {}
                    for k, leaf in slot.items():
                        lk = (gi, j, k)
                        src = self._decompress(lk, self._host[lk][:, hslots],
                                               hslots, leaf)
                        out[k] = leaf.at[:, dst].set(jnp.asarray(src))
                    slots_out.append(out)
                else:
                    slots_out.append(slot)
            new_groups.append(tuple(slots_out))
        be.pool_cache = {**be.pool_cache, "groups": tuple(new_groups)}

    def drop(self, hslot: int) -> None:
        self._staged.pop(hslot, None)   # host cells just become garbage

    def restore(self, hslot: int, page: int) -> None:
        self._pending.append((hslot, page))


class JaxEngineBackend:
    """ExecutionBackend over jitted prefill/decode on the local device."""

    prefill_needs_slots = True
    # armed by the ServingLoop when the scheduler is slack-aware: a
    # CLOCK-FREE key (Request -> seconds) preferring the victim with
    # the most remaining deadline slack (DESIGN.md §8)
    slack_of = None

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 cache_len: Optional[int] = None, moe_impl: str = "local",
                 time_scale: float = 1.0,
                 chunk_tokens: Optional[int] = None,
                 paged: bool = False, page_size: int = 128,
                 kv_pool_tokens: Optional[int] = None,
                 prefix_cache: bool = False,
                 session_ttl: Optional[float] = None,
                 host_pool_tokens: Optional[int] = None,
                 spill_bw: float = 16e9,
                 spill_dtype: str = ""):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len or cfg.max_seq_len
        self.moe_impl = moe_impl
        self.chunk_tokens = chunk_tokens
        self.clock = WallClock(time_scale)
        self.supports_decode = cfg.has_decode
        self.flops_per_token = 2.0 * cfg.active_param_count()
        self.paged = paged
        self.spill_dtype = spill_dtype
        # retention layer (core/retention.py): the radix prefix index
        # plus, when session_ttl is set, TTL'd multi-turn session
        # retention of finished transcripts; host_pool_tokens adds the
        # host-RAM spill tier beneath it.  Both the host-slot count and
        # the per-page transfer price are denominated in COMPRESSED
        # bytes (spill_dtype), through the same paging.host_tier_geometry
        # rule the cost model uses — so an int4 spill tier retains more
        # pages AND restores each one faster under the same budget
        self.retention: Optional[KvRetention] = None
        host_pages, slot_bytes = paging.host_tier_geometry(
            cfg, host_pool_tokens, page_size, spill_dtype)
        prefix_cache = prefix_cache or session_ttl is not None
        if prefix_cache:
            assert paged, "KV retention rides on the paged KV pool"
            assert cfg.prefix_cacheable, \
                f"{cfg.name}: KV retention needs chunk-resumable prefill " \
                "and purely attention-paged state (no recurrent carries)"
            self.retention = KvRetention(
                page_size, session_ttl=session_ttl,
                host_pool_pages=host_pages,
                spill_seconds_per_page=slot_bytes / spill_bw,
                spill_page_bytes=slot_bytes)
        else:
            assert not host_pages, \
                "the host spill tier rides on the retention layer"

        if paged:
            assert tfm.supports_paged_decode(cfg), \
                f"{cfg.name}: paged KV needs self-attention decode"
            S = cfg.attn_cache_len(self.cache_len)
            self.page_size = page_size
            self.s_attn = S
            self.pages_per_seq = -(-S // page_size)
            # same HBM BYTE budget as a contiguous bf16 pool of
            # max_slots by default, re-denominated at the pool's actual
            # cache dtype (an int8 pool holds ~2x the pages); the trash
            # page comes OUT of the budget
            total = kv_pool_tokens or max_slots * S
            n_pages = paging.device_pool_pages(cfg, total, page_size) - 1
            if kv_pool_tokens is not None and n_pages < self.pages_per_seq:
                raise ValueError(
                    f"kv_pool_tokens={kv_pool_tokens} too small: the "
                    f"paged pool needs at least "
                    f"{(self.pages_per_seq + 1) * page_size} tokens (one "
                    f"full request of {self.pages_per_seq} pages + the "
                    f"trash page)")
            n_pages = max(n_pages, self.pages_per_seq)
            self.alloc = paging.BlockAllocator(
                n_pages, page_size, host_pages=host_pages,
                page_bytes=page_size * max(cfg.cache_bytes_per_token(), 1),
                host_slot_bytes=slot_bytes)
            self.trash_page = n_pages            # pool index n_pages
            self.pool_cache = tfm.init_paged_cache(
                cfg, max_slots, self.cache_len, n_pages + 1, page_size)
            self._bt = _BlockTableMirror(max_slots, self.pages_per_seq,
                                         self.trash_page)
            self.pool_cache["block_tables"] = jnp.asarray(self._bt.host)
            if host_pages:
                self.retention.copier = _EngineCopier(self, host_pages,
                                                      spill_dtype)
            self._decode_fn = jax.jit(
                lambda p, t, c: tfm.decode_step(cfg, p, t, c,
                                                moe_impl=moe_impl,
                                                page_size=page_size,
                                                paged_len=S))
        else:
            self.pool_cache = tfm.init_cache(cfg, max_slots, self.cache_len)
            self._decode_fn = jax.jit(
                lambda p, t, c: tfm.decode_step(cfg, p, t, c,
                                                moe_impl=moe_impl))
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self._slot_of: Dict[int, int] = {}
        self.next_tok = jnp.zeros((max_slots,), jnp.int32)
        self.outputs: Dict[int, List[int]] = {}
        self._prefill_fns: Dict[tuple, callable] = {}
        self.n_prefill_shapes = 0

    @property
    def prefix_cache(self) -> Optional[PrefixCache]:
        """The retention layer's radix backend (None when disabled) —
        the surface older call sites and tests address."""
        return self.retention.prefix if self.retention is not None else None

    # ------------------------------------------------------------- jits --
    def _prefill_fn(self, pad_to: int, bsz: int):
        key = ("prefill", pad_to, bsz)
        if key not in self._prefill_fns:
            cfg, moe_impl = self.cfg, self.moe_impl

            def fn(p, tokens, lengths):
                return tfm.prefill(cfg, p, tokens=tokens, lengths=lengths,
                                   cache_len=self.cache_len,
                                   moe_impl=moe_impl)
            self._prefill_fns[key] = jax.jit(fn)
            self.n_prefill_shapes += 1
        return self._prefill_fns[key]

    def _chunk_fn(self, chunk_len: int, bsz: int):
        key = ("chunk", chunk_len, bsz)
        if key not in self._prefill_fns:
            cfg, moe_impl = self.cfg, self.moe_impl

            def fn(p, tokens, cache, start, lengths):
                return tfm.prefill_chunk(cfg, p, tokens, cache, start,
                                         lengths, moe_impl=moe_impl)
            self._prefill_fns[key] = jax.jit(fn)
            self.n_prefill_shapes += 1
        return self._prefill_fns[key]

    # --------------------------------------------------------- protocol --
    def begin(self, requests: Sequence[Request]) -> None:
        for r in requests:
            r.materialize_tokens(self.cfg.vocab_size)
            if r.sliced_tokens > 0:
                # cold resume of a slice-promoted request (checkpointed
                # drain, core/recovery.py): the promoted ids are the
                # LAST sliced_tokens of the prompt — seed the output
                # list with them so generated-token indexing
                # (_transcript_tokens, slice yields) keeps its absolute
                # alignment on a backend that never ran the original
                # decode steps
                self.outputs[r.rid] = [
                    int(t) for t in
                    r.tokens[r.prompt_len - r.sliced_tokens:r.prompt_len]]
            else:
                self.outputs[r.rid] = []
        self.clock.start()

    def kv_budget_tokens(self) -> float:
        # slot caches are preallocated at cache_len: memory safety is
        # structural, the loop's admission control is slot-based
        return math.inf

    def free_slots(self) -> int:
        return sum(1 for r in self.slot_req if r is None)

    # ------------------------------------------------- paged KV (§3) -----
    def _insert_tokens(self, r: Request) -> int:
        """Tokens a cache holds right after prefill: the prompt plus the
        first decode write (window-capped for ring caches)."""
        return min(r.prompt_len + 1, self.s_attn)

    def _decode_tokens(self, r: Request) -> int:
        """Tokens after this iteration's write at slot
        prompt+generated-sliced-1 (sliced tokens were promoted into the
        prompt by a slice-yield and are already inside prompt_len)."""
        return min(r.prompt_len + r.generated - r.sliced_tokens, self.s_attn)

    def free_blocks(self) -> int:
        """Engine-level observability (serve.py printout); admission
        itself goes through ``admit_blocks``."""
        return self.alloc.free_pages() if self.paged else 1 << 30

    def _prompt_tokens(self, r: Request):
        return r.tokens[:r.prompt_len]

    def admit_blocks(self, requests: Sequence[Request]) -> int:
        if not self.paged:
            return len(requests)
        return paging.admit_blocks(self.alloc, requests, self._insert_tokens,
                                   cache=self.retention,
                                   tokens_of=self._prompt_tokens)

    def decode_preempt(self, pool: Sequence[Request]) -> List[Request]:
        if not self.paged:
            return []
        victims = paging.extend_for_decode(self.alloc, pool,
                                           self._decode_tokens,
                                           cache=self.retention,
                                           slack_of=self.slack_of)
        for v in victims:
            slot = self._slot_of.pop(v.rid, None)
            if slot is not None:
                self.slot_req[slot] = None
                self._bt.clear(slot, v.rid)
            else:
                self._bt.forget(v.rid)
            # outputs survive here: the loop decides whether the victim
            # keeps a slice (on_slice_yield truncates) or restarts
            # (on_preempt_reset wipes)
        for r in pool:                       # tables may have grown a page
            slot = self._slot_of.get(r.rid)
            if slot is not None:
                # incremental: only newly appended pages are written —
                # the old full-table np.array_equal rescan paid
                # O(pool x pages_per_seq) on EVERY dispatch
                self._bt.sync(slot, r.rid, self.alloc)
        return victims

    def on_slice_yield(self, req: Request, keep: int) -> None:
        """Slice-boundary preemption kept ``keep`` generated tokens
        (now promoted into the prompt): drop only the unaligned tail —
        the resume prefill's argmax re-appends from position keep."""
        out = self.outputs.get(req.rid)
        if out is not None:
            del out[keep:]

    def on_preempt_reset(self, req: Request) -> None:
        self.outputs[req.rid] = []       # regenerated after re-prefill

    # ------------------------------------------- fault/drain teardown -----
    def abort_prefill(self, req: Request) -> None:
        """A mid-prefill request leaves before its KV enters the slot
        pool (prefill-job abandon, checkpointed drain): free its
        admission-reserved pages outright.  No slot was taken yet —
        slots are assigned in ``_finish_prefill``."""
        if self.paged:
            self.alloc.release(req.rid)
            self._bt.forget(req.rid)

    def evict_request(self, req: Request) -> None:
        """Tear down a pooled request's slot + pages WITHOUT retention
        registration (decode-pool kill / drain): its partial KV never
        becomes a radix path.  ``outputs`` survives — the loop still
        reads ``generated_tokens`` to promote the preserved slice."""
        slot = self._slot_of.pop(req.rid, None)
        if slot is not None:
            self.slot_req[slot] = None
        if self.paged:
            self.alloc.release(req.rid)
            if slot is not None:
                self._bt.clear(slot, req.rid)
            else:
                self._bt.forget(req.rid)

    def chunk_plan(self, batch: FormedBatch) -> List[Tuple[int, int]]:
        total = max(batch.pad_to, 8)     # min real-tensor prompt width
        c = self.chunk_tokens if tfm.supports_chunked_prefill(self.cfg) \
            else None
        skip = batch_prefix_skip(batch) if self.prefix_cache is not None \
            else 0
        return plan_chunks(total, c, skip=skip)

    def transfer_seconds(self, batch: FormedBatch) -> float:
        return 0.0            # prefill writes straight into the slot pool

    def prefill_chunk(self, job: PrefillJob, idx: int) -> float:
        reqs = job.batch.requests
        B = len(reqs)
        start, clen = job.chunks[idx]
        # chunk-mode execution whenever the plan is split OR starts past
        # position 0 (a cached prefix was skipped — the single remaining
        # span still continues an existing cache)
        chunked = len(job.chunks) > 1 or job.chunks[0][0] > 0
        h = job.handle
        if h is None:
            total = job.chunks[-1][0] + job.chunks[-1][1]
            toks = np.zeros((B, total), np.int32)
            lens = np.zeros((B,), np.int32)
            for i, r in enumerate(reqs):
                L = min(r.prompt_len, total)
                toks[i, :L] = r.tokens[:L]
                lens[i] = L
            h = job.handle = {
                "toks": toks, "lens": jnp.asarray(lens), "np_lens": lens,
                "cache": (tfm.init_cache(self.cfg, B, self.cache_len)
                          if chunked else None),
                "first": np.zeros((B,), np.int64),
            }
            if job.chunks[0][0] > 0:
                # seed the batch cache's prefix region from the shared
                # page pool before the first (post-prefix) chunk runs
                self._seed_prefix(h, reqs)
        if not chunked:
            fn = self._prefill_fn(clen, B)
            logits, cache = fn(self.params, jnp.asarray(h["toks"]), h["lens"])
            h["first"][:] = np.asarray(jnp.argmax(logits, -1))
            h["cache"] = cache
        else:
            fn = self._chunk_fn(clen, B)
            logits, h["cache"] = fn(
                self.params, jnp.asarray(h["toks"][:, start:start + clen]),
                h["cache"], start, h["lens"])
            last = h["np_lens"] - 1
            fin = (last >= start) & (last < start + clen)
            if fin.any():
                h["first"][fin] = np.asarray(jnp.argmax(logits, -1))[fin]
        if idx == len(job.chunks) - 1:
            if chunked:
                h["cache"] = {"pos": h["lens"].astype(jnp.int32),
                              "groups": h["cache"]["groups"]}
            self._finish_prefill(job)
        return 0.0            # wall backend: the loop reads the clock

    def _seed_prefix(self, h, reqs: Sequence[Request]) -> None:
        """Copy each row's cached-prefix K/V out of the shared page pool
        into the batch prefill cache, so chunked prefill can resume past
        it.  One gather per cache leaf for the whole batch; the gather is
        the exact inverse of ``_insert_slots_paged``'s scatter, so seeded
        values are bit-identical to a cold recompute.  A session-resumed
        row's hit is NOT page-aligned (the pinned partial tail extends
        it): the gather then includes the tail page and the per-row mask
        cuts at the exact token."""
        page, maxp = self.page_size, self.pages_per_seq
        B = len(reqs)
        idx = np.full((B, maxp), self.trash_page, np.int32)
        plen = np.zeros((B,), np.int32)
        for i, r in enumerate(reqs):
            npg = -(-r.prefix_hit_tokens // page)   # incl. a partial tail
            if npg:
                idx[i, :npg] = self.alloc.table(r.rid)[:npg]
                plen[i] = r.prefix_hit_tokens
        if not plen.any():
            return
        idxj = jnp.asarray(idx)
        S = self.s_attn
        mask = jnp.arange(S)[None, :] < jnp.asarray(plen)[:, None]  # (B,S)

        def seed(cache_leaf, pool_leaf):
            g = pool_leaf[:, idxj]               # (reps, B, maxp, page, ...)
            g = g.reshape(g.shape[:2] + (maxp * page,) + g.shape[4:])
            g = g[:, :, :S]
            m = mask.reshape((1, B, S) + (1,) * (g.ndim - 3))
            return jnp.where(m, g, cache_leaf)

        new_groups = []
        for gi, (pattern, reps) in enumerate(self.cfg.block_groups()):
            slots_out = []
            for j, btype in enumerate(pattern):
                cslot = h["cache"]["groups"][gi][j]
                if btype in (BLOCK_ATTN, BLOCK_MOE):
                    pslot = self.pool_cache["groups"][gi][j]
                    slots_out.append({k: seed(cslot[k], pslot[k])
                                      for k in cslot})
                else:       # unreachable under the prefix_cacheable gate
                    slots_out.append(cslot)
            new_groups.append(tuple(slots_out))
        h["cache"] = {"pos": h["cache"]["pos"], "groups": tuple(new_groups)}

    def _finish_prefill(self, job: PrefillJob) -> None:
        """First tokens out; batched slot insertion for continuing rows."""
        h = job.handle
        slots, rows, firsts, tables, shared = [], [], [], [], []
        to_register = []
        free = iter(i for i, r in enumerate(self.slot_req) if r is None)
        for i, r in enumerate(job.batch.requests):
            tok = int(h["first"][i])
            self.outputs[r.rid].append(tok)
            if r.max_new_tokens <= 1 or not self.cfg.has_decode:
                if self.paged:
                    # done at first token: this row is never scattered
                    # into the pool, so its pages hold NO transcript KV
                    # — plain free, never retention (which would index
                    # garbage pages into the radix)
                    self.alloc.release(r.rid)
                continue
            slot = next(free)
            self.slot_req[slot] = r
            self._slot_of[r.rid] = slot
            slots.append(slot)
            rows.append(i)
            firsts.append(tok)
            if self.paged:
                t = self.alloc.table(r.rid)      # reserved at admission
                self._bt.insert(slot, r.rid, t)
                tables.append(t)
                # shared prefix pages already hold this KV — never
                # re-scattered (they may be read by other live requests)
                shared.append(r.prefix_hit_tokens // self.page_size
                              if self.prefix_cache is not None else 0)
                if self.prefix_cache is not None:
                    to_register.append((r, t))
        if slots:
            if self.paged:
                self._insert_slots_paged(h["cache"], slots, rows, firsts,
                                         tables, shared)
            else:
                self._insert_slots(h["cache"], slots, rows, firsts)
        # index full prompt pages AFTER their KV is physically in the
        # pool — a concurrent later batch may hit them immediately
        for r, t in to_register:
            self.prefix_cache.register(self.alloc,
                                       self._prompt_tokens(r), t)
        job.handle = None

    def _insert_slots(self, batch_cache, slots: List[int], rows: List[int],
                      firsts: List[int]) -> None:
        """Scatter batch rows into pool slots: ONE gather/scatter per
        cache leaf for the whole batch (vs. a per-request device
        round-trip pre-refactor)."""
        sl = jnp.asarray(slots, jnp.int32)
        rw = jnp.asarray(rows, jnp.int32)
        pos = self.pool_cache["pos"].at[sl].set(batch_cache["pos"][rw])
        groups = jax.tree.map(
            lambda pl, bc: pl.at[:, sl].set(bc[:, rw]),
            self.pool_cache["groups"], batch_cache["groups"])
        self.pool_cache = {"pos": pos, "groups": groups}
        self.next_tok = self.next_tok.at[sl].set(
            jnp.asarray(firsts, jnp.int32))

    def _insert_slots_paged(self, batch_cache, slots: List[int],
                            rows: List[int], firsts: List[int],
                            tables: List[List[int]],
                            shared: Optional[List[int]] = None) -> None:
        """Scatter prefilled caches into the page pool: attention K/V
        rows are chopped into page-sized spans and written to each
        request's allocated pages (one scatter per leaf for the whole
        batch); per-slot leaves (recurrent state, vision KV, positions)
        use the contiguous slot scatter unchanged.  The first
        ``shared[i]`` pages of a table are a cached prefix that ALREADY
        lives in the pool — skipped, so shared pages are written exactly
        once, by their original owner."""
        sl = jnp.asarray(slots, jnp.int32)
        rw = jnp.asarray(rows, jnp.int32)
        pos = self.pool_cache["pos"].at[sl].set(batch_cache["pos"][rw])
        dst, srow, spg = [], [], []
        for k, (row, t) in enumerate(zip(rows, tables)):
            skip_pages = shared[k] if shared else 0
            for j, pg in enumerate(t):
                if j < skip_pages:
                    continue
                dst.append(pg)
                srow.append(row)
                spg.append(j)
        dst = jnp.asarray(dst, jnp.int32)
        srow = jnp.asarray(srow, jnp.int32)
        spg = jnp.asarray(spg, jnp.int32)
        page, maxp = self.page_size, self.pages_per_seq

        def scatter_pages(pool_leaf, batch_leaf):
            pad = maxp * page - batch_leaf.shape[2]
            if pad:
                widths = [(0, 0)] * batch_leaf.ndim
                widths[2] = (0, pad)
                batch_leaf = jnp.pad(batch_leaf, widths)
            bp = batch_leaf.reshape(batch_leaf.shape[:2] + (maxp, page)
                                    + batch_leaf.shape[3:])
            return pool_leaf.at[:, dst].set(bp[:, srow, spg])

        new_groups = []
        for gi, (pattern, reps) in enumerate(self.cfg.block_groups()):
            slots_out = []
            for j, btype in enumerate(pattern):
                pool_slot = self.pool_cache["groups"][gi][j]
                bc_slot = batch_cache["groups"][gi][j]
                if btype in (BLOCK_ATTN, BLOCK_MOE):
                    slots_out.append({k: scatter_pages(pool_slot[k],
                                                       bc_slot[k])
                                      for k in pool_slot})
                else:
                    slots_out.append(jax.tree.map(
                        lambda pf, bf: pf.at[:, sl].set(bf[:, rw]),
                        pool_slot, bc_slot))
            new_groups.append(tuple(slots_out))
        self.pool_cache = {"pos": pos,
                           "block_tables": jnp.asarray(self._bt.host),
                           "groups": tuple(new_groups)}
        self._bt.dirty = False
        self.next_tok = self.next_tok.at[sl].set(
            jnp.asarray(firsts, jnp.int32))

    def decode_iter(self, pool: Sequence[Request],
                    context_tokens: int) -> float:
        if self.paged and self._bt.dirty:
            # tables changed (extend/preempt/release) — push the tiny
            # (slots, pages_per_seq) int32 host mirror; steady-state
            # decode iterations skip the transfer
            self.pool_cache["block_tables"] = jnp.asarray(self._bt.host)
            self._bt.dirty = False
        logits, self.pool_cache = self._decode_fn(
            self.params, self.next_tok, self.pool_cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        self.next_tok = nxt
        toks = np.asarray(nxt)
        for slot, r in enumerate(self.slot_req):
            if r is not None:
                self.outputs[r.rid].append(int(toks[slot]))
        return 0.0

    def release(self, req: Request) -> None:
        slot = self._slot_of.pop(req.rid, None)
        if slot is not None:
            self.slot_req[slot] = None
        if self.paged:
            self._release_pages(req)
            if slot is not None:
                self._bt.clear(slot, req.rid)
            else:
                self._bt.forget(req.rid)

    def _release_pages(self, req: Request) -> None:
        """End-of-life for a request's KV pages: one retention policy
        instead of an unconditional free — the transcript's full pages
        join the radix path and the partial tail stays pinned under the
        session key (core/retention.py)."""
        if self.retention is not None:
            self.retention.on_release(self.alloc, req,
                                      self._transcript_tokens(req),
                                      self.clock.now())
        else:
            self.alloc.release(req.rid)

    def _transcript_tokens(self, req: Request) -> np.ndarray:
        """The token path whose KV the pool physically holds for
        ``req``: prompt plus generated[:-1] — the iteration that
        produced the LAST token never wrote its KV."""
        out = self.outputs.get(req.rid) or []
        # generated[:sliced_tokens] already live inside the prompt
        # (slice-yield promotion) — exclude them or they'd count twice
        gen = np.asarray(out[req.sliced_tokens:max(req.generated - 1, 0)],
                         np.int32)
        return np.concatenate(
            [np.asarray(self._prompt_tokens(req), np.int32), gen])

    def generated_tokens(self, req: Request) -> np.ndarray:
        return np.asarray(self.outputs.get(req.rid, ()), np.int32)

    def maintain(self, now: float) -> None:
        maintain_backend(self, now)


class ServingEngine:
    """Facade: schedule + serve a request set on the JAX backend.

    Thin wiring only — the run loop is core/serving_loop.ServingLoop in
    ``disagg`` topology (prefill chunks interleave with slot decode)."""

    def __init__(self, cfg: ModelConfig, params, scheduler, *,
                 max_slots: int = 8, cache_len: Optional[int] = None,
                 moe_impl: str = "local", time_scale: float = 1.0,
                 chunk_tokens: Optional[int] = None, paged: bool = False,
                 page_size: int = 128,
                 kv_pool_tokens: Optional[int] = None,
                 prefix_cache: bool = False,
                 session_ttl: Optional[float] = None,
                 host_pool_tokens: Optional[int] = None,
                 spill_bw: float = 16e9,
                 spill_dtype: str = "",
                 slice_tokens: Optional[int] = None,
                 recorder=None, tracer=None,
                 fault_plan=None, recovery=None,
                 restore_timeout: float = 30.0):
        self.cfg = cfg
        self.params = params
        self.sched = scheduler
        self.backend = JaxEngineBackend(
            cfg, params, max_slots=max_slots, cache_len=cache_len,
            moe_impl=moe_impl, time_scale=time_scale,
            chunk_tokens=chunk_tokens, paged=paged, page_size=page_size,
            kv_pool_tokens=kv_pool_tokens, prefix_cache=prefix_cache,
            session_ttl=session_ttl, host_pool_tokens=host_pool_tokens,
            spill_bw=spill_bw, spill_dtype=spill_dtype)
        faults = None
        if fault_plan is not None and fault_plan.any_armed:
            faults = FaultInjector(fault_plan)
        self.faults = faults
        self.loop = ServingLoop(scheduler, self.backend, LoopConfig(
            mode="disagg", decode_slot_cap=max_slots,
            slice_tokens=slice_tokens, restore_timeout=restore_timeout),
            recorder=recorder, tracer=tracer,
            faults=faults, recovery=recovery)
        self.result: Optional[ServeResult] = None

    @property
    def outputs(self) -> Dict[int, List[int]]:
        return self.backend.outputs

    @property
    def n_prefill_shapes(self) -> int:
        return self.backend.n_prefill_shapes

    @property
    def interleaved_decode_steps(self) -> int:
        return self.result.interleaved_decode_steps if self.result else 0

    def submit(self, requests: List[Request]) -> None:
        self._pending = list(requests)

    def run(self, max_wall_s: float = 600.0) -> List[Request]:
        self.result = self.loop.run(self._pending, time_limit=math.inf,
                                    max_wall_s=max_wall_s)
        return [r for r in self._pending
                if r.finished >= 0 and not r.dropped]
