"""Real JAX serving engine — BucketServe policies driving actual models.

This is the execution layer the simulator's cost model stands in for at
paper scale: at tiny-model scale (CPU) it runs the *same* scheduler
objects against real jitted prefill/decode computations, token for token.

TPU-native continuous batching (DESIGN.md §3): the decode pool is a
FIXED-CAPACITY slot tensor — cache pytree with a leading slot axis, an
alive mask, and per-slot next-token ids.  Each iteration decodes all
slots (dead slots compute garbage that is masked); completed requests
free their slot and new prefilled requests are scattered in.  Static
shapes throughout: one compiled executable per bucket pad-shape for
prefill (bucketing bounds the executable count — the recompilation
argument for bucketing on TPU), one for decode.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from .request import Request
from .scheduler import BucketServeScheduler


def _insert_slot(pool_cache, batch_cache, slot: int, b: int):
    """Copy sequence `b` of a prefill cache into pool slot `slot`."""
    pos = pool_cache["pos"].at[slot].set(batch_cache["pos"][b])
    groups = jax.tree.map(
        lambda pl, bc: pl.at[:, slot].set(bc[:, b]),
        pool_cache["groups"], batch_cache["groups"])
    return {"pos": pos, "groups": groups}


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, scheduler, *,
                 max_slots: int = 8, cache_len: Optional[int] = None,
                 moe_impl: str = "local", time_scale: float = 1.0):
        self.cfg = cfg
        self.params = params
        self.sched = scheduler
        self.max_slots = max_slots
        self.cache_len = cache_len or cfg.max_seq_len
        self.moe_impl = moe_impl
        self.time_scale = time_scale       # virtual seconds per wall second

        self.pool_cache = tfm.init_cache(cfg, max_slots, self.cache_len)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.next_tok = jnp.zeros((max_slots,), jnp.int32)
        self.outputs: Dict[int, List[int]] = {}
        self._prefill_fns: Dict[tuple, callable] = {}
        self._decode_fn = jax.jit(
            lambda p, t, c: tfm.decode_step(cfg, p, t, c,
                                            moe_impl=moe_impl))
        self.n_prefill_shapes = 0

    # ------------------------------------------------------------- jits --
    def _prefill_fn(self, pad_to: int, bsz: int):
        key = (pad_to, bsz)
        if key not in self._prefill_fns:
            cfg, moe_impl = self.cfg, self.moe_impl

            def fn(p, tokens, lengths):
                return tfm.prefill(cfg, p, tokens=tokens, lengths=lengths,
                                   cache_len=self.cache_len,
                                   moe_impl=moe_impl)
            self._prefill_fns[key] = jax.jit(fn)
            self.n_prefill_shapes += 1
        return self._prefill_fns[key]

    # -------------------------------------------------------------- api --
    def submit(self, requests: List[Request]) -> None:
        for r in requests:
            if r.tokens is None:
                rng = np.random.default_rng(r.rid)
                r.tokens = rng.integers(
                    0, self.cfg.vocab_size, r.prompt_len).astype(np.int32)
            self.outputs[r.rid] = []
        self._pending = sorted(requests, key=lambda r: r.arrival)
        self._t0 = time.perf_counter()

    def _now(self) -> float:
        return (time.perf_counter() - self._t0) * self.time_scale

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def run(self, max_wall_s: float = 600.0) -> List[Request]:
        done: List[Request] = []
        n_total = len(self._pending)
        arrived = 0
        while len(done) < n_total:
            if time.perf_counter() - self._t0 > max_wall_s:
                break
            now = self._now()
            while arrived < n_total and self._pending[arrived].arrival <= now:
                self.sched.on_arrival(self._pending[arrived], now)
                arrived += 1

            free = self._free_slots()
            progressed = False
            if self.sched.queued() and free:
                batch = self.sched.next_prefill_batch(now)
                if batch is not None:
                    reqs = batch.requests
                    if len(reqs) > len(free):   # slot-capacity clamp
                        for r in reqs[len(free):]:
                            self.sched.on_arrival(r, now)
                        reqs = reqs[:len(free)]
                    self._do_prefill(reqs, max(batch.pad_to, 8), done)
                    progressed = True
            if any(r is not None for r in self.slot_req):
                self._do_decode_iter(done)
                progressed = True
            if not progressed:
                if arrived < n_total:
                    time.sleep(min(
                        0.001,
                        max(self._pending[arrived].arrival - now, 0)
                        / self.time_scale))
                else:
                    break
        return done

    # ------------------------------------------------------- internals --
    def _do_prefill(self, reqs: List[Request], pad_to: int, done):
        now = self._now()
        B = len(reqs)
        toks = np.zeros((B, pad_to), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(reqs):
            L = min(r.prompt_len, pad_to)
            toks[i, :L] = r.tokens[:L]
            lens[i] = L
            r.prefill_start = now
        fn = self._prefill_fn(pad_to, B)
        logits, cache = fn(self.params, jnp.asarray(toks), jnp.asarray(lens))
        first = jnp.argmax(logits, -1).astype(jnp.int32)
        now = self._now()
        for i, r in enumerate(reqs):
            r.first_token = now
            r.generated = 1
            self.outputs[r.rid].append(int(first[i]))
            if r.max_new_tokens <= 1 or not self.cfg.has_decode:
                r.finished = now
                done.append(r)
                continue
            slot = self._free_slots()[0]
            self.pool_cache = _insert_slot(self.pool_cache, cache, slot, i)
            self.next_tok = self.next_tok.at[slot].set(first[i])
            self.slot_req[slot] = r
            self.sched.admit_decode(r)

    def _do_decode_iter(self, done):
        logits, self.pool_cache = self._decode_fn(
            self.params, self.next_tok, self.pool_cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        self.next_tok = nxt
        now = self._now()
        for slot, r in enumerate(self.slot_req):
            if r is None:
                continue
            r.generated += 1
            self.outputs[r.rid].append(int(nxt[slot]))
            if r.generated >= r.max_new_tokens:
                r.finished = now
                done.append(r)
                self.slot_req[slot] = None
                self.sched.release_decode(r)
