"""Observability layer: per-request latency ledger + typed event
timeline (DESIGN.md §7).

Two instruments, both backend-agnostic (they stamp whatever clock the
ServingLoop runs on — virtual seconds on the cost model, scaled wall
seconds on the engine):

* :class:`LatencyLedger` — a phase state machine every ``Request``
  carries.  A request is in exactly ONE phase at any instant; each
  transition accumulates the elapsed interval into the phase being
  left.  Because transitions are stamped with the loop's monotonic
  clock and the partition is exhaustive, a **conservation invariant**
  holds by construction: the phase durations sum to
  ``closed_at - t0`` (first arrival to retirement) to float tolerance
  — asserted in tests for every request in both backends, including
  dropped ones (their phases sum to the drop time).

* :class:`Tracer` — a typed event sink (complete/instant/counter/async
  spans) exportable as Chrome trace-event JSON, so a serve run opens
  directly in ``ui.perfetto.dev`` with one track per bucket / spill
  channel / executor.  The disabled default (:data:`NULL_TRACER`) is a
  zero-overhead seam: every hot-path call site guards on
  ``tracer.enabled`` before building any argument, so a disabled run
  performs no tracer calls and no event allocations at all — the
  regression test drives the loop with a tracer whose methods *raise*
  (enabled=False) and must complete untouched.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------- ledger --
#: The exhaustive, non-overlapping phase partition of a request's life:
#:   queue           — bucket dwell: arrival (or requeue release) until
#:                     batch dispatch
#:   admission_block — waiting after a slot-capacity / KV-page clamp
#:                     bounced the request back to the queue
#:   requeue_gap     — the restart-penalty window after an OOM eviction
#:                     or a mid-decode preemption (time past the window
#:                     spills into ``queue``)
#:   restore_hold    — parked while a host->device KV restore is in
#:                     flight (core/retention.py spill tier)
#:   formed          — dispatched into a formed batch, not yet executing
#:                     (batch-formation overhead on the request clock;
#:                     the scheduler's own bucketing cost is accounted
#:                     separately as ``bucketing_overhead_s``)
#:   prefill         — prompt chunks running (includes inter-chunk
#:                     residency while decode interleaves)
#:   transfer        — prefill->decode KV transfer + decode-slot wait
#:                     (disagg topology only)
#:   decode          — live in the decode pool until finish/preemption
#:   fault_retry     — stalled on an injected/substrate fault while the
#:                     recovery policy backs off and retries (core/
#:                     faults.py); zero in a fault-free run
PHASES = ("queue", "admission_block", "requeue_gap", "restore_hold",
          "formed", "prefill", "transfer", "decode", "fault_retry")

#: Phases that are WAITING (scheduler-inflicted) rather than compute —
#: the numerator of the latency-blame share the burst-tail gates read.
#: Fault backoff counts as waiting: the request burned wall time without
#: compute progressing.
WAIT_PHASES = ("queue", "admission_block", "requeue_gap", "restore_hold",
               "fault_retry")

#: Conservation tolerance: phase sums are chains of float adds over the
#: same stamps the end-to-end subtraction uses, so only accumulation
#: roundoff can appear.
CONSERVE_TOL = 1e-6


class LatencyLedger:
    """Per-request phase accounting (see :data:`PHASES`).

    ``seq`` records the *transition labels* in order (phase re-entries
    that don't change phase are accumulated silently) — the surface the
    engine-vs-sim parity suite compares, since wall/virtual durations
    legitimately differ but the decision sequence must not.
    """

    __slots__ = ("t0", "closed_at", "phases", "seq", "ttft_phases",
                 "_cur", "_since", "_gap_until")

    def __init__(self) -> None:
        self.t0 = -1.0                       # FIRST arrival (requeues
        #                                      overwrite Request.arrival)
        self.closed_at = -1.0
        self.phases: Dict[str, float] = {}
        self.seq: List[str] = []
        # phase breakdown frozen at first-token time (what TTFT blame
        # reads); overwritten if a preemption forces a second prefill
        self.ttft_phases: Optional[Dict[str, float]] = None
        self._cur: Optional[str] = None
        self._since = 0.0
        self._gap_until = -1.0

    # ------------------------------------------------------------ state --
    @property
    def started(self) -> bool:
        return self.t0 >= 0.0

    @property
    def closed(self) -> bool:
        return self.closed_at >= 0.0

    # ------------------------------------------------------ transitions --
    def start(self, t: float) -> None:
        """First arrival: the request enters ``queue`` at ``t``."""
        assert not self.started, "ledger already started"
        self.t0 = t
        self._cur = "queue"
        self._since = t
        self.seq.append("queue")

    def _accumulate(self, t: float) -> None:
        assert self.started and not self.closed, (self.t0, self.closed_at)
        assert t >= self._since - 1e-9, \
            f"non-monotonic ledger stamp: {t} < {self._since} in {self._cur}"
        t = max(t, self._since)
        ph = self.phases
        if self._cur == "requeue_gap" and self._gap_until >= 0.0:
            # split at the penalty-window end: the remainder is ordinary
            # queueing (the request was schedulable again)
            cut = min(max(self._gap_until, self._since), t)
            ph["requeue_gap"] = ph.get("requeue_gap", 0.0) \
                + (cut - self._since)
            if t > cut:
                ph["queue"] = ph.get("queue", 0.0) + (t - cut)
            self._gap_until = -1.0
        else:
            ph[self._cur] = ph.get(self._cur, 0.0) + (t - self._since)
        self._since = t

    def to(self, phase: str, t: float) -> None:
        """Transition into ``phase`` at time ``t`` (no-op accumulate if
        already there)."""
        assert phase in PHASES, phase
        self._accumulate(t)
        if phase != self._cur:
            self._cur = phase
            self.seq.append(phase)

    def gap(self, t: float, until: float) -> None:
        """Enter the restart-penalty window at ``t``; time past
        ``until`` counts as ``queue`` again."""
        self.to("requeue_gap", t)
        self._gap_until = until

    def mark_first(self, t: float) -> None:
        """First token stamped at ``t``: freeze the TTFT-phase view."""
        self._accumulate(t)
        self.ttft_phases = dict(self.phases)

    def close(self, t: float) -> None:
        """Retirement (finish OR drop) at ``t``."""
        self._accumulate(t)
        self.closed_at = t
        self._cur = None

    # ----------------------------------------------------- conservation --
    def total(self) -> float:
        return sum(self.phases.values())

    def residual(self) -> float:
        """Conservation defect: ``(closed_at - t0) - sum(phases)``."""
        assert self.closed, "ledger still open"
        return (self.closed_at - self.t0) - self.total()

    def conserved(self, tol: float = CONSERVE_TOL) -> bool:
        return self.closed and abs(self.residual()) <= tol

    def wait_share(self, phases: Optional[Dict[str, float]] = None) -> float:
        """Fraction of the (given or lifetime) phase sum spent WAITING
        (:data:`WAIT_PHASES`) rather than in compute/transfer."""
        ph = self.phases if phases is None else phases
        tot = sum(ph.values())
        if tot <= 0.0:
            return 0.0
        return sum(ph.get(p, 0.0) for p in WAIT_PHASES) / tot


def blame_means(samples: List[Dict[str, float]]) -> Dict[str, float]:
    """Mean seconds per phase over a list of phase dicts (the ONE
    aggregation rule `ServeResult.blame` and the monitor share)."""
    if not samples:
        return {}
    out: Dict[str, float] = {}
    for p in PHASES:
        tot = sum(s.get(p, 0.0) for s in samples)
        if tot > 0.0:
            out[p] = tot / len(samples)
    return out


# ---------------------------------------------------------------- tracer --
class NullTracer:
    """Disabled tracer: ``enabled`` is False and every emit is a no-op.
    Hot-path call sites must guard on ``enabled`` BEFORE building event
    arguments — that guard, not these no-op bodies, is the zero-overhead
    contract (DESIGN.md §7)."""

    enabled = False

    def track(self, name: str) -> int:
        return 0

    def complete(self, track, name, ts, dur, cat="span", args=None) -> None:
        pass

    def instant(self, track, name, ts, cat="event", args=None) -> None:
        pass

    def counter(self, track, name, ts, values) -> None:
        pass

    def async_begin(self, track, name, ts, id_, cat="request",
                    args=None) -> None:
        pass

    def async_end(self, track, name, ts, id_, cat="request",
                  args=None) -> None:
        pass

    def export(self) -> Dict:
        return {"traceEvents": []}


#: Module singleton: the default `tracer` attribute everywhere.
NULL_TRACER = NullTracer()


class Tracer:
    """Collects Chrome trace-event records (`ph`: X/i/C/b/e) with one
    pseudo-thread per named track.  Timestamps are the loop clock's
    seconds, stored as microseconds (the trace-event unit).  ``export``
    sorts by timestamp (emission order is NOT monotonic — batch spans
    are emitted at completion with their start stamp) and prepends
    thread-name metadata so Perfetto renders named tracks."""

    enabled = True

    def __init__(self) -> None:
        self.events: List[Dict] = []
        self._tracks: Dict[str, int] = {}

    # ------------------------------------------------------------ tracks --
    def track(self, name: str) -> int:
        tid = self._tracks.get(name)
        if tid is None:
            tid = len(self._tracks) + 1
            self._tracks[name] = tid
        return tid

    # ------------------------------------------------------------- emits --
    def _ev(self, ph: str, track: str, name: str, ts: float, cat: str,
            args: Optional[Dict]) -> Dict:
        ev = {"name": name, "cat": cat, "ph": ph, "ts": ts * 1e6,
              "pid": 1, "tid": self.track(track)}
        if args is not None:
            ev["args"] = args
        self.events.append(ev)
        return ev

    def complete(self, track: str, name: str, ts: float, dur: float,
                 cat: str = "span", args: Optional[Dict] = None) -> None:
        ev = self._ev("X", track, name, ts, cat, args)
        ev["dur"] = max(dur, 0.0) * 1e6

    def instant(self, track: str, name: str, ts: float,
                cat: str = "event", args: Optional[Dict] = None) -> None:
        ev = self._ev("i", track, name, ts, cat, args)
        ev["s"] = "t"                                  # thread-scoped

    def counter(self, track: str, name: str, ts: float,
                values: Dict[str, float]) -> None:
        self._ev("C", track, name, ts, "counter", dict(values))

    def async_begin(self, track: str, name: str, ts: float, id_,
                    cat: str = "request",
                    args: Optional[Dict] = None) -> None:
        self._ev("b", track, name, ts, cat, args)["id"] = id_

    def async_end(self, track: str, name: str, ts: float, id_,
                  cat: str = "request",
                  args: Optional[Dict] = None) -> None:
        self._ev("e", track, name, ts, cat, args)["id"] = id_

    # ------------------------------------------------------------ export --
    def export(self) -> Dict:
        meta: List[Dict] = [{"name": "process_name", "ph": "M", "pid": 1,
                             "args": {"name": "bucketserve"}}]
        for name, tid in sorted(self._tracks.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                         "tid": tid, "args": {"name": name}})
        # stable sort: a 'b' emitted before its same-stamp 'e' stays first
        return {"traceEvents": meta + sorted(self.events,
                                             key=lambda e: e["ts"]),
                "displayTimeUnit": "ms"}

    def save(self, path: str) -> Dict:
        doc = self.export()
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc


# ------------------------------------------------------------ validation --
_VALID_PH = ("X", "i", "C", "b", "e", "M")


def validate_perfetto(doc) -> List[str]:
    """Schema check for an exported trace-event document.  Returns a
    list of problems (empty = valid): monotonic non-negative ``ts`` in
    file order, ``X`` spans with non-negative ``dur``, non-empty
    numeric ``C`` counter args, and balanced ``b``/``e`` async pairs
    per (cat, id) with no orphan ends."""
    errs: List[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["missing traceEvents list"]
    last_ts = -math.inf
    open_async: Dict[Tuple, int] = {}
    for i, e in enumerate(doc["traceEvents"]):
        if not isinstance(e, dict) or "name" not in e:
            errs.append(f"event {i}: not an object with a name")
            continue
        ph = e.get("ph")
        if ph not in _VALID_PH:
            errs.append(f"event {i} ({e['name']}): unknown ph {ph!r}")
            continue
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"event {i} ({e['name']}): bad ts {ts!r}")
            continue
        if ts < last_ts:
            errs.append(f"event {i} ({e['name']}): non-monotonic ts "
                        f"{ts} < {last_ts}")
        last_ts = ts
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i} ({e['name']}): X without "
                            f"non-negative dur ({dur!r})")
        elif ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                errs.append(f"event {i} ({e['name']}): counter needs "
                            "non-empty numeric args")
        elif ph in ("b", "e"):
            if "id" not in e:
                errs.append(f"event {i} ({e['name']}): async without id")
                continue
            key = (e.get("cat"), e["id"])
            if ph == "b":
                open_async[key] = open_async.get(key, 0) + 1
            elif open_async.get(key, 0) <= 0:
                errs.append(f"event {i} ({e['name']}): orphan async end "
                            f"{key}")
            else:
                open_async[key] -= 1
    for key, n in open_async.items():
        if n:
            errs.append(f"unbalanced async span {key}: {n} unclosed")
    return errs
