"""Deterministic fault-injection plane (DESIGN.md §9).

The reproduction's robustness claims are only testable if the substrate
can FAIL on demand — and only debuggable if it fails the SAME way every
run.  This module is the seeded chaos seam both execution backends and
the retention layer consult at their typed injection sites:

* ``decode_step``    — transient device error on a decode iteration
                       (the loop backs off and retries the step);
* ``prefill_chunk``  — a prefill chunk fails (retry with backoff;
                       repeated failure abandons the job and may
                       quarantine poisoned requests);
* ``restore_stall``  — the host->device restore channel stalls for
                       ``stall_s`` virtual seconds (held requests hit
                       the loop's restore timeout and re-prefill cold);
* ``restore_error``  — a restore transfer hard-fails (retention retries
                       with backoff, burning the channel, then cancels
                       the in-flight restores and degrades to
                       recompute);
* ``host_corrupt``   — a host slot's content rots AT SPILL TIME; the
                       per-slot checksum stamped by the retention layer
                       detects it at restore-commit and the page is
                       discarded instead of served;
* ``maintain_tick``  — a housekeeping tick is lost (clock hiccup); TTL
                       expiry and restore completion slip one iteration.

Determinism contract: every decision is a PURE function of
``(plan.seed, site, counter)`` where ``counter`` is the per-site draw
index — never the clock, never Python's global RNG.  Two runs with the
same plan draw identical fault sequences, and because both backends
share the loop/retention code paths that draw, a faulted run replays
bit-identically into either substrate (the chaos extension of the
engine-vs-sim parity surface).  The mixer is splitmix64 (integer-only,
~30 ns per draw) so high-frequency sites stay off the profile.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Tuple

# the typed injection sites — ``FaultPlan`` rejects anything else so a
# typo'd spec fails loudly instead of silently never firing
SITES: Tuple[str, ...] = ("decode_step", "prefill_chunk", "restore_stall",
                          "restore_error", "host_corrupt", "maintain_tick")

_M64 = (1 << 64) - 1
_GOLDEN = 0x9E3779B97F4A7C15


def _mix64(x: int) -> int:
    """splitmix64 finalizer: a bijective avalanche on 64-bit ints."""
    x = (x + _GOLDEN) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


def _u01(seed: int, site_id: int, counter: int) -> float:
    """Uniform [0, 1) from the (seed, site, counter) triple — THE
    determinism contract.  53 mantissa bits of a double."""
    h = _mix64(_mix64(seed & _M64) ^ _mix64((site_id * _GOLDEN) & _M64)
               ^ (counter & _M64))
    return (h >> 11) * (1.0 / (1 << 53))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-site fire probabilities + fault magnitudes.  Immutable so a
    plan can be shared between a reference and a chaos run, serialized
    into a trace header, or round-tripped through ``spec()``."""

    seed: int = 0
    rates: Mapping[str, float] = dataclasses.field(default_factory=dict)
    stall_s: float = 30.0          # restore-channel stall magnitude

    def __post_init__(self):
        for site, rate in self.rates.items():
            assert site in SITES, f"unknown fault site {site!r}"
            assert 0.0 <= rate <= 1.0, (site, rate)

    def rate(self, site: str) -> float:
        return self.rates.get(site, 0.0)

    @property
    def any_armed(self) -> bool:
        return any(r > 0.0 for r in self.rates.values())

    # ------------------------------------------------ spec round-trip --
    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the compact CLI form, e.g.
        ``"seed=7,decode_step=0.02,restore_stall=0.5,stall_s=5"``.
        Keys are sites (value = rate) or the scalars seed / stall_s."""
        seed, stall_s, rates = 0, 30.0, {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            key, _, val = part.partition("=")
            key = key.strip()
            if key == "seed":
                seed = int(val)
            elif key == "stall_s":
                stall_s = float(val)
            else:
                assert key in SITES, f"unknown fault site {key!r} in spec"
                rates[key] = float(val)
        return cls(seed=seed, rates=rates, stall_s=stall_s)

    def spec(self) -> str:
        parts = [f"seed={self.seed}"] + [
            f"{s}={self.rates[s]:g}" for s in SITES if s in self.rates]
        parts.append(f"stall_s={self.stall_s:g}")
        return ",".join(parts)


class FaultInjector:
    """Draws fault decisions against a :class:`FaultPlan` and keeps the
    replay log.  One injector per run; the loop threads it through both
    backends and the retention layer, so every draw site is shared code
    and the per-site counters advance identically on both substrates."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._counters: Dict[str, int] = {s: 0 for s in SITES}
        self._site_ids: Dict[str, int] = {s: i for i, s in enumerate(SITES)}
        # replay surface: every FIRED event as (site, counter)
        self.log: List[Tuple[str, int]] = []

    def fire(self, site: str) -> bool:
        """One decision at ``site``.  Advances the site counter whether
        or not the fault fires — the counter indexes DRAWS, so the
        decision stream is independent of what other sites do."""
        c = self._counters[site]
        self._counters[site] = c + 1
        rate = self.plan.rate(site)
        if rate <= 0.0:
            return False
        fired = _u01(self.plan.seed, self._site_ids[site], c) < rate
        if fired:
            self.log.append((site, c))
        return fired

    def draws(self, site: str) -> int:
        return self._counters[site]

    def fired(self, site: str) -> List[int]:
        """Counters at which ``site`` fired, in draw order — the
        per-site sequence the cross-backend parity gate compares."""
        return [c for s, c in self.log if s == site]

    def fired_count(self) -> int:
        return len(self.log)
