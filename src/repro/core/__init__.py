"""BucketServe core: the paper's contribution as composable modules."""
from .request import Request, TaskType                      # noqa: F401
from .bucket import Bucket, BucketManager                   # noqa: F401
from .batcher import (DynamicBatchController, FormedBatch,  # noqa: F401
                      MemoryBudget)
from .scheduler import (BucketServeScheduler,               # noqa: F401
                        GoodputScheduler, SchedulerBase, SchedulerConfig)
from .monitor import GlobalMonitor                          # noqa: F401
from .paging import BlockAllocator                          # noqa: F401
from .prefix_cache import PrefixCache, PrefixStats          # noqa: F401
from .retention import KvRetention, RetentionStats          # noqa: F401
from .serving_loop import (Clock, ExecutionBackend,         # noqa: F401
                           LoopConfig, PrefillJob, ServeResult,
                           ServingLoop, VirtualClock, WallClock)
