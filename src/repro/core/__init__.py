"""BucketServe core: the paper's contribution as composable modules."""
from .request import Request, TaskType                      # noqa: F401
from .bucket import Bucket, BucketManager                   # noqa: F401
from .batcher import (DynamicBatchController, FormedBatch,  # noqa: F401
                      MemoryBudget)
from .scheduler import BucketServeScheduler, SchedulerConfig  # noqa: F401
from .monitor import GlobalMonitor                          # noqa: F401
