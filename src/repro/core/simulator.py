"""Discrete-event simulator: paper-scale end-to-end serving experiments.

The container is CPU-only, so the paper's 4×A100 experiments (Fig. 5/6)
are reproduced on an analytic cost model; the same scheduler objects also
drive the *real* JAX engine (core/engine.py) at tiny-model scale, which
is how the cost model's scheduling behaviour is validated.

Cost model:
  prefill (compute-bound):  t = FLOPs(padded tokens) / (chips·peak·MFU)
  decode  (memory-bound) :  t = max(weight+KV bytes / (chips·BW·eff),
                                     FLOPs / (chips·peak·MFU))
  KV transfer prefill->decode over NVLink (A100) / ICI (TPU).

OOM semantics: schedulers admitting more live KV tokens than the device
budget trigger an OOM event — the offending batch is evicted and
re-queued after a restart penalty (models vLLM preemption/recompute).
BucketServe's Eq. (5)/(6) memory safety avoids these by construction.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

from repro.models.config import ModelConfig
from .batcher import FormedBatch, MemoryBudget
from .request import Request, TaskType


# ------------------------------------------------------------- hardware ---
@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float            # per chip, bf16
    hbm_bw: float                # per chip
    link_bw: float               # inter-chip (KV transfer)
    hbm_bytes: int               # per chip
    prefill_chips: int = 2
    decode_chips: int = 2
    mfu: float = 0.55            # achievable fraction of peak in prefill
    bw_eff: float = 0.80         # achievable fraction of HBM bandwidth


A100X4 = HardwareSpec("a100x4", 312e12, 1.555e12, 300e9, 40 * 2 ** 30,
                      prefill_chips=2, decode_chips=2)
V5E_POD = HardwareSpec("v5e", 197e12, 819e9, 50e9, 16 * 2 ** 30,
                       prefill_chips=128, decode_chips=128)


class CostModel:
    def __init__(self, cfg: ModelConfig, hw: HardwareSpec,
                 bytes_per_el: int = 2):
        self.cfg = cfg
        self.hw = hw
        self.b = bytes_per_el
        self.p_active = cfg.active_param_count()
        # honors the int8-KV serving variant (halved cache traffic/budget)
        self.kv_per_tok = max(cfg.cache_bytes_per_token(), 1)
        self.weight_bytes = cfg.param_count() * bytes_per_el

    def _attn_flops(self, s: int) -> float:
        """Quadratic attention FLOPs per sequence of length s (score+value)."""
        win = self.cfg.sliding_window or (
            self.cfg.local_window if self.cfg.arch_type == "hybrid" else 0)
        if self.cfg.attention_free:
            return 2.0 * 2 * self.cfg.n_layers * self.cfg.d_model * s * 64
        eff = min(s, win) if win else s
        n_attn = self.cfg.n_layers
        return 2.0 * 2 * n_attn * self.cfg.n_heads * self.cfg.d_head * s * eff

    def prefill_seconds(self, n: int, pad_to: int) -> float:
        tokens = n * pad_to                      # padded compute (TPU shapes)
        flops = 2.0 * self.p_active * tokens + n * self._attn_flops(pad_to)
        chips = self.hw.prefill_chips
        return flops / (chips * self.hw.peak_flops * self.hw.mfu)

    def decode_iter_seconds(self, context_tokens: int, pool: int) -> float:
        """One iteration over the decode pool (one token each).
        `context_tokens`: KV tokens actually READ this iteration — exact
        live tokens for continuous/paged systems, padded-batch tokens for
        batch-granularity systems (the paper's Fig. 3b waste)."""
        if pool == 0:
            return 0.0
        chips = self.hw.decode_chips
        mem = (self.weight_bytes / chips +
               context_tokens * self.kv_per_tok / chips) / \
            (self.hw.hbm_bw * self.hw.bw_eff)
        comp = 2.0 * self.p_active * pool / (chips * self.hw.peak_flops
                                             * self.hw.mfu)
        return max(mem, comp)

    def transfer_seconds(self, prompt_tokens: int) -> float:
        return prompt_tokens * self.kv_per_tok / self.hw.link_bw

    def kv_budget_tokens(self, chips: int, reserve: float = 0.10,
                         act_reserve: float = 0.05) -> float:
        total = self.hw.hbm_bytes * chips
        remain = total - self.weight_bytes - act_reserve * total
        return max(0.0, (1 - reserve) * remain) / self.kv_per_tok


# ------------------------------------------------------------- results ----
@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    makespan: float
    busy_prefill: float
    busy_decode: float
    useful_flops: float
    padded_flops: float
    oom_events: int
    bucketing_overhead_s: float
    prefill_time_total: float = 0.0
    decode_time_total: float = 0.0
    transfer_time_total: float = 0.0

    def finished(self):
        return [r for r in self.requests if r.finished >= 0]

    def throughput_tok_s(self) -> float:
        toks = sum(r.generated + r.prompt_len for r in self.finished())
        return toks / max(self.makespan, 1e-9)

    def output_tok_s(self) -> float:
        return sum(r.generated for r in self.finished()) / max(self.makespan, 1e-9)

    def server_rps(self) -> float:
        return len(self.finished()) / max(self.makespan, 1e-9)

    def slo_attainment(self) -> float:
        if not self.requests:
            return 0.0
        return sum(r.slo_met() for r in self.requests) / len(self.requests)

    def utilization(self, hw: HardwareSpec) -> float:
        """Model-FLOPs utilization over the busy window (the simulator's
        analogue of the paper's GPU-utilization metric)."""
        chips = hw.prefill_chips + hw.decode_chips
        return self.useful_flops / max(
            chips * hw.peak_flops * self.makespan, 1e-9)

    def padding_efficiency(self) -> float:
        return self.useful_flops / max(self.padded_flops, 1e-9)

    def busy_utilization(self, n_executors: int = 2) -> float:
        """Fraction of executor-time busy — the closest analogue of the
        paper's 'average GPU utilization' (Fig. 5b)."""
        return min(1.0, (self.busy_prefill + self.busy_decode)
                   / max(n_executors * self.makespan, 1e-9))


# ------------------------------------------------------------ simulator ---
class Simulator:
    """P/D serving simulation in one of three execution modes:

    * ``disagg``  — separate prefill/decode executors + KV transfer
      (BucketServe, DistServe).
    * ``coupled`` — ONE executor; each iteration fuses the new prefill
      batch (if any) with one decode step over the live pool — Orca-style
      iteration-level scheduling.  Prefill work inflates every concurrent
      request's TPOT: the phase interference DistServe/BucketServe remove.
    * ``static``  — one executor; a batch runs prefill + ALL decode steps
      to completion before the next batch starts (naive static batching).
    """

    def __init__(self, scheduler, cost: CostModel, *, mode: str = "disagg",
                 decode_slot_cap: int = 256, restart_penalty: float = 0.5,
                 tick: float = 0.005):
        assert mode in ("disagg", "coupled", "static")
        self.sched = scheduler
        self.cost = cost
        self.mode = mode
        self.decode_slot_cap = decode_slot_cap
        self.restart_penalty = restart_penalty
        self.tick = tick

    # ------------------------------------------------------------------
    def run(self, requests: List[Request],
            time_limit: float = 3600.0) -> SimResult:
        cost, sched = self.cost, self.sched
        arrivals = sorted(requests, key=lambda r: r.arrival)
        self._n = len(requests)
        st = _SimState(kv_budget=cost.kv_budget_tokens(
            cost.hw.decode_chips if self.mode == "disagg"
            else cost.hw.decode_chips + cost.hw.prefill_chips))
        if self.mode == "disagg":
            self._run_disagg(arrivals, st, time_limit)
        else:
            self._run_coupled(arrivals, st, time_limit)
        overhead = getattr(getattr(sched, "buckets", None), "overhead_s", 0.0)
        return SimResult(requests=requests, makespan=st.now,
                         busy_prefill=st.busy_p, busy_decode=st.busy_d,
                         useful_flops=st.useful, padded_flops=st.padded,
                         oom_events=st.oom, bucketing_overhead_s=overhead,
                         prefill_time_total=st.t_pre,
                         decode_time_total=st.t_dec,
                         transfer_time_total=st.t_xfer)

    # ------------------------------------------------------------ util --
    def _admit_arrivals(self, arrivals, st):
        while st.ai < len(arrivals) and arrivals[st.ai].arrival <= st.now:
            self.sched.on_arrival(arrivals[st.ai], arrivals[st.ai].arrival)
            st.ai += 1

    @staticmethod
    def _live_tokens(pool):
        return sum(r.prompt_len + r.generated for r in pool)

    def _finish_iteration(self, pool, st, end_time):
        """Advance every pooled request one token; retire finished ones."""
        cost = self.cost
        st.useful += 2.0 * cost.p_active * len(pool)
        st.padded += 2.0 * cost.p_active * len(pool)
        for r in list(pool):
            r.generated += 1
            if r.generated >= r.max_new_tokens:
                r.finished = end_time
                st.done += 1
                pool.remove(r)
                self.sched.release_decode(r)

    def _handle_oom(self, batch, st):
        """Evict + re-queue; oversized singletons are dropped (unservable);
        the scheduler's retry backoff (notify_oom) shrinks its next cap."""
        if hasattr(self.sched, "notify_oom"):
            self.sched.notify_oom()
        for r in batch.requests:
            if r.prompt_len + r.max_new_tokens > st.kv_budget:
                r.dropped = True
                r.finished = -1.0
                st.done += 1
                continue
            r.arrival = st.now + self.restart_penalty
            self.sched.on_arrival(r, r.arrival)

    def _account_prefill(self, batch, dt, st):
        cost = self.cost
        st.busy_p += dt
        st.t_pre += dt * batch.size
        st.useful += 2.0 * cost.p_active * batch.total_tokens
        st.padded += 2.0 * cost.p_active * batch.padded_tokens

    # --------------------------------------------------------- disagg --
    def _run_disagg(self, arrivals, st, time_limit):
        cost, sched = self.cost, self.sched
        pool: List[Request] = []
        pending_join: List[list] = []     # [ready_time, req]
        prefill_free = decode_free = 0.0

        while st.done < self._n and st.now < time_limit:
            self._admit_arrivals(arrivals, st)
            for item in list(pending_join):
                if item[0] <= st.now and len(pool) < self.decode_slot_cap:
                    pool.append(item[1])
                    pending_join.remove(item)

            progressed = False
            if prefill_free <= st.now and sched.queued():
                batch = sched.next_prefill_batch(st.now)
                if batch is not None:
                    batch_tokens = sum(r.prompt_len + r.max_new_tokens
                                       for r in batch.requests)
                    pending_tokens = sum(
                        it[1].prompt_len + it[1].max_new_tokens
                        for it in pending_join)
                    if (self._live_tokens(pool) + pending_tokens
                            + batch_tokens > st.kv_budget):
                        st.oom += 1
                        self._handle_oom(batch, st)
                        prefill_free = st.now + self.restart_penalty
                    else:
                        dt = cost.prefill_seconds(batch.size, batch.pad_to)
                        xfer = cost.transfer_seconds(batch.total_tokens)
                        for r in batch.requests:
                            r.prefill_start = st.now
                            r.first_token = st.now + dt
                            r.generated = 1
                            if r.generated >= r.max_new_tokens:
                                r.finished = st.now + dt
                                st.done += 1
                            else:
                                # KV allocated AT PREFILL: account it now so
                                # the batcher's Eq. (6) sees in-transfer
                                # caches too (prevents admission overshoot).
                                sched.admit_decode(r)
                                pending_join.append([st.now + dt + xfer, r])
                        prefill_free = st.now + dt
                        self._account_prefill(batch, dt, st)
                        st.t_xfer += xfer * batch.size
                    progressed = True
            if decode_free <= st.now and pool:
                dt = cost.decode_iter_seconds(self._live_tokens(pool),
                                              len(pool))
                decode_free = st.now + dt
                st.busy_d += dt
                st.t_dec += dt * len(pool)
                self._finish_iteration(pool, st, st.now + dt)
                progressed = True

            if not progressed:
                cands = [c for c in
                         [prefill_free if sched.queued() else None,
                          decode_free if pool else None,
                          arrivals[st.ai].arrival if st.ai < len(arrivals)
                          else None]
                         + [it[0] for it in pending_join]
                         if c is not None and c > st.now]
                st.now = min(cands) if cands else st.now + self.tick

    # --------------------------------------------------------- coupled --
    def _run_coupled(self, arrivals, st, time_limit):
        """Orca/UELLM-style single-executor engines.

        * ``coupled`` (Orca): iteration-level — each iteration fuses the
          new prefill batch with one decode step over the live pool; exact
          (selective-batching) KV reads, but prefill inflates every
          concurrent TPOT (phase interference).
        * ``static`` (naive static batching, UELLM batch-granularity):
          a formed batch runs prefill + decode TO COMPLETION.  Every
          iteration reads the PADDED batch context (all slots padded to
          the batch max prompt) and the executor is held until the
          longest member finishes (convoy effect).  This is the mixed-
          batch decode waste of paper Fig. 3b.
        """
        cost, sched = self.cost, self.sched
        pool: List[Request] = []
        static = self.mode == "static"

        while st.done < self._n and st.now < time_limit:
            self._admit_arrivals(arrivals, st)
            batch = None
            can_admit = ((not static) or not pool) and \
                st.now >= st.oom_cooldown_until
            if sched.queued() and can_admit and \
                    len(pool) < self.decode_slot_cap:
                batch = sched.next_prefill_batch(st.now)
                if batch is not None:
                    batch_tokens = sum(r.prompt_len + r.max_new_tokens
                                       for r in batch.requests)
                    if self._live_tokens(pool) + batch_tokens > st.kv_budget:
                        st.oom += 1
                        self._handle_oom(batch, st)
                        st.oom_cooldown_until = st.now + self.restart_penalty
                        batch = None

            if static:
                if batch is not None:
                    self._run_batch_to_completion(batch, st)
                else:
                    cands = [c for c in
                             [arrivals[st.ai].arrival
                              if st.ai < len(arrivals) else None]
                             if c is not None and c > st.now]
                    if sched.queued():
                        cands.append(st.now + self.tick)
                    st.now = min(cands) if cands else st.now + self.tick
                continue

            if batch is None and not pool:
                cands = [c for c in
                         [arrivals[st.ai].arrival if st.ai < len(arrivals)
                          else None]
                         if c is not None and c > st.now]
                st.now = min(cands) if cands else st.now + self.tick
                continue

            dt = 0.0
            if batch is not None:
                dt += cost.prefill_seconds(batch.size, batch.pad_to)
            if pool:
                dt += cost.decode_iter_seconds(self._live_tokens(pool),
                                               len(pool))
            end = st.now + dt
            if batch is not None:
                for r in batch.requests:
                    r.prefill_start = st.now
                    r.first_token = end          # interference: full iter
                    r.generated = 1
                self._account_prefill(
                    batch, cost.prefill_seconds(batch.size, batch.pad_to), st)
            if pool:
                ddt = cost.decode_iter_seconds(self._live_tokens(pool),
                                               len(pool))
                st.busy_d += ddt
                st.t_dec += ddt * len(pool)
                self._finish_iteration(pool, st, end)
            if batch is not None:
                for r in batch.requests:
                    if r.generated >= r.max_new_tokens:
                        r.finished = end
                        st.done += 1
                    else:
                        pool.append(r)
                        sched.admit_decode(r)
            st.now = end

    def _run_batch_to_completion(self, batch, st):
        """Static/batch-granularity execution with padded decode reads."""
        cost, sched = self.cost, self.sched
        n = batch.size
        pad_prompt = batch.pad_to
        dt = cost.prefill_seconds(n, pad_prompt)
        self._account_prefill(batch, dt, st)
        for r in batch.requests:
            r.prefill_start = st.now
            r.first_token = st.now + dt
            r.generated = 1
            sched.admit_decode(r)
        t = st.now + dt
        iters = max(r.max_new_tokens for r in batch.requests) - 1
        for i in range(1, iters + 1):
            context = n * (pad_prompt + i)       # PADDED batch KV read
            ddt = cost.decode_iter_seconds(context, n)
            t += ddt
            st.busy_d += ddt
            st.t_dec += ddt * n
            st.useful += 2.0 * cost.p_active * sum(
                1 for r in batch.requests if r.generated < r.max_new_tokens)
            st.padded += 2.0 * cost.p_active * n
            for r in batch.requests:
                if r.generated < r.max_new_tokens:
                    r.generated += 1
                    if r.generated >= r.max_new_tokens:
                        r.finished = t
        for r in batch.requests:
            if r.finished < 0:
                r.finished = t
            st.done += 1
            sched.release_decode(r)
        st.now = t


@dataclasses.dataclass
class _SimState:
    kv_budget: float
    now: float = 0.0
    ai: int = 0
    done: int = 0
    busy_p: float = 0.0
    busy_d: float = 0.0
    useful: float = 0.0
    padded: float = 0.0
    oom: int = 0
    t_pre: float = 0.0
    t_dec: float = 0.0
    t_xfer: float = 0.0
    oom_cooldown_until: float = 0.0
