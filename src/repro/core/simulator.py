"""Analytic cost-model backend: paper-scale end-to-end serving runs.

The container is CPU-only, so the paper's 4×A100 experiments (Fig. 5/6)
are reproduced on an analytic cost model; the same scheduler objects also
drive the *real* JAX engine (core/engine.py) at tiny-model scale, which
is how the cost model's scheduling behaviour is validated.

All orchestration lives in core/serving_loop.py — this module only
prices the substrate: :class:`CostModelBackend` implements the
``ExecutionBackend`` protocol on a :class:`VirtualClock`, and
:class:`Simulator` is a thin facade wiring (scheduler, cost model,
execution mode) into a :class:`ServingLoop`.

Cost model:
  prefill (compute-bound):  t = FLOPs(padded tokens) / (chips·peak·MFU)
  decode  (memory-bound) :  t = max(weight+KV bytes / (chips·BW·eff),
                                     FLOPs / (chips·peak·MFU))
  KV transfer prefill->decode over NVLink (A100) / ICI (TPU).

OOM semantics: schedulers admitting more live KV tokens than the device
budget trigger an OOM event — the offending batch is evicted and
re-queued after a restart penalty (models vLLM preemption/recompute).
BucketServe's Eq. (5)/(6) memory safety avoids these by construction.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import math

import numpy as np

from repro.models.config import ModelConfig
from . import paging
from .batcher import FormedBatch
from .faults import FaultInjector
from .prefix_cache import PrefixCache
from .request import Request
from .retention import KvRetention, maintain_backend
from .serving_loop import (LoopConfig, PrefillJob, ServeResult, ServingLoop,
                           VirtualClock, batch_prefix_skip, plan_chunks)

# Back-compat alias: benchmark/analysis code predating the unified loop
# imports the result type under its simulator-era name.
SimResult = ServeResult


# ------------------------------------------------------------- hardware ---
@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float            # per chip, bf16
    hbm_bw: float                # per chip
    link_bw: float               # inter-chip (KV transfer)
    hbm_bytes: int               # per chip
    prefill_chips: int = 2
    decode_chips: int = 2
    mfu: float = 0.55            # achievable fraction of peak in prefill
    bw_eff: float = 0.80         # achievable fraction of HBM bandwidth


A100X4 = HardwareSpec("a100x4", 312e12, 1.555e12, 300e9, 40 * 2 ** 30,
                      prefill_chips=2, decode_chips=2)
V5E_POD = HardwareSpec("v5e", 197e12, 819e9, 50e9, 16 * 2 ** 30,
                       prefill_chips=128, decode_chips=128)


class CostModel:
    def __init__(self, cfg: ModelConfig, hw: HardwareSpec,
                 bytes_per_el: int = 2):
        self.cfg = cfg
        self.hw = hw
        self.b = bytes_per_el
        self.p_active = cfg.active_param_count()
        # honors the int8-KV serving variant (halved cache traffic/budget)
        self.kv_per_tok = max(cfg.cache_bytes_per_token(), 1)
        self.weight_bytes = cfg.param_count() * bytes_per_el

    def _attn_flops(self, s: int) -> float:
        """Quadratic attention FLOPs per sequence of length s (score+value)."""
        win = self.cfg.sliding_window or (
            self.cfg.local_window if self.cfg.arch_type == "hybrid" else 0)
        if self.cfg.attention_free:
            return 2.0 * 2 * self.cfg.n_layers * self.cfg.d_model * s * 64
        eff = min(s, win) if win else s
        n_attn = self.cfg.n_layers
        return 2.0 * 2 * n_attn * self.cfg.n_heads * self.cfg.d_head * s * eff

    def prefill_seconds(self, n: int, pad_to: int) -> float:
        tokens = n * pad_to                      # padded compute (TPU shapes)
        flops = 2.0 * self.p_active * tokens + n * self._attn_flops(pad_to)
        chips = self.hw.prefill_chips
        return flops / (chips * self.hw.peak_flops * self.hw.mfu)

    def prefill_chunk_seconds(self, n: int, start: int, length: int) -> float:
        """One chunked-prefill step: linear FLOPs for the chunk's tokens
        plus the *incremental* quadratic attention cost of extending each
        sequence from ``start`` to ``start+length`` context."""
        flops = 2.0 * self.p_active * n * length + n * (
            self._attn_flops(start + length) - self._attn_flops(start))
        chips = self.hw.prefill_chips
        return flops / (chips * self.hw.peak_flops * self.hw.mfu)

    def decode_iter_seconds(self, context_tokens: int, pool: int) -> float:
        """One iteration over the decode pool (one token each).
        `context_tokens`: KV tokens actually READ this iteration — exact
        live tokens for continuous/paged systems, padded-batch tokens for
        batch-granularity systems (the paper's Fig. 3b waste)."""
        if pool == 0:
            return 0.0
        chips = self.hw.decode_chips
        mem = (self.weight_bytes / chips +
               context_tokens * self.kv_per_tok / chips) / \
            (self.hw.hbm_bw * self.hw.bw_eff)
        comp = 2.0 * self.p_active * pool / (chips * self.hw.peak_flops
                                             * self.hw.mfu)
        return max(mem, comp)

    def transfer_seconds(self, prompt_tokens: int) -> float:
        return prompt_tokens * self.kv_per_tok / self.hw.link_bw

    def kv_budget_tokens(self, chips: int, reserve: float = 0.10,
                         act_reserve: float = 0.05) -> float:
        total = self.hw.hbm_bytes * chips
        remain = total - self.weight_bytes - act_reserve * total
        return max(0.0, (1 - reserve) * remain) / self.kv_per_tok


# -------------------------------------------------------------- backend ---
class CostModelBackend:
    """ExecutionBackend over the analytic cost model (virtual time).

    ``prefill_chunk``/``decode_iter`` price work instead of running it —
    the ServingLoop advances request state itself.  ``chunk_tokens``
    enables chunked prefill in the cost model too (incremental quadratic
    attention per chunk); default is whole-prompt prefill, matching the
    paper's setup.

    ``paged=True`` mirrors the real engine's block accounting
    (core/paging.py): the token KV budget becomes a page budget driven
    through the same BlockAllocator + admit/extend/preempt policies, so
    the two backends make identical paged admission decisions (the
    backend-parity invariant, DESIGN.md §3).

    ``prefix_cache=True`` mirrors the engine's cross-request prefix
    cache too: token ids are materialized with the engine's exact rng
    rule, the same radix index drives lookups/registration through
    ``paging.admit_blocks``, and chunk plans skip the cached prefix —
    so hit counts, admission decisions AND the priced prefill work
    (incremental attention from the resume offset) stay in parity.
    """

    prefill_needs_slots = False
    supports_decode = True
    # armed by the ServingLoop when the scheduler is slack-aware: a
    # CLOCK-FREE key (Request -> seconds) preferring the victim with
    # the most remaining deadline slack (DESIGN.md §8)
    slack_of = None

    def __init__(self, cost: CostModel, *, kv_budget: float,
                 chunk_tokens: Optional[int] = None, paged: bool = False,
                 page_size: int = 128,
                 kv_pool_tokens: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 prefix_cache: bool = False,
                 session_ttl: Optional[float] = None,
                 host_pool_tokens: Optional[int] = None,
                 spill_bw: float = 16e9,
                 spill_dtype: str = ""):
        self.cost = cost
        self.clock = VirtualClock()
        self.paged = paged
        self.chunk_tokens = chunk_tokens
        self.flops_per_token = 2.0 * cost.p_active
        self.session_ttl = session_ttl
        self.spill_dtype = spill_dtype
        self.page_size = page_size
        # host spill tier: SAME geometry + per-page transfer pricing
        # rule as the engine (paging.host_tier_geometry: slots and
        # seconds both denominated in COMPRESSED spill-dtype bytes), so
        # spill decisions and hold times agree between the backends
        self._host_pages, self._slot_bytes = paging.host_tier_geometry(
            cost.cfg, host_pool_tokens, page_size, spill_dtype)
        self._spill_sec = self._slot_bytes / spill_bw
        self.retention: Optional[KvRetention] = None
        prefix_cache = prefix_cache or session_ttl is not None
        if prefix_cache:
            assert paged, "KV retention rides on the paged KV pool"
            assert cost.cfg.prefix_cacheable, \
                f"{cost.cfg.name}: KV retention needs chunk-resumable " \
                "prefill and purely attention-paged state"
            self.retention = self._make_retention()
        else:
            assert not self._host_pages, \
                "the host spill tier rides on the retention layer"
        if paged:
            # block accounting REPLACES the token-budget OOM check
            self._kv_budget = math.inf
            cfg = cost.cfg
            # the ONE window-cap rule both backends share (parity)
            self._cap = cfg.attn_cache_len(cache_len or cfg.max_seq_len)
            # mirror the engine's sizing EXACTLY (byte-denominated
            # through the same paging.device_pool_pages rule, one page
            # of the budget reserved as the dead-slot trash page) so
            # identical kv_pool_tokens yields identical admission
            # decisions.  kv_budget needs no re-denomination: it is
            # ALREADY cache-dtype tokens (kv_budget_tokens divided the
            # HBM bytes by cache_bytes_per_token)
            if kv_pool_tokens is not None:
                n_pages = paging.device_pool_pages(
                    cfg, int(kv_pool_tokens), page_size) - 1
            else:
                n_pages = int(kv_budget) // page_size - 1
            min_pages = -(-self._cap // page_size)
            if kv_pool_tokens is not None and n_pages < min_pages:
                raise ValueError(
                    f"kv_pool_tokens={kv_pool_tokens} too small: the "
                    f"paged pool needs at least "
                    f"{(min_pages + 1) * page_size} tokens (one full "
                    f"request of {min_pages} pages + the trash page)")
            self.alloc = self._make_alloc(max(n_pages, min_pages))
        else:
            self._kv_budget = kv_budget

    def _make_retention(self) -> KvRetention:
        return KvRetention(
            self.page_size,
            session_ttl=self.session_ttl,
            host_pool_pages=self._host_pages,
            spill_seconds_per_page=self._spill_sec,
            spill_page_bytes=self._slot_bytes)

    def _make_alloc(self, n_pages: int) -> paging.BlockAllocator:
        cfg = self.cost.cfg
        return paging.BlockAllocator(
            n_pages, self.page_size, host_pages=self._host_pages,
            page_bytes=self.page_size * max(cfg.cache_bytes_per_token(), 1),
            host_slot_bytes=self._slot_bytes)

    @property
    def prefix_cache(self) -> Optional[PrefixCache]:
        """The retention layer's radix backend (None when disabled) —
        the surface older call sites and tests address."""
        return self.retention.prefix if self.retention is not None else None

    def begin(self, requests: Sequence[Request]) -> None:
        self.clock = VirtualClock()
        if self.paged:
            self.alloc = self._make_alloc(self.alloc.n_pages)
        if self.retention is not None:
            self.retention = self._make_retention()
            # the radix index keys on ACTUAL token ids: materialize them
            # through the one shared rule (Request.materialize_tokens —
            # which leaves later session turns for the loop to compose)
            # so both backends make identical hit/miss decisions
            for r in requests:
                r.materialize_tokens(self.cost.cfg.vocab_size)

    def kv_budget_tokens(self) -> float:
        return self._kv_budget

    def maintain(self, now: float) -> None:
        maintain_backend(self, now)

    def free_slots(self) -> int:          # pragma: no cover - not consulted
        return 1 << 30

    # ------------------------------------------------- paged KV mirror ----
    def _insert_tokens(self, r: Request) -> int:
        return min(r.prompt_len + 1, self._cap)

    def _decode_tokens(self, r: Request) -> int:
        # sliced_tokens were PROMOTED into the prompt by a slice-yield
        # (serving_loop._preempt_for_decode): they are already counted
        # inside prompt_len, so only the post-promotion generation adds
        # physical context on top
        return min(r.prompt_len + r.generated - r.sliced_tokens, self._cap)

    def _prompt_tokens(self, r: Request):
        return r.tokens[:r.prompt_len]

    def admit_blocks(self, requests: Sequence[Request]) -> int:
        if not self.paged:
            return len(requests)
        return paging.admit_blocks(self.alloc, requests, self._insert_tokens,
                                   cache=self.retention,
                                   tokens_of=self._prompt_tokens)

    def decode_preempt(self, pool: Sequence[Request]) -> List[Request]:
        if not self.paged:
            return []
        return paging.extend_for_decode(self.alloc, pool,
                                        self._decode_tokens,
                                        cache=self.retention,
                                        slack_of=self.slack_of)

    def on_slice_yield(self, req: Request, keep: int) -> None:
        # the synthetic id stream (generated_tokens) is prefix-stable:
        # truncating req.generated back to ``keep`` IS the truncation
        pass

    def on_preempt_reset(self, req: Request) -> None:
        pass

    # ------------------------------------------- fault/drain teardown -----
    def abort_prefill(self, req: Request) -> None:
        """A mid-prefill request leaves before its KV enters service
        (prefill-job abandon, checkpointed drain): free its pages
        OUTRIGHT — never through ``release``, which would register a
        garbage partial transcript with the retention layer."""
        if self.paged:
            self.alloc.release(req.rid)     # idempotent: no-table is a no-op

    def evict_request(self, req: Request) -> None:
        """Tear down a pooled request's KV without retention
        registration — the decode-pool kill / drain analogue of a
        preemption victim's teardown (which ``extend_for_decode`` does
        inside the backend)."""
        if self.paged:
            self.alloc.release(req.rid)

    def chunk_plan(self, batch: FormedBatch) -> List[Tuple[int, int]]:
        # same gate as the real engine (cfg.chunkable_prefill) so the two
        # backends schedule identically for ring-cache/VLM configs
        c = self.chunk_tokens if self.cost.cfg.chunkable_prefill else None
        skip = batch_prefix_skip(batch) if self.prefix_cache is not None \
            else 0
        return plan_chunks(batch.pad_to, c, skip=skip)

    def prefill_chunk(self, job: PrefillJob, idx: int) -> float:
        start, length = job.chunks[idx]
        if idx == len(job.chunks) - 1 and self.prefix_cache is not None:
            # mirror the engine's registration point (end of prefill,
            # decode-continuing rows only) so hit counts stay in parity
            for r in job.batch.requests:
                if r.max_new_tokens > 1 and self.cost.cfg.has_decode:
                    self.prefix_cache.register(
                        self.alloc, self._prompt_tokens(r),
                        self.alloc.table(r.rid))
        if len(job.chunks) == 1 and start == 0:
            return self.cost.prefill_seconds(job.batch.size, length)
        # a span starting past 0 (later chunk OR resumed-after-prefix
        # prefill) pays the incremental quadratic attention cost of
        # extending each sequence's context from ``start``
        return self.cost.prefill_chunk_seconds(job.batch.size, start, length)

    def transfer_seconds(self, batch: FormedBatch) -> float:
        return self.cost.transfer_seconds(batch.total_tokens)

    def decode_iter(self, pool: Sequence[Request],
                    context_tokens: int) -> float:
        return self.cost.decode_iter_seconds(context_tokens, len(pool))

    def release(self, req: Request) -> None:
        if not self.paged:
            return
        # retention applies only to decode-continuing requests — the
        # engine never scatters a first-token-only row's KV into the
        # pool, so retaining it here would break hit-count parity
        if self.retention is not None and req.max_new_tokens > 1 \
                and self.cost.cfg.has_decode:
            self.retention.on_release(self.alloc, req,
                                      self._transcript_tokens(req),
                                      self.clock.now())
        else:
            self.alloc.release(req.rid)

    def _transcript_tokens(self, req: Request) -> Optional[np.ndarray]:
        """Mirror of the engine's rule: the pool holds KV for the
        prompt plus generated[:-1]."""
        if req.tokens is None:
            return None
        # generated[:sliced_tokens] already live inside tokens[:prompt_len]
        # (slice-yield promotion) — exclude them or they'd count twice
        gen = self.generated_tokens(req)[req.sliced_tokens:
                                         max(req.generated - 1, 0)]
        return np.concatenate(
            [np.asarray(req.tokens[:req.prompt_len], np.int32), gen])

    def generated_tokens(self, req: Request) -> np.ndarray:
        """Deterministic SYNTHETIC generated ids — the cost model runs
        no model, but session transcripts must still be concrete token
        paths.  Seeded per rid (disjoint from the prompt
        materialization rule), so regenerating the same request yields
        the same transcript: hit counts stay reproducible and in
        parity with the engine's (whose ids differ but whose
        transcript STRUCTURE is identical)."""
        rng = np.random.default_rng([req.rid, 0xD3C0DE])
        return rng.integers(0, self.cost.cfg.vocab_size,
                            req.generated).astype(np.int32)


# ------------------------------------------------------------ simulator ---
class Simulator:
    """Facade: (scheduler, cost model, mode) -> configured ServingLoop.

    Execution modes (loop topology, see serving_loop.ServingLoop):

    * ``disagg``  — separate prefill/decode executors + KV transfer
      (BucketServe, DistServe).
    * ``coupled`` — ONE executor; each iteration fuses the new prefill
      batch (if any) with one decode step over the live pool — Orca-style
      iteration-level scheduling.  Prefill work inflates every concurrent
      request's TPOT: the phase interference DistServe/BucketServe remove.
    * ``static``  — one executor; a batch runs prefill + ALL decode steps
      to completion before the next batch starts (naive static batching).
    """

    def __init__(self, scheduler, cost: CostModel, *, mode: str = "disagg",
                 decode_slot_cap: int = 256, restart_penalty: float = 0.5,
                 tick: float = 0.005, chunk_tokens: Optional[int] = None,
                 paged: bool = False, page_size: int = 128,
                 kv_pool_tokens: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 prefix_cache: bool = False,
                 session_ttl: Optional[float] = None,
                 host_pool_tokens: Optional[int] = None,
                 spill_bw: float = 16e9,
                 spill_dtype: str = "",
                 slice_tokens: Optional[int] = None,
                 recorder=None, tracer=None,
                 fault_plan=None, recovery=None,
                 restore_timeout: float = 30.0):
        assert mode in ("disagg", "coupled", "static")
        prefix_cache = prefix_cache or session_ttl is not None
        # static mode runs a batch to completion without per-iteration
        # decode_preempt extends, so paged accounting would silently
        # understate the live footprint — refuse the combination
        assert not (paged and mode == "static"), \
            "paged KV accounting needs iteration-level decode " \
            "(disagg/coupled)"
        # fused-iteration modes bypass backend.chunk_plan (prefill is one
        # hardcoded whole-prompt span), so a prefix cache would count
        # hits and discount charges WITHOUT ever skipping prefill —
        # refuse rather than silently misreport
        assert not (prefix_cache and mode != "disagg"), \
            "prefix cache needs chunk-planned prefill (disagg mode)"
        self.sched = scheduler
        self.cost = cost
        self.mode = mode
        chips = cost.hw.decode_chips if mode == "disagg" \
            else cost.hw.decode_chips + cost.hw.prefill_chips
        self.backend = CostModelBackend(
            cost, kv_budget=cost.kv_budget_tokens(chips),
            chunk_tokens=chunk_tokens, paged=paged, page_size=page_size,
            kv_pool_tokens=kv_pool_tokens, cache_len=cache_len,
            prefix_cache=prefix_cache, session_ttl=session_ttl,
            host_pool_tokens=host_pool_tokens, spill_bw=spill_bw,
            spill_dtype=spill_dtype)
        # fault-injection plane (core/faults.py): a FaultPlan is turned
        # into a per-run injector HERE so the facade owns the arming —
        # passing a plan with no armed site is the same as passing None
        faults = None
        if fault_plan is not None and fault_plan.any_armed:
            faults = FaultInjector(fault_plan)
        self.faults = faults
        self.loop = ServingLoop(scheduler, self.backend, LoopConfig(
            mode=mode, decode_slot_cap=decode_slot_cap,
            restart_penalty=restart_penalty, tick=tick,
            slice_tokens=slice_tokens, restore_timeout=restore_timeout),
            recorder=recorder, tracer=tracer,
            faults=faults, recovery=recovery)

    def run(self, requests: List[Request], time_limit: float = 3600.0,
            drain_at: Optional[float] = None,
            resume_clock: Optional[float] = None) -> SimResult:
        return self.loop.run(requests, time_limit=time_limit,
                             drain_at=drain_at, resume_clock=resume_clock)
