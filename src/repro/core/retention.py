"""Unified KV retention: one end-of-life policy for every cached page.

Before this layer, "a request finished" meant "free its pages" — with
one ad-hoc exception (the prefix cache pinned FULL prompt pages at
prefill time) and no way to keep a *conversation's* cache alive between
turns.  BucketServe's motivating traffic is exactly the workload where
that hurts: agentic/chat sessions re-send the whole transcript every
turn, so turn N+1 re-prefills tokens whose KV was in the pool seconds
ago (Apt-Serve arXiv 2504.07494, UELLM arXiv 2409.14961).

:class:`KvRetention` makes "free on release" one case of a general
retention policy (DESIGN.md §3 "Session retention"):

* the PR 3 radix index (:class:`~repro.core.prefix_cache.PrefixCache`)
  becomes the SHARED-PREFIX BACKEND.  At release, the finished
  request's full transcript — prompt AND generated tokens — is
  registered: page content is a pure function of the token path (RoPE
  uses absolute positions), so generated tokens simply EXTEND the
  radix path past the prompt.  Any later request whose prompt walks
  the same token path (most importantly the session's own next turn)
  reuses those pages by reference;
* a SESSION TABLE holds the one page the radix cannot: the partial
  tail (``transcript_len % page`` tokens).  It stays pinned PRIVATELY
  under the session key with a TTL; the next turn of the same session
  — after verifying its prompt continues the exact transcript token
  path — takes the pin over (the tail becomes its private page at the
  right virtual index) and prefill resumes past the whole restored
  transcript, not just its page-aligned prefix;
* eviction pressure walks ONE ordered policy: expired session tails →
  LRU cold radix prefixes → live session tails (soonest-expiring
  first) → and only then does the caller fall back to refcount-aware
  request preemption (``paging.extend_for_decode``).  A pinned session
  is therefore always unpinned before any live request loses work.

The layer owns the whole pin lifecycle (TTL tick, pressure unpin,
release-time registration) — call sites in the loop/backends only
forward their clock.  Both execution backends drive one instance
through the shared ``paging.admit_blocks`` policy, so session hit
counts cannot drift between the engine and the cost model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .prefix_cache import PrefixCache


@dataclasses.dataclass
class RetentionStats:
    """Session-side accounting (the radix side lives in PrefixStats)."""

    sessions_retained: int = 0   # release-time session entries created
    session_lookups: int = 0     # admitted requests carrying a session id
    session_hits: int = 0        # ... resumed from a live session entry
    session_hit_tokens: int = 0  # transcript tokens restored via sessions
    tail_reuses: int = 0         # pinned partial tail pages handed back
    sessions_expired: int = 0    # entries dropped by the TTL tick
    sessions_evicted: int = 0    # entries unpinned by memory pressure


@dataclasses.dataclass
class _Session:
    """Retained transcript of one conversation's last finished turn."""

    sid: int
    turn: int
    path: np.ndarray             # transcript token ids (len = T)
    full_tokens: int             # page-aligned prefix registered on the radix
    tail_page: Optional[int]     # pinned private partial tail (None if T%page==0)
    expires_at: float
    claimed_by: Optional[int] = None   # rid mid-admission (commit/abort pending)


class KvRetention:
    """Retention policy over a BlockAllocator: radix prefix backend +
    TTL'd session table.  Duck-type-compatible with the ``cache``
    argument of ``paging.admit_blocks`` / ``paging.extend_for_decode``
    (lookup / evict / evict_one / note_admit / abort), which is how
    both backends route their admit and eviction paths through it."""

    def __init__(self, page_size: int,
                 session_ttl: Optional[float] = None):
        assert page_size > 0
        self.page_size = page_size
        self.session_ttl = session_ttl
        self.prefix = PrefixCache(page_size)
        self.sessions: Dict[int, _Session] = {}
        self.stats = RetentionStats()
        self._now = 0.0
        # earliest expires_at across live entries (inf when none): the
        # per-iteration TTL tick early-returns on it, so steady-state
        # serving pays O(1) per tick, not O(live sessions)
        self._next_expiry = math.inf

    # ------------------------------------------------------------ queries --
    @property
    def sessions_enabled(self) -> bool:
        return self.session_ttl is not None

    def __len__(self) -> int:
        return len(self.prefix)

    def live_sessions(self) -> int:
        return len(self.sessions)

    # ------------------------------------------------------- pin lifecycle --
    def tick(self, alloc, now: float) -> int:
        """TTL maintenance, called by the backends each loop iteration:
        drop every expired, unclaimed session entry.  Returns pages
        actually freed (a tail with no other referent).  O(1) until the
        earliest entry actually expires (cached watermark)."""
        self._now = max(self._now, now)
        if self._now < self._next_expiry:
            return 0
        freed = 0
        for sid in [s for s, e in self.sessions.items()
                    if e.claimed_by is None and e.expires_at <= self._now]:
            freed += self._drop_session(alloc, sid, expired=True)
        # claimed entries (transient, mid-admission) stay in the min so
        # a later tick retries them after commit/abort resolves
        self._next_expiry = min(
            (e.expires_at for e in self.sessions.values()),
            default=math.inf)
        return freed

    def _drop_session(self, alloc, sid: int, *, expired: bool) -> int:
        e = self.sessions.pop(sid)
        freed = 0
        if e.tail_page is not None:
            freed = int(alloc.unpin(e.tail_page))
        if expired:
            self.stats.sessions_expired += 1
        else:
            self.stats.sessions_evicted += 1
        return freed

    def on_release(self, alloc, req, path_tokens, now: float) -> int:
        """End-of-life for a finished request's pages — the ONE place
        release policy lives.  ``path_tokens`` is the transcript whose
        KV the pool physically holds: prompt + generated[:-1] (the last
        generated token's KV is never written).  Full pages go onto the
        radix path; the partial tail is pinned under the session key
        with a TTL; only then are the table's references dropped, so
        retained pages survive.  Returns pages freed (like
        ``BlockAllocator.release``); idempotent per rid."""
        self._now = max(self._now, now)
        if not alloc.holds(req.rid):
            return 0
        if not self.sessions_enabled or path_tokens is None:
            return alloc.release(req.rid)
        path = np.ascontiguousarray(path_tokens, dtype=np.int32)
        table = alloc.table(req.rid)
        T = min(len(path), len(table) * self.page_size)
        full = T // self.page_size
        if full:
            self.prefix.register(alloc, path[:full * self.page_size], table)
        sid = req.session_id
        if sid is not None:
            tail_page = table[full] if T % self.page_size else None
            if tail_page is not None:
                alloc.pin(tail_page)
            old = self.sessions.pop(sid, None)
            if old is not None and old.tail_page is not None:
                alloc.unpin(old.tail_page)
            expires = self._now + self.session_ttl
            self.sessions[sid] = _Session(
                sid=sid, turn=req.turn, path=path[:T],
                full_tokens=full * self.page_size, tail_page=tail_page,
                expires_at=expires)
            self._next_expiry = min(self._next_expiry, expires)
            self.stats.sessions_retained += 1
        return alloc.release(req.rid)

    # ------------------------------------------------- admission (lookup) --
    def lookup(self, tokens, req=None) -> Tuple[List[int], int]:
        """Longest retained run for ``tokens``: the radix walk first;
        then, if the request belongs to a live unexpired session whose
        transcript the prompt EXACTLY continues (token-path verified —
        the tail's KV is only valid for that path) and the radix still
        covers the whole page-aligned transcript (no gap), the pinned
        tail extends the hit to the full transcript length.  The entry
        is CLAIMED, not consumed — ``note_admit`` commits the claim
        (pin hand-over) once the allocator accepted the request;
        ``abort`` rolls it back if admission failed."""
        tokens = np.asarray(tokens)
        pages, hit = self.prefix.lookup(tokens)
        sid = getattr(req, "session_id", None)
        if sid is None or not self.sessions_enabled:
            return pages, hit
        e = self.sessions.get(sid)
        if (e is None or e.claimed_by is not None
                or e.expires_at <= self._now):
            return pages, hit
        T = len(e.path)
        if (hit == e.full_tokens and len(tokens) > T
                and np.array_equal(tokens[:T], e.path)):
            e.claimed_by = req.rid
            req.session_hit_tokens = T
            if e.tail_page is not None:
                return pages + [e.tail_page], T
        return pages, hit

    def note_admit(self, alloc, req, hit_tokens: int) -> None:
        """A request was ADMITTED (pages allocated): fold its hit into
        the radix stats and commit any pending session claim — the
        table now references the tail, so the session pin transfers
        (unpin) and the entry is consumed."""
        self.prefix.note_admit(alloc, req, hit_tokens)
        sid = getattr(req, "session_id", None)
        if sid is None or not self.sessions_enabled:
            return
        self.stats.session_lookups += 1
        e = self.sessions.get(sid)
        if e is None or e.claimed_by != req.rid:
            return
        del self.sessions[sid]
        if e.tail_page is not None:
            alloc.unpin(e.tail_page)
            self.stats.tail_reuses += 1
        self.stats.session_hits += 1
        self.stats.session_hit_tokens += len(e.path)

    def abort(self, req) -> None:
        """Admission failed after ``lookup``: release the claim so the
        session stays resumable (nothing was mutated yet)."""
        sid = getattr(req, "session_id", None)
        if sid is None:
            return
        e = self.sessions.get(sid)
        if e is not None and e.claimed_by == req.rid:
            e.claimed_by = None
        req.session_hit_tokens = 0

    # ---------------------------------------------------------- eviction --
    def evict(self, alloc, need: int, protect=()) -> int:
        """Free up to ``need`` pages along the ONE retention order:
        (1) expired session tails (dead weight), (2) LRU cold radix
        prefixes (nobody loses work), (3) live session tails, soonest-
        expiring first (a session loses its resume, no live request
        loses work).  The caller (``paging.extend_for_decode``) falls
        back to request preemption only when all three come up empty —
        sessions are therefore always unpinned before any live request
        is preempted."""
        protect = set(protect)
        freed = self._evict_sessions(alloc, need, protect,
                                     expired_only=True)
        if freed < need:
            freed += self.prefix.evict(alloc, need - freed, protect)
        if freed < need:
            freed += self._evict_sessions(alloc, need - freed, protect,
                                          expired_only=False)
        return freed

    def evict_one(self, alloc, protect=()) -> bool:
        return self.evict(alloc, 1, protect) > 0

    def _evict_sessions(self, alloc, need: int, protect,
                        expired_only: bool) -> int:
        freed = 0
        if need <= 0 or not self.sessions:
            return 0
        for sid, e in sorted(self.sessions.items(),
                             key=lambda kv: kv[1].expires_at):
            if freed >= need:
                break
            if (e.claimed_by is not None or e.tail_page is None
                    or e.tail_page in protect
                    or alloc.refs(e.tail_page) != 1):
                continue
            if expired_only and e.expires_at > self._now:
                continue
            expired = e.expires_at <= self._now
            freed += self._drop_session(alloc, sid, expired=expired)
        return freed

    def clear(self, alloc) -> int:
        """Unpin everything — every session tail, then the whole radix.
        Returns pages freed."""
        freed = 0
        for sid in list(self.sessions):
            freed += self._drop_session(alloc, sid, expired=False)
        return freed + self.prefix.clear(alloc)
