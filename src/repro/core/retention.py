"""Unified KV retention: one end-of-life policy for every cached page.

Before this layer, "a request finished" meant "free its pages" — with
one ad-hoc exception (the prefix cache pinned FULL prompt pages at
prefill time) and no way to keep a *conversation's* cache alive between
turns.  BucketServe's motivating traffic is exactly the workload where
that hurts: agentic/chat sessions re-send the whole transcript every
turn, so turn N+1 re-prefills tokens whose KV was in the pool seconds
ago (Apt-Serve arXiv 2504.07494, UELLM arXiv 2409.14961).

:class:`KvRetention` makes "free on release" one case of a general
retention policy (DESIGN.md §3 "Session retention"):

* the PR 3 radix index (:class:`~repro.core.prefix_cache.PrefixCache`)
  becomes the SHARED-PREFIX BACKEND.  At release, the finished
  request's full transcript — prompt AND generated tokens — is
  registered: page content is a pure function of the token path (RoPE
  uses absolute positions), so generated tokens simply EXTEND the
  radix path past the prompt.  Any later request whose prompt walks
  the same token path (most importantly the session's own next turn)
  reuses those pages by reference;
* a SESSION TABLE holds the one page the radix cannot: the partial
  tail (``transcript_len % page`` tokens).  It stays pinned PRIVATELY
  under the session key with a TTL; the next turn of the same session
  — after verifying its prompt continues the exact transcript token
  path — takes the pin over (the tail becomes its private page at the
  right virtual index) and prefill resumes past the whole restored
  transcript, not just its page-aligned prefix;
* eviction pressure walks ONE ordered policy: expired session tails →
  LRU cold radix prefixes → live session tails (soonest-expiring
  first) → and only then does the caller fall back to refcount-aware
  request preemption (``paging.extend_for_decode``).  A pinned session
  is therefore always unpinned before any live request loses work.

HOST SPILL TIER (PR 5, ``host_pool_pages > 0``): every rung above gains
a non-destructive option — before a retained page is DROPPED (and its
next use pays a full re-prefill), it is SPILLED: copied device→host
(``BlockAllocator.spill``) so only its HBM is reclaimed.  A later
lookup whose hit continues into spilled pages triggers a host→device
RESTORE instead of a re-prefill: device pages are reserved, the copy is
dispatched, and the request is HELD (``Request.spill_wait``) until the
transfer lands — converting the dominant multi-turn perf cliff
(pressure/TTL eviction → cold re-prefill) into an overlappable
PCIe-bandwidth cost (Apt-Serve's hybrid cache, arXiv 2504.07494).
Destruction happens only when the host budget is ALSO exhausted, and
then against the host pool's own LRU.  With spill enabled, TTL expiry
DEMOTES a session tail to host (the entry stays resumable — host RAM
is cheap) rather than destroying it.  The actual byte movement is the
backend's job (``copier``: the engine gathers/scatters real KV; the
cost model prices the transfer seconds only), but every DECISION —
what spills, what restores, when a transfer completes relative to the
serving clock — lives here, shared by both backends, so spill/restore
counts hold under backend parity.

The layer owns the whole pin lifecycle (TTL tick, pressure unpin,
release-time registration, spill/restore transitions) — call sites in
the loop/backends only forward their clock.  Both execution backends
drive one instance through the shared ``paging.admit_blocks`` policy,
so session hit counts cannot drift between the engine and the cost
model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .prefix_cache import PrefixCache
from .telemetry import NULL_TRACER


@dataclasses.dataclass
class RetentionStats:
    """Session-side accounting (the radix side lives in PrefixStats)."""

    sessions_retained: int = 0   # release-time session entries created
    session_lookups: int = 0     # admitted requests carrying a session id
    session_hits: int = 0        # ... resumed from a live session entry
    session_hit_tokens: int = 0  # transcript tokens restored via sessions
    tail_reuses: int = 0         # pinned partial tail pages handed back
    sessions_expired: int = 0    # entries DROPPED by the TTL tick
    sessions_evicted: int = 0    # entries unpinned by memory pressure
    # ---- host spill tier (PR 5) ----
    pages_spilled: int = 0       # device->host page copies initiated
    pages_restored: int = 0      # host->device page copies completed
    restored_tokens: int = 0     # KV tokens brought back instead of re-prefilled
    spill_drops: int = 0         # spilled entries destroyed (host LRU/teardown)
    restore_holds: int = 0       # restore runs that held a request on TTFT
    spill_seconds: float = 0.0   # priced device->host transfer time
    restore_seconds: float = 0.0  # priced host->device transfer time
    # ---- quantized spill tier (byte denomination) ----
    bytes_spilled: int = 0       # COMPRESSED bytes moved device->host
    bytes_restored: int = 0      # COMPRESSED bytes moved host->device
    # ---- fault/recovery plane (core/faults.py, core/recovery.py) ----
    restore_stalls: int = 0      # injected channel stalls absorbed
    restore_retries: int = 0     # channel hard-faults retried (backoff)
    restore_failures: int = 0    # restore runs abandoned after retries
    restore_sheds: int = 0       # runs shed by the deadline-slack rule
    restore_timeouts: int = 0    # held requests unparked by the timeout
    corruptions: int = 0         # host-slot checksum mismatches caught


@dataclasses.dataclass
class _Session:
    """Retained transcript of one conversation's last finished turn.

    Tail spill states mirror the radix node's: LIVE (``tail_page`` set,
    ``tail_hslot`` None), SPILLED (``tail_hslot`` set, ``tail_page``
    None — demoted to host, ``expires_at`` becomes inf because the host
    LRU owns its lifetime now), RESTORING (both set — the reserved
    device page's copy lands at ``tail_ready``)."""

    sid: int
    turn: int
    path: np.ndarray             # transcript token ids (len = T)
    full_tokens: int             # page-aligned prefix registered on the radix
    tail_page: Optional[int]     # pinned private partial tail (None if T%page==0)
    expires_at: float
    claimed_by: Optional[int] = None   # rid mid-admission (commit/abort pending)
    tail_hslot: Optional[int] = None   # host slot (spilled/restoring tail)
    tail_ready: float = -1.0           # restore completion time
    stamp: int = 0                     # LRU rank shared with radix nodes
    # class TTFT budget of the turn that retained this transcript: the
    # slack-aware eviction rung's CLOCK-FREE sacrifice rank (a
    # loose-budget batch session tolerates a cold resume far better
    # than a 2 s-TTFT chat session — DESIGN.md §8)
    slo_ttft: float = 2.0


class KvRetention:
    """Retention policy over a BlockAllocator: radix prefix backend +
    TTL'd session table.  Duck-type-compatible with the ``cache``
    argument of ``paging.admit_blocks`` / ``paging.extend_for_decode``
    (lookup / evict / evict_one / note_admit / abort), which is how
    both backends route their admit and eviction paths through it."""

    def __init__(self, page_size: int,
                 session_ttl: Optional[float] = None,
                 host_pool_pages: int = 0,
                 spill_seconds_per_page: float = 0.0,
                 spill_page_bytes: int = 0):
        assert page_size > 0
        assert host_pool_pages >= 0
        self.page_size = page_size
        self.session_ttl = session_ttl
        self.host_pool_pages = host_pool_pages
        self.spill_seconds_per_page = spill_seconds_per_page
        # bytes one page occupies in the HOST tier (at the spill dtype,
        # scales included) — what a spill/restore transfer MOVES; 0 in
        # legacy call sites that never read the byte stats
        self.spill_page_bytes = spill_page_bytes
        # slack-aware sacrifice ordering (DESIGN.md §8): armed by the
        # ServingLoop when the scheduler is deadline-slack aware — the
        # live-session eviction rung then sacrifices the session whose
        # class budget tolerates a cold resume best (largest slo_ttft)
        # instead of the soonest-expiring one
        self.slack_aware = False
        # fault-injection / recovery seams (core/faults.py §9): armed by
        # the ServingLoop AFTER backend.begin (backends rebuild retention
        # there).  ``faults`` draws restore-channel stall / hard-error /
        # host-corruption decisions; ``recovery`` bounds the retries and
        # carries the deadline-slack shed rule.  Both None in a
        # fault-free run — every new branch below is skipped.
        self.faults = None
        self.recovery = None
        self.prefix = PrefixCache(page_size)
        self.prefix.on_host_drop = self._on_host_drop
        # event-timeline seam (core/telemetry.py): the ServingLoop
        # overwrites this after backend.begin when tracing is on
        self.tracer = NULL_TRACER
        self.sessions: Dict[int, _Session] = {}
        self.stats = RetentionStats()
        self._now = 0.0
        # backend-supplied data mover (spill/restore/drop/poll); None
        # for the cost model, which only prices the transfers
        self.copier = None
        # in-flight restores: (hslot, "node"/"tail", node-or-sid);
        # completion times live on the node/entry, the watermark keeps
        # the per-iteration poll O(1) until something is actually due
        self._restores: List[Tuple[int, str, object]] = []
        self._next_restore = math.inf
        self._restore_free = 0.0     # when the host<->device channel frees
        # anti-thrash reservations: rid -> (expiry, hit-path pages).  A
        # held request's whole hit path (live prefix + restoring run)
        # is protected from eviction until that request consumes it at
        # admission (note_admit) — otherwise concurrent restores under
        # a tight pool spill each other's just-restored pages and the
        # system livelocks copying instead of serving.  The expiry is a
        # leak backstop for requests that never come back.
        self._reserved: Dict[int, Tuple[float, frozenset]] = {}
        # per-slot integrity checksums stamped at SPILL time and
        # verified when the restore channel next READS the slot — a
        # corrupted host copy is destroyed (cold re-prefill) instead of
        # ever being copied back and served
        self._checksums: Dict[int, int] = {}
        # earliest expires_at across live entries (inf when none): the
        # per-iteration TTL tick early-returns on it, so steady-state
        # serving pays O(1) per tick, not O(live sessions)
        self._next_expiry = math.inf

    def _on_host_drop(self, hslot: int, revived: bool) -> None:
        """PrefixCache destroyed/revived a spilled node's host copy."""
        self._checksums.pop(hslot, None)
        if self.copier is not None:
            self.copier.drop(hslot)
        if not revived:
            self.stats.spill_drops += 1

    def _drop_host_slot(self, alloc, hslot: int) -> None:
        """Destroy a session tail's host copy — the ONE teardown path
        (slot back to the allocator, copier staging discarded, drop
        counted) for every session-side site."""
        self._checksums.pop(hslot, None)
        alloc.drop_spilled(hslot)
        if self.copier is not None:
            self.copier.drop(hslot)
        self.stats.spill_drops += 1

    # -------------------------------------------- host-slot integrity --
    @staticmethod
    def _expected_checksum(hslot: int) -> int:
        """Model-level per-slot checksum: a pure function of the slot,
        identical in both backends (the engine's real bytes are
        bit-exact across spill/restore by the PR 5 copier tests, so the
        model checksum tracks the DECISION — was the content rotted —
        which is the parity surface)."""
        return (hslot * 0x9E3779B1 + 0x7F4A7C15) & 0xFFFFFFFF

    def _stamp_checksum(self, hslot: int) -> None:
        """At spill time: record the slot checksum.  An injected
        ``host_corrupt`` fault rots the stored value — bit-rot at rest,
        caught only when the slot is next read."""
        chk = self._expected_checksum(hslot)
        if self.faults is not None and self.faults.fire("host_corrupt"):
            chk ^= 1
        self._checksums[hslot] = chk

    def _checksum_ok(self, hslot: int) -> bool:
        return self._checksums.get(
            hslot, self._expected_checksum(hslot)) \
            == self._expected_checksum(hslot)

    # ------------------------------------------------------------ queries --
    @property
    def sessions_enabled(self) -> bool:
        return self.session_ttl is not None

    @property
    def spill_enabled(self) -> bool:
        return self.host_pool_pages > 0

    def __len__(self) -> int:
        return len(self.prefix)

    def live_sessions(self) -> int:
        return len(self.sessions)

    def restores_in_flight(self) -> int:
        return len(self._restores)

    def restore_pages_in_flight(self) -> int:
        """Device pages currently reserved by in-flight restores —
        real KV occupancy Eq. (6) would otherwise miss (the pages left
        the free list at ``restore_begin`` but belong to no table)."""
        return len(self._restores)

    def restore_backlog_bytes(self) -> int:
        """Compressed bytes still queued on the modeled PCIe channel —
        the restore-aware admission term's input (DESIGN.md §4)."""
        return len(self._restores) * self.spill_page_bytes

    # ------------------------------------------------------- pin lifecycle --
    def tick(self, alloc, now: float) -> int:
        """Housekeeping, called by BOTH backends each loop iteration
        through the one shared :func:`maintain_backend` path: (1) flip
        in-flight restores whose transfer landed to LIVE, (2) TTL
        maintenance — with spill enabled an expired tail is DEMOTED to
        host (the session stays resumable for a bandwidth cost);
        without, or when demotion is impossible, the entry drops as
        before.  Returns device pages actually freed.  O(1) until a
        watermark (earliest expiry / earliest restore) actually
        passes."""
        self._now = max(self._now, now)
        if self._now >= self._next_restore:
            self._complete_restores(alloc)
        if self.copier is not None:
            self.copier.poll()
        if self._now < self._next_expiry:
            return 0
        freed = 0
        for sid in [s for s, e in self.sessions.items()
                    if e.claimed_by is None and e.expires_at <= self._now]:
            e = self.sessions[sid]
            if self.spill_enabled and self._spill_tail(alloc, e):
                freed += 1           # demoted: HBM freed, entry survives
            else:
                freed += self._drop_session(alloc, sid, expired=True)
        # claimed entries (transient, mid-admission) stay in the min so
        # a later tick retries them after commit/abort resolves
        self._next_expiry = min(
            (e.expires_at for e in self.sessions.values()),
            default=math.inf)
        return freed

    def _complete_restores(self, alloc) -> None:
        """Flip every in-flight restore whose modeled transfer time has
        passed: the host slot releases (restore_commit) and the page
        becomes an ordinary LIVE retained page — the held request's
        next admission attaches it by reference like any other hit."""
        still = []
        for hslot, kind, obj in self._restores:
            if kind == "node":
                node = obj
                if not node.restoring or node.hslot != hslot:
                    continue                      # torn down meanwhile
                if node.ready_at > self._now:
                    still.append((hslot, kind, obj))
                    continue
                alloc.restore_commit(hslot)
                self.prefix.mark_live(node)
                self.stats.pages_restored += 1
                self.stats.restored_tokens += self.page_size
                self.stats.bytes_restored += self.spill_page_bytes
            else:                                 # session tail
                e = self.sessions.get(obj)
                if e is None or e.tail_hslot != hslot:
                    continue                      # replaced meanwhile
                if e.tail_ready > self._now:
                    still.append((hslot, kind, obj))
                    continue
                alloc.restore_commit(hslot)
                e.tail_hslot = None
                e.tail_ready = -1.0
                self.stats.pages_restored += 1
                self.stats.restored_tokens += len(e.path) - e.full_tokens
                self.stats.bytes_restored += self.spill_page_bytes
        self._restores = still
        self._next_restore = min(
            (o.ready_at if k == "node" else self.sessions[o].tail_ready
             for _, k, o in still), default=math.inf)

    def _release_tail(self, alloc, e: _Session) -> int:
        """Tear down an entry's tail wherever it lives: LIVE unpins,
        SPILLED gives the host slot back, RESTORING commits the
        in-flight copy first (the content is already on device) and
        then unpins.  Returns device pages freed."""
        if e.tail_hslot is not None:
            if e.tail_page is not None:           # restore in flight
                alloc.restore_commit(e.tail_hslot)
                e.tail_hslot = None
                return int(alloc.unpin(e.tail_page))
            self._drop_host_slot(alloc, e.tail_hslot)
            e.tail_hslot = None
            return 0
        if e.tail_page is not None:
            return int(alloc.unpin(e.tail_page))
        return 0

    def _drop_session(self, alloc, sid: int, *, expired: bool) -> int:
        e = self.sessions.pop(sid)
        freed = self._release_tail(alloc, e)
        if expired:
            self.stats.sessions_expired += 1
        else:
            self.stats.sessions_evicted += 1
        return freed

    def on_release(self, alloc, req, path_tokens, now: float) -> int:
        """End-of-life for a finished request's pages — the ONE place
        release policy lives.  ``path_tokens`` is the transcript whose
        KV the pool physically holds: prompt + generated[:-1] (the last
        generated token's KV is never written).  Full pages go onto the
        radix path; the partial tail is pinned under the session key
        with a TTL; only then are the table's references dropped, so
        retained pages survive.  Returns pages freed (like
        ``BlockAllocator.release``); idempotent per rid."""
        self._now = max(self._now, now)
        if not alloc.holds(req.rid):
            return 0
        if not self.sessions_enabled or path_tokens is None:
            return alloc.release(req.rid)
        path = np.ascontiguousarray(path_tokens, dtype=np.int32)
        table = alloc.table(req.rid)
        T = min(len(path), len(table) * self.page_size)
        full = T // self.page_size
        if full:
            self.prefix.register(alloc, path[:full * self.page_size], table)
        sid = req.session_id
        if sid is not None:
            tail_page = table[full] if T % self.page_size else None
            if tail_page is not None:
                alloc.pin(tail_page)
            old = self.sessions.pop(sid, None)
            if old is not None:
                self._release_tail(alloc, old)
            expires = self._now + self.session_ttl
            self.sessions[sid] = _Session(
                sid=sid, turn=req.turn, path=path[:T],
                full_tokens=full * self.page_size, tail_page=tail_page,
                expires_at=expires, stamp=self.prefix._tick(),
                slo_ttft=req.slo_ttft)
            self._next_expiry = min(self._next_expiry, expires)
            self.stats.sessions_retained += 1
        return alloc.release(req.rid)

    # ------------------------------------------------- admission (lookup) --
    def lookup(self, tokens, req=None, alloc=None) -> Tuple[List[int], int]:
        """Longest retained run for ``tokens``: the radix walk first;
        then, if the request belongs to a live session whose transcript
        the prompt EXACTLY continues (token-path verified — the tail's
        KV is only valid for that path) and the radix still covers the
        whole page-aligned transcript (no gap), the pinned tail extends
        the hit to the full transcript length.  The entry is CLAIMED,
        not consumed — ``note_admit`` commits the claim (pin hand-over)
        once the allocator accepted the request; ``abort`` rolls it
        back if admission failed.

        SPILLED continuation (host tier): when the walk runs into pages
        that were spilled to host — cold radix pages or a demoted
        session tail — the lookup initiates their host→device RESTORE
        (device pages reserved, copies dispatched) and flags the
        request HELD via ``req.spill_wait``: ``admit_blocks`` does not
        admit it, the loop parks it until the transfer lands, and its
        NEXT admission finds the pages live and resumes past them —
        restore latency lands on that request's TTFT instead of a full
        re-prefill.  If no device page can be reserved even after
        eviction, the request falls back to its live hit (cold
        re-prefill of the spilled part, which ``register`` then uses to
        revive the spilled nodes for free)."""
        tokens = np.asarray(tokens)
        pages, cont = self.prefix.lookup_run(tokens)
        hit = len(pages) * self.page_size
        e = None
        sid = getattr(req, "session_id", None)
        if sid is not None and self.sessions_enabled:
            cand = self.sessions.get(sid)
            # expires_at is inf for a demoted (spilled) entry: host
            # residence, not the TTL, bounds its life now
            if (cand is not None and cand.claimed_by is None
                    and cand.expires_at > self._now):
                T = len(cand.path)
                walk = hit + len(cont) * self.page_size
                # the walk must REACH the transcript's full pages but
                # the live hit must not overshoot them: a radix run
                # extending past full_tokens (another request indexed
                # more of the same path) already serves the whole
                # transcript better than the tail hand-over would —
                # claiming then would hand the tail to the wrong table
                # index and shrink the prefix skip (the PR 4 `==` rule)
                if (walk >= cand.full_tokens and hit <= cand.full_tokens
                        and len(tokens) > T
                        and np.array_equal(tokens[:T], cand.path)):
                    e = cand
                    e.stamp = self.prefix._tick()
        if (cont or (e is not None and e.tail_hslot is not None)) \
                and self.spill_enabled and alloc is not None:
            if self._restore_path(alloc, req, pages, cont, e):
                return pages, hit                # held — not admitted
        if e is not None and hit == e.full_tokens and e.tail_hslot is None:
            e.claimed_by = req.rid
            req.session_hit_tokens = len(e.path)
            if e.tail_page is not None:
                return pages + [e.tail_page], len(e.path)
        return pages, hit

    def _restore_path(self, alloc, req, pages: List[int], cont,
                      e: Optional[_Session]) -> bool:
        """Bring the spilled continuation of a hit back to device:
        reserve a destination page per spilled node (evicting colder
        retained pages if the free list is short), dispatch the copies,
        and model their completion — one transfer channel, so a run of
        k pages lands ``k * spill_seconds_per_page`` after the channel
        frees.  Returns True when the request must be HELD
        (``req.spill_wait`` set to the completion time).  Restores that
        are already in flight are joined, not re-issued (idempotence);
        a run that cannot reserve pages degrades to the live hit."""
        ready = -1.0
        new = 0
        protect = list(pages)
        planned: List[Tuple[int, int]] = []      # (hslot, page) copies
        broken = False
        for node in cont:
            if node.restoring:
                ready = max(ready, node.ready_at)
                protect.append(node.page)
                continue
            if not self._checksum_ok(node.hslot):
                # bit-rot at rest: destroy the node (and its — equally
                # spilled — subtree) before any copy moves garbage; the
                # request degrades to its live hit and re-prefills
                self.stats.corruptions += 1
                if self.tracer.enabled:
                    self.tracer.instant(
                        "restore-channel", "corrupt-slot", self._now,
                        cat="fault", args={"hslot": node.hslot})
                self.prefix._drop_spilled_subtree(alloc, node)
                self.prefix.drop_spilled_node(alloc, node)
                broken = True
                break
            page = self._reserve_page(alloc, node.hslot, protect)
            if page is None:
                broken = True
                break
            self.prefix.mark_restoring(node, page, math.inf)
            self._restores.append((node.hslot, "node", node))
            planned.append((node.hslot, page))
            protect.append(page)
            new += 1
        if (e is not None and e.tail_hslot is not None
                and e.tail_page is None and not broken):
            if not self._checksum_ok(e.tail_hslot):
                # the tail tokens are lost to bit-rot: the entry
                # survives truncated to its page-aligned transcript
                # (the radix still backs that); an entry with nothing
                # left drops entirely
                self.stats.corruptions += 1
                h = e.tail_hslot
                e.tail_hslot = None
                e.tail_ready = -1.0
                self._drop_host_slot(alloc, h)
                e.path = e.path[:e.full_tokens]
                if e.full_tokens == 0:
                    self._drop_session(alloc, e.sid, expired=False)
            else:
                page = self._reserve_page(alloc, e.tail_hslot, protect)
                if page is not None:
                    e.tail_page = page
                    self._restores.append((e.tail_hslot, "tail", e.sid))
                    planned.append((e.tail_hslot, page))
                    protect.append(page)
                    new += 1
        elif e is not None and e.tail_hslot is not None \
                and e.tail_page is not None:
            ready = max(ready, e.tail_ready)          # already in flight
            protect.append(e.tail_page)
        if new:
            # fault plane (core/faults.py): one stall draw + a bounded
            # retry loop of hard-error draws per dispatched run.  Draws
            # happen BEFORE any copy is issued, so a failed run cancels
            # cleanly (reserved pages return, slots back at rest).
            stall = 0.0
            attempts = 0
            failed = False
            if self.faults is not None:
                if self.faults.fire("restore_stall"):
                    stall = self.faults.plan.stall_s
                    self.stats.restore_stalls += 1
                max_r = self.recovery.max_retries \
                    if self.recovery is not None else 0
                while self.faults.fire("restore_error"):
                    attempts += 1
                    if attempts > max_r:
                        failed = True
                        break
            if failed:
                self._cancel_new_restores(alloc, new, e)
                self.stats.restore_failures += 1
                self.stats.restore_retries += attempts - 1
                if self.tracer.enabled:
                    self.tracer.instant(
                        "restore-channel", "restore-failed", self._now,
                        cat="fault", args={"pages": new, "rid": req.rid})
                new = 0
            else:
                # each retry re-sends the whole run (burns the channel);
                # backoff gaps sit between sends
                xfer = (attempts + 1) * new * self.spill_seconds_per_page
                backoff = sum(self.recovery.backoff(i)
                              for i in range(attempts)) \
                    if attempts and self.recovery is not None else 0.0
                ch_start = max(self._now, self._restore_free)
                done = ch_start + stall + xfer + backoff
                # deadline-slack shed rule (core/recovery.py): when the
                # restore cannot land inside the requester's remaining
                # TTFT budget, give the channel to winnable work and
                # fall back to recompute
                if (self.recovery is not None and req is not None
                        and self.recovery.should_shed(
                            req.slo_ttft - (self._now - req.t0()),
                            done - self._now)):
                    self._cancel_new_restores(alloc, new, e)
                    self.stats.restore_sheds += 1
                    self.stats.restore_retries += attempts
                    new = 0
                else:
                    self._restore_free = done
                    if self.copier is not None:
                        for hslot, page in planned:
                            self.copier.restore(hslot, page)
                    if self.tracer.enabled:
                        self.tracer.complete(
                            "restore-channel", f"restore x{new}", ch_start,
                            done - ch_start, cat="restore",
                            args={"pages": new, "rid": req.rid,
                                  "retries": attempts, "stall_s": stall})
                    self.stats.restore_seconds += xfer
                    self.stats.restore_retries += attempts
                    for hslot, kind, obj in self._restores[-new:]:
                        if kind == "node":
                            obj.ready_at = done
                        else:                     # tail (only if tail_new)
                            e.tail_ready = done
                    self._next_restore = min(self._next_restore, done)
                    ready = max(ready, done)
        if ready >= 0.0:
            req.spill_wait = ready
            self.stats.restore_holds += 1
            self._reserved[req.rid] = (ready + 60.0, frozenset(protect))
            return True
        return False

    def _cancel_new_restores(self, alloc, new: int, e) -> None:
        """Unwind the trailing ``new`` restores of a run that never
        dispatched (hard fault after retries, or shed): reserved pages
        return to the free list, slots go back AT REST — the inverse of
        the reservation walk, no copy was ever issued."""
        for hslot, kind, obj in self._restores[-new:]:
            ok = alloc.restore_cancel(hslot)
            assert ok, f"cancel of slot {hslot} found no restore in flight"
            if kind == "node":
                self.prefix.mark_spilled(obj, hslot)
            else:
                e.tail_page = None
                e.tail_ready = -1.0
        del self._restores[-new:]

    def _reserve_page(self, alloc, hslot: int, protect) -> Optional[int]:
        page = alloc.restore_begin(hslot)
        if page is None and self.evict(alloc, 1, protect=protect) > 0:
            page = alloc.restore_begin(hslot)
        return page

    def _protected(self, protect) -> set:
        """Caller's protect set plus every unexpired restore
        reservation (expired ones are dropped — the leak backstop)."""
        p = set(protect)
        for rid in list(self._reserved):
            expiry, pages = self._reserved[rid]
            if expiry <= self._now:
                del self._reserved[rid]
            else:
                p |= pages
        return p

    def note_admit(self, alloc, req, hit_tokens: int) -> None:
        """A request was ADMITTED (pages allocated): fold its hit into
        the radix stats and commit any pending session claim — the
        table now references the tail, so the session pin transfers
        (unpin) and the entry is consumed."""
        self._reserved.pop(req.rid, None)      # restore consumed
        self.prefix.note_admit(alloc, req, hit_tokens)
        sid = getattr(req, "session_id", None)
        if sid is None or not self.sessions_enabled:
            return
        self.stats.session_lookups += 1
        e = self.sessions.get(sid)
        if e is None or e.claimed_by != req.rid:
            return
        del self.sessions[sid]
        if e.tail_page is not None:
            alloc.unpin(e.tail_page)
            self.stats.tail_reuses += 1
        self.stats.session_hits += 1
        self.stats.session_hit_tokens += len(e.path)

    def abort(self, req) -> None:
        """Admission failed after ``lookup``: release the claim so the
        session stays resumable (nothing was mutated yet).  Also the
        HOLD path: a held request keeps no claim — in-flight restores
        stay owned by the retention layer and complete regardless."""
        sid = getattr(req, "session_id", None)
        if sid is None:
            return
        e = self.sessions.get(sid)
        if e is not None and e.claimed_by == req.rid:
            e.claimed_by = None
        req.session_hit_tokens = 0

    # ---------------------------------------------------------- eviction --
    def evict(self, alloc, need: int, protect=()) -> int:
        """Free up to ``need`` device pages along the ONE retention
        order: (1) expired session tails (dead weight), (2) LRU cold
        radix prefixes (nobody loses work), (3) live session tails,
        soonest-expiring first (a session loses its resume, no live
        request loses work).  With the host tier enabled every rung
        tries to SPILL its victim first — the HBM page frees either
        way, but a spilled victim stays restorable for a bandwidth
        cost — and destroys only when the host budget is ALSO
        exhausted (after the host pool's own LRU failed to make room
        for a warmer entry).  The caller (``paging.extend_for_decode``)
        falls back to request preemption only when every rung comes up
        empty — a retained page is always sacrificed before any live
        request loses work.

        Pages reserved by an in-flight restore (``_reserved``) are
        protected too: spilling a page some held request is about to
        consume would trade one copy for another forever (restore
        thrash) instead of making progress."""
        protect = self._protected(protect)
        freed = self._reclaim_sessions(alloc, need, protect,
                                       expired_only=True)
        if freed < need:
            freed += self._reclaim_prefix(alloc, need - freed, protect)
        if freed < need:
            freed += self._reclaim_sessions(alloc, need - freed, protect,
                                            expired_only=False)
        if self.tracer.enabled:
            self.tracer.instant("retention", "evict-walk", self._now,
                                cat="evict",
                                args={"need": need, "freed": freed})
        return freed

    def evict_one(self, alloc, protect=()) -> bool:
        return self.evict(alloc, 1, protect) > 0

    def _reclaim_sessions(self, alloc, need: int, protect,
                          expired_only: bool) -> int:
        freed = 0
        if need <= 0 or not self.sessions:
            return 0
        if self.slack_aware and not expired_only:
            # slack-ordered sacrifice (DESIGN.md §8): unpin the session
            # whose class TTFT budget is LOOSEST first — a batch-class
            # transcript eats a cold resume inside its budget; a chat
            # session does not.  Ties fall back to soonest-expiring.
            # The rank is clock-free (class budgets only), so eviction
            # decisions stay parity-equal across backends.
            key = lambda kv: (-kv[1].slo_ttft, kv[1].expires_at)  # noqa: E731
        else:
            key = lambda kv: kv[1].expires_at                     # noqa: E731
        for sid, e in sorted(self.sessions.items(), key=key):
            if freed >= need:
                break
            if (e.claimed_by is not None or e.tail_page is None
                    or e.tail_hslot is not None    # no HBM behind it
                    or e.tail_page in protect
                    or alloc.refs(e.tail_page) != 1):
                continue
            if expired_only and e.expires_at > self._now:
                continue
            if self.spill_enabled and self._spill_tail(alloc, e):
                freed += 1                         # demoted, not destroyed
                continue
            expired = e.expires_at <= self._now
            freed += self._drop_session(alloc, sid, expired=expired)
        return freed

    def _reclaim_prefix(self, alloc, need: int, protect) -> int:
        """Radix rung: spill the LRU frontier to host while the budget
        lasts (spilling a leaf exposes its parent, so rescan per
        generation like ``PrefixCache.evict``), then fall back to
        destructive LRU eviction for the remainder."""
        freed = 0
        if need <= 0:
            return 0
        if self.spill_enabled:
            exhausted = False
            while freed < need and not exhausted:
                progressed = False
                for node in self.prefix.spill_candidates(alloc, protect):
                    if freed >= need:
                        break
                    if not self._spill_node(alloc, node):
                        exhausted = True           # host budget is gone
                        break
                    freed += 1
                    progressed = True
                if not progressed:
                    break
        if freed < need:
            freed += self.prefix.evict(alloc, need - freed, protect)
        return freed

    # ------------------------------------------------- spill transitions --
    def _spill_node(self, alloc, node) -> bool:
        if not self._host_slot_for(alloc, node.stamp):
            return False
        h = alloc.spill(node.page)
        if h is None:
            return False
        if self.copier is not None:
            self.copier.spill(node.page, h)
        self._stamp_checksum(h)
        self.prefix.mark_spilled(node, h)
        self.stats.pages_spilled += 1
        self.stats.spill_seconds += self.spill_seconds_per_page
        self.stats.bytes_spilled += self.spill_page_bytes
        if self.tracer.enabled:
            self.tracer.complete("spill-channel", "spill", self._now,
                                 self.spill_seconds_per_page, cat="spill",
                                 args={"hslot": h, "kind": "prefix"})
        return True

    def _spill_tail(self, alloc, e: _Session) -> bool:
        if (e.tail_page is None or e.tail_hslot is not None
                or e.claimed_by is not None
                or alloc.refs(e.tail_page) != 1
                or not self._host_slot_for(alloc, e.stamp)):
            return False
        h = alloc.spill(e.tail_page)
        if h is None:
            return False
        if self.copier is not None:
            self.copier.spill(e.tail_page, h)
        self._stamp_checksum(h)
        e.tail_page = None
        e.tail_hslot = h
        e.expires_at = math.inf        # demoted: host LRU owns it now
        self.stats.pages_spilled += 1
        self.stats.spill_seconds += self.spill_seconds_per_page
        self.stats.bytes_spilled += self.spill_page_bytes
        if self.tracer.enabled:
            self.tracer.complete("spill-channel", "spill", self._now,
                                 self.spill_seconds_per_page, cat="spill",
                                 args={"hslot": h, "kind": "tail"})
        return True

    def _host_slot_for(self, alloc, stamp: int) -> bool:
        """Ensure a free host slot for an item stamped ``stamp``: when
        the pool is full, drop the LRU spilled item (radix leaf or
        demoted session tail) — but only one COLDER than the incoming
        item, so the host pool converges to the warmest retained set
        instead of thrashing."""
        if not self.spill_enabled:
            return False
        while alloc.free_host_slots() == 0:
            cands = []
            node = self.prefix.lru_spilled_leaf()
            if node is not None:
                cands.append((node.stamp, 0, node))
            sess = min((e for e in self.sessions.values()
                        if e.tail_hslot is not None and e.tail_page is None
                        and e.claimed_by is None),
                       key=lambda e: e.stamp, default=None)
            if sess is not None:
                cands.append((sess.stamp, 1, sess))
            if not cands:
                return False
            vstamp, kind, victim = min(cands)
            if vstamp >= stamp:
                return False           # incoming is colder than the pool
            if kind == 0:
                self.prefix.drop_spilled_node(alloc, victim)
            else:
                self.sessions.pop(victim.sid)
                self._drop_host_slot(alloc, victim.tail_hslot)
        return True

    # --------------------------------------------- recovery / drain hooks --
    def cancel_hold(self, req, timeout: bool = True) -> None:
        """A held request abandons its parked restore — the restore
        timeout fired (stalled channel) or the loop is draining: drop
        its anti-thrash reservation and any session claim so it
        re-enters the queue COLD.  In-flight restores stay owned by the
        layer — if the copies ever land, the pages become ordinary
        retained pages a later admission can hit."""
        self._reserved.pop(req.rid, None)
        self.abort(req)
        if timeout:
            self.stats.restore_timeouts += 1
            if self.tracer.enabled:
                self.tracer.instant("restore-channel", "hold-timeout",
                                    self._now, cat="fault",
                                    args={"rid": req.rid})

    def demote_all(self, alloc) -> int:
        """Drain (core/recovery.py): demote every live session tail
        device->host so retained transcripts survive device teardown —
        the host tier is the designated survivor of device loss.
        Returns tails demoted."""
        n = 0
        for e in list(self.sessions.values()):
            if e.tail_page is not None and e.tail_hslot is None \
                    and e.claimed_by is None \
                    and self._spill_tail(alloc, e):
                n += 1
        return n

    def clear(self, alloc) -> int:
        """Unpin everything — every session tail (committing in-flight
        restores, returning host slots), then the whole radix.
        Returns device pages freed."""
        freed = 0
        for sid in list(self.sessions):
            freed += self._drop_session(alloc, sid, expired=False)
        self._restores.clear()
        self._next_restore = math.inf
        self._checksums.clear()
        return freed + self.prefix.clear(alloc)


# ------------------------------------------------------ shared maintain ---
def maintain_backend(backend, now: float) -> None:
    """THE one housekeeping path for every execution backend's
    ``maintain`` hook: tick the retention layer (TTL expiry/demotion
    AND spill/restore completion polling) exactly when a paged pool
    with a retention layer exists.  Both ``JaxEngineBackend`` and
    ``CostModelBackend`` delegate here verbatim, so an event that fires
    at clock time t in one backend fires at t in the other — the
    pre-PR-5 backends each hand-rolled this guard, and a drift in
    either (ticking without paged, forgetting the completion poll)
    silently broke parity."""
    rt = getattr(backend, "retention", None)
    if rt is not None and getattr(backend, "paged", False):
        rt.tick(backend.alloc, now)
