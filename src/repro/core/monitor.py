"""Global Monitor — system-wide metric aggregation (paper §III).

Feeds the Dynamic Batching Controller (memory pressure) and the P/D
Scheduler (queue occupancy, waiting times).  Pure bookkeeping: works for
both the discrete-event simulator and the real engine.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List

from .telemetry import blame_means


@dataclasses.dataclass
class Snapshot:
    t: float
    queue_len: int
    decode_pool: int
    in_flight_tokens: int
    arrival_rate: float
    mean_seq_len: float
    n_buckets: int
    kv_util: float
    prefix_hit_rate: float = 0.0
    prefix_pages_saved: int = 0
    session_hits: int = 0
    session_hit_tokens: int = 0
    spilled_pages: int = 0
    restored_pages: int = 0
    # live tail-latency state (PR 7): nearest-rank percentiles over the
    # rolling TTFT/TPOT sample windows — what an SLO-aware scheduler
    # steers on (a mean hides exactly the tail it must protect).  p95
    # included because the SLO gates read p95 (PR 8).
    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    tpot_p50: float = 0.0
    tpot_p99: float = 0.0
    ttft_p95: float = 0.0
    tpot_p95: float = 0.0
    # mean seconds per ledger phase over the rolling retirement window
    # (core/telemetry.py blame_means — the ONE aggregation rule shared
    # with ServeResult.blame)
    blame: Dict[str, float] = dataclasses.field(default_factory=dict)
    # deadline-slack state (DESIGN.md §8): rolling per-class goodput
    # (fraction of recently retired requests meeting BOTH SLO budgets)
    # and the tightest live deadline slack seen at the goodput
    # scheduler's last queue scan — what its admission relief steers on
    class_goodput: Dict[str, float] = dataclasses.field(default_factory=dict)
    min_slack_s: float = float("inf")


def _nearest_rank(xs, q: float) -> float:
    """Nearest-rank percentile (ceil(q/100 * n)-th sorted sample); 0.0
    on an empty series.  The SAME rule ServeResult uses, so live
    snapshots and post-run gates can never disagree on definition."""
    if not xs:
        return 0.0
    s = sorted(xs)
    rank = max(-(-int(q * len(s)) // 100), 1)   # ceil without float error
    return s[min(rank, len(s)) - 1]


class GlobalMonitor:
    def __init__(self, window_s: float = 10.0):
        self.window_s = window_s
        self.arrivals: Deque[float] = collections.deque()
        self.seq_lens: Deque[int] = collections.deque(maxlen=512)
        self.batch_lat: Deque[float] = collections.deque(maxlen=512)
        # rolling tail-latency samples (PR 7), fed by the ServingLoop
        # at first-token / retirement time
        self.ttft_samples: Deque[float] = collections.deque(maxlen=512)
        self.tpot_samples: Deque[float] = collections.deque(maxlen=512)
        # rolling per-class latency-blame samples (PR 8): closed ledger
        # phase dicts keyed by request class ('' = untagged)
        self.blame_samples: Dict[str, Deque[Dict[str, float]]] = \
            collections.defaultdict(lambda: collections.deque(maxlen=512))
        # rolling per-class SLO outcomes (DESIGN.md §8): one met/missed
        # flag per retired request, windowed like blame — the live
        # goodput estimate a deadline-aware scheduler steers on
        self.slo_samples: Dict[str, Deque[bool]] = \
            collections.defaultdict(lambda: collections.deque(maxlen=512))
        # tightest deadline slack over the queued requests at the
        # goodput scheduler's last scan (a LEVEL, overwritten per scan;
        # inf = no queue or no slack-aware scheduler attached)
        self.min_slack_s = float("inf")
        self.history: List[Snapshot] = []
        self.in_flight_tokens = 0
        self.decode_pool = 0
        self.queue_len = 0
        self.n_buckets = 1
        self.kv_budget_tokens = 1.0
        # cross-request prefix cache (core/prefix_cache.py): admission
        # hit accounting, fed by the ServingLoop per admitted request
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prefix_pages_saved = 0
        # session retention (core/retention.py): admitted requests that
        # resumed a retained conversation transcript
        self.session_hits = 0
        self.session_hit_tokens = 0
        # host spill tier (core/retention.py, PR 5): pages moved over
        # the host<->device channel instead of dropped/re-prefilled
        self.spilled_pages = 0
        self.restored_pages = 0
        # restore-aware admission pricing: the CURRENT in-flight
        # restore state (pages reserved on device, compressed bytes
        # still queued on the channel) — levels, not counters; the
        # loop's maintain step overwrites them each iteration and the
        # batch controller folds them into Eq. (6)
        self.restore_pages_in_flight = 0
        self.restore_backlog_bytes = 0

    # ------------------------------------------------------------ events --
    def on_arrival(self, t: float, seq_len: int) -> None:
        self.arrivals.append(t)
        self._prune_arrivals(t)
        self.seq_lens.append(seq_len)
        self.queue_len += 1

    def on_requeue(self) -> None:
        """Re-admission of an already-counted request (OOM eviction,
        slot-capacity clamp).  Restores queue occupancy WITHOUT touching
        the arrival-rate window or the sequence-length stats — those
        describe the client workload, which did not change."""
        self.queue_len += 1

    def on_batch(self, latency_s: float) -> None:
        self.batch_lat.append(latency_s)

    def on_first_token(self, ttft_s: float, cls: str = "") -> None:
        """A request produced its first token ``ttft_s`` after arrival."""
        self.ttft_samples.append(ttft_s)

    def on_tpot(self, tpot_s: float, cls: str = "") -> None:
        """A request finished with a per-output-token latency sample."""
        self.tpot_samples.append(tpot_s)

    def on_retire(self, cls: str, phases: Dict[str, float],
                  slo_met: bool | None = None) -> None:
        """A request retired with a closed latency ledger: keep its
        phase breakdown in the rolling per-class blame window, and
        (when the loop reports it) its SLO outcome in the rolling
        goodput window."""
        self.blame_samples[cls].append(dict(phases))
        if slo_met is not None:
            self.slo_samples[cls].append(bool(slo_met))

    def on_slack(self, slack_s: float) -> None:
        """The slack-aware scheduler scanned its queue: overwrite the
        tightest remaining deadline slack it saw (seconds; negative =
        a request is already past its TTFT budget)."""
        self.min_slack_s = slack_s

    def on_prefix_lookup(self, hit_tokens: int, page_size: int) -> None:
        """One admitted request matched against the prefix cache:
        ``hit_tokens`` prompt tokens (page-aligned, 0 = cold) will be
        served from shared pages instead of re-prefilled."""
        self.prefix_lookups += 1
        if hit_tokens > 0:
            self.prefix_hits += 1
            self.prefix_hit_tokens += hit_tokens
            self.prefix_pages_saved += hit_tokens // max(page_size, 1)

    def on_session_hit(self, hit_tokens: int) -> None:
        """One admitted request resumed a retained session transcript:
        ``hit_tokens`` transcript tokens (including the pinned partial
        tail) restored instead of re-prefilled."""
        self.session_hits += 1
        self.session_hit_tokens += hit_tokens

    def on_spill_traffic(self, spilled: int, restored: int) -> None:
        """Host-tier copy traffic since the last report: pages that
        moved device->host (eviction demoted, not destroyed) and pages
        that came back host->device (restored instead of
        re-prefilled)."""
        self.spilled_pages += spilled
        self.restored_pages += restored

    def on_restore_state(self, pages_in_flight: int,
                         backlog_bytes: int) -> None:
        """Overwrite the in-flight restore LEVEL (not a delta): device
        pages reserved by restores plus compressed bytes queued on the
        host channel, read off the retention layer each maintain
        tick."""
        self.restore_pages_in_flight = pages_in_flight
        self.restore_backlog_bytes = backlog_bytes

    # ------------------------------------------------------------- stats --
    def _prune_arrivals(self, t: float) -> None:
        """Drop arrival stamps older than the window.  Called on BOTH
        arrival and snapshot — an idle tail with no arrivals must decay
        to rate 0, not keep reporting the last burst forever."""
        while self.arrivals and self.arrivals[0] < t - self.window_s:
            self.arrivals.popleft()

    def arrival_rate(self) -> float:
        if len(self.arrivals) < 2:
            return 0.0
        span = max(self.arrivals[-1] - self.arrivals[0], 1e-6)
        return (len(self.arrivals) - 1) / span

    def mean_seq_len(self) -> float:
        if not self.seq_lens:
            return 1.0
        return sum(self.seq_lens) / len(self.seq_lens)

    def mean_batch_latency(self) -> float:
        if not self.batch_lat:
            return 0.0
        return sum(self.batch_lat) / len(self.batch_lat)

    def kv_util(self) -> float:
        return min(1.0, self.in_flight_tokens / max(self.kv_budget_tokens, 1))

    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / max(self.prefix_lookups, 1)

    def ttft_percentile(self, q: float) -> float:
        return _nearest_rank(self.ttft_samples, q)

    def tpot_percentile(self, q: float) -> float:
        return _nearest_rank(self.tpot_samples, q)

    def blame(self, cls: str = "") -> Dict[str, float]:
        """Mean seconds per phase over the rolling window for one
        request class (all classes pooled when every sample is '')."""
        return blame_means(list(self.blame_samples.get(cls, ())))

    def class_goodput(self) -> Dict[str, float]:
        """Rolling per-class goodput: fraction of recently retired
        requests (the slo_samples window) that met both SLO budgets."""
        return {cls: sum(dq) / len(dq)
                for cls, dq in self.slo_samples.items() if dq}

    def snapshot(self, t: float) -> Snapshot:
        self._prune_arrivals(t)     # idle tail: rate decays without events
        pooled = [s for dq in self.blame_samples.values() for s in dq]
        s = Snapshot(t, self.queue_len, self.decode_pool,
                     self.in_flight_tokens, self.arrival_rate(),
                     self.mean_seq_len(), self.n_buckets, self.kv_util(),
                     self.prefix_hit_rate(), self.prefix_pages_saved,
                     self.session_hits, self.session_hit_tokens,
                     self.spilled_pages, self.restored_pages,
                     self.ttft_percentile(50), self.ttft_percentile(99),
                     self.tpot_percentile(50), self.tpot_percentile(99),
                     ttft_p95=self.ttft_percentile(95),
                     tpot_p95=self.tpot_percentile(95),
                     blame=blame_means(pooled),
                     class_goodput=self.class_goodput(),
                     min_slack_s=self.min_slack_s)
        self.history.append(s)
        return s
