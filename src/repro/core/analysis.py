"""Analytical waste model — paper Eqs. (1)-(4).

Eq. (1)  KV memory of a batch:  2·L·H·D·S_max·B·N
Eq. (2)  waste ratio:           (S_max - S_avg) / S_max
Eq. (3)  expected waste:        Σ_b ∫_{L_b}^{U_b} (1 - S/U_b) f(S) dS
Eq. (4)  optimal boundary:      U_b* = E[S | S in bucket]

These drive both the benchmark `waste_model` (validating that midpoint
bisection approaches the Eq.-(4) optimum) and the beyond-paper
quantile-based boundary refinement (core/bucket.py).
"""
from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np


def kv_cache_bytes(n_layers: int, n_heads: int, d_head: int, s_max: int,
                   bytes_per_el: int, batch: int) -> int:
    """Paper Eq. (1)."""
    return 2 * n_layers * n_heads * d_head * s_max * bytes_per_el * batch


def waste_ratio(lengths: Sequence[int]) -> float:
    """Paper Eq. (2) for one batch."""
    if len(lengths) == 0:
        return 0.0
    smax = max(lengths)
    if smax == 0:
        return 0.0
    return (smax - float(np.mean(lengths))) / smax


def expected_waste(lengths: np.ndarray, boundaries: Sequence[float]) -> float:
    """Paper Eq. (3), empirical: lengths ~ f(S); buckets [b_i, b_{i+1}).

    Padding target of bucket b is its upper bound U_b; waste of a request
    of length S is (1 - S/U_b).  Returns the mean over all requests.
    """
    lengths = np.asarray(lengths, np.float64)
    bounds = np.asarray(sorted(boundaries), np.float64)
    assert len(bounds) >= 2
    idx = np.clip(np.searchsorted(bounds, lengths, side="right") - 1,
                  0, len(bounds) - 2)
    ub = bounds[idx + 1]
    ub = np.maximum(ub, 1e-9)
    return float(np.mean(1.0 - np.minimum(lengths, ub) / ub))


def padded_tokens(lengths: np.ndarray, boundaries: Sequence[float]) -> float:
    """Total padded-slot tokens under bucket-upper padding (for benches)."""
    lengths = np.asarray(lengths, np.float64)
    bounds = np.asarray(sorted(boundaries), np.float64)
    idx = np.clip(np.searchsorted(bounds, lengths, side="right") - 1,
                  0, len(bounds) - 2)
    return float(np.sum(bounds[idx + 1] - lengths))


def optimal_boundary(lengths: np.ndarray, low: float, up: float) -> float:
    """Paper Eq. (4): conditional expectation of S within [low, up)."""
    lengths = np.asarray(lengths, np.float64)
    sel = lengths[(lengths >= low) & (lengths < up)]
    if sel.size == 0:
        return (low + up) / 2
    return float(sel.mean())


def optimal_boundaries_kmeans(lengths: np.ndarray, k: int,
                              iters: int = 50) -> list[float]:
    """Iterate Eq. (4) to a fixed point (1-D Lloyd's) — the paper's
    theoretical optimum, used as the gold standard in benchmarks and by
    the beyond-paper `distribution_aware` refinement."""
    lengths = np.sort(np.asarray(lengths, np.float64))
    if lengths.size == 0:
        return [0.0, 1.0]
    qs = np.linspace(0, 1, k + 1)
    bounds = np.quantile(lengths, qs)
    bounds[0], bounds[-1] = 0.0, lengths[-1] + 1
    for _ in range(iters):
        centers = []
        for i in range(k):
            centers.append(optimal_boundary(lengths, bounds[i], bounds[i + 1]))
        new = bounds.copy()
        for i in range(k - 1):
            # boundary between buckets i, i+1 sits between their optima
            new[i + 1] = (centers[i] + centers[i + 1]) / 2
        if np.allclose(new, bounds):
            break
        bounds = new
    return list(bounds)
