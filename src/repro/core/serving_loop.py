"""Event-driven serving loop — ONE orchestrator for every substrate.

Historically the repo had two divergent run loops: three hand-rolled
mode loops in ``core/simulator.py`` and a synchronous coupled loop in
``core/engine.py``.  This module extracts the shared orchestration —
arrivals, scheduler ticks, prefill dispatch (optionally in chunks),
KV-transfer/join, decode-pool admission, OOM handling/re-queue, and
per-request timing — into a single :class:`ServingLoop` that drives any
object implementing the :class:`ExecutionBackend` protocol
(DESIGN.md §2).

Backends plug in the substrate:

* ``CostModelBackend`` (core/simulator.py) — analytic A100/TPU cost
  model on a :class:`VirtualClock`; paper-scale discrete-event runs.
* ``JaxEngineBackend`` (core/engine.py)    — real jitted prefill/decode
  on a :class:`WallClock`; tiny-model CPU/TPU runs, token for token.

Execution topology is loop *configuration*, not loop code:

* ``disagg``  — separate prefill/decode executors + KV transfer
  (BucketServe, DistServe).  The real engine also runs this topology:
  chunked prefill interleaves decode iterations between prompt chunks,
  so decode never stalls behind a long prefill.
* ``coupled`` — one executor; each iteration fuses the new prefill
  batch with one decode step over the live pool (Orca-style
  iteration-level scheduling; prefill inflates every concurrent TPOT).
* ``static``  — one executor; a formed batch runs prefill + ALL decode
  steps to completion before the next batch starts, every iteration
  reading the PADDED batch context (paper Fig. 3b waste).

OOM semantics: admitting more live KV tokens than the backend budget
triggers an OOM event — the offending batch is evicted and re-queued
(``requeue=True``: workload stats are not double-counted) after a
restart penalty.  BucketServe's Eq. (5)/(6) safety avoids these by
construction.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import time
from typing import (Dict, List, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)

import numpy as np

from .batcher import FormedBatch
from .monitor import _nearest_rank
from .recovery import (DEFAULT_RECOVERY, LoopCheckpoint, RecoveryPolicy,
                       build_checkpoint)
from .request import Request
from .telemetry import (NULL_TRACER, WAIT_PHASES, LatencyLedger,
                        blame_means)


# -------------------------------------------------------------- clocks ----
class Clock(Protocol):
    """Minimal clock the loop schedules against.  ``virtual`` clocks jump
    between events (discrete-event time); wall clocks sleep."""

    virtual: bool

    def now(self) -> float: ...

    def advance(self, to: float) -> None: ...


class VirtualClock:
    """Discrete-event time: ``advance`` jumps straight to the event."""

    virtual = True

    def __init__(self) -> None:
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def advance(self, to: float) -> None:
        self.t = max(self.t, to)


class WallClock:
    """Scaled wall time: ``time_scale`` virtual seconds per wall second.
    ``advance`` sleeps (capped at 1 ms so arrivals stay responsive)."""

    virtual = False

    def __init__(self, time_scale: float = 1.0) -> None:
        self.time_scale = time_scale
        self._t0 = time.perf_counter()

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return (time.perf_counter() - self._t0) * self.time_scale

    def advance(self, to: float) -> None:
        dt = (to - self.now()) / self.time_scale
        if dt > 0:
            time.sleep(min(dt, 0.001))

    def wall_elapsed(self) -> float:
        return time.perf_counter() - self._t0


# ---------------------------------------------------------------- jobs ----
def plan_chunks(total: int, chunk: Optional[int],
                skip: int = 0) -> List[Tuple[int, int]]:
    """Split ``total`` padded prompt tokens into (start, length) spans.
    ``chunk`` of None/<=0/>=remaining means whole-prompt (one span).
    ``skip`` head positions (a cached prefix) are excluded from
    planning but spans keep ABSOLUTE offsets, so token slicing and RoPE
    stay positionally exact.  Shared by every backend so the span math
    cannot drift between substrates."""
    if skip:
        assert 0 < skip < total, (skip, total)
        return [(skip + s, ln) for s, ln in plan_chunks(total - skip, chunk)]
    if not chunk or chunk <= 0 or chunk >= total:
        return [(0, total)]
    return [(s, min(chunk, total - s)) for s in range(0, total, chunk)]


def batch_prefix_skip(batch: FormedBatch) -> int:
    """Prompt positions a whole batch can skip: the MINIMUM cached
    prefix across rows (page-aligned; a cold row pins it to 0).  Rows
    with longer hits recompute the overlap — bit-identical by
    construction, so correctness never depends on batch mixing.  The
    ONE min-over-batch rule both backends plan chunks with."""
    return min((r.prefix_hit_tokens for r in batch.requests), default=0)


@dataclasses.dataclass
class PrefillJob:
    """A formed batch scheduled onto the prefill executor, split into
    token-span chunks.  Un-chunked execution is the 1-chunk case."""

    batch: FormedBatch
    chunks: List[Tuple[int, int]]            # (start, length) token spans
    next_chunk: int = 0
    started_at: float = -1.0
    handle: object = None                    # backend-private chunk state
    fault_attempts: int = 0                  # injected chunk faults absorbed
    faulted: bool = False                    # ledgers parked in fault_retry

    @property
    def done(self) -> bool:
        return self.next_chunk >= len(self.chunks)


# ------------------------------------------------------------- protocol ---
@runtime_checkable
class ExecutionBackend(Protocol):
    """What a substrate must provide to be driven by the ServingLoop.

    The backend owns *execution* (device state, cost math) and its own
    notion of time; the loop owns *orchestration* (queues, admission,
    OOM policy, timing bookkeeping).  Durations are in the clock's
    (virtual) seconds.  On a wall clock the calls block for real and the
    returned duration is ignored — the loop reads the clock instead.
    """

    clock: Clock
    flops_per_token: float        # model FLOPs per processed token (2·P)
    prefill_needs_slots: bool     # True: a batch needs free decode slots
    supports_decode: bool         # False: requests finish at first token

    def begin(self, requests: Sequence[Request]) -> None:
        """Reset per-run state (token materialization, clock start)."""

    def kv_budget_tokens(self) -> float:
        """Live-token budget for OOM admission (inf = substrate-managed)."""

    def free_slots(self) -> int:
        """Free decode slots (only consulted when prefill_needs_slots)."""

    def admit_blocks(self, requests: Sequence[Request]) -> int:
        """Reserve insert-time KV pages for a PREFIX of the batch; return
        how many requests got pages (all of them for non-paged backends).
        The loop re-queues the rest — the block analogue of the
        decode-slot clamp.  Prefix-cached backends also match each
        prompt against their radix index here, setting
        ``Request.prefix_hit_tokens`` (the loop feeds it to the
        monitor and the chunk plan skips the cached span)."""

    def decode_preempt(self, pool: Sequence[Request]) -> List[Request]:
        """Called before each decode iteration: grow every pooled
        request's pages to cover its next token write, preempting
        requests on pool exhaustion (youngest first, or most-slack
        first when the loop armed ``slack_of``; KV pages for victims
        are already freed).  The loop re-queues the returned victims
        via ``requeue=True``.  Non-paged backends return []."""

    def on_slice_yield(self, req: Request, keep: int) -> None:
        """A preempted request kept its first ``keep`` generated tokens
        (slice-boundary yield): drop backend generation state PAST them
        — the engine truncates its output list, the cost model's
        deterministic id stream is prefix-stable by construction."""

    def on_preempt_reset(self, req: Request) -> None:
        """A preempted request restarts from scratch: drop all of its
        generated state (the engine wipes its output list)."""

    def chunk_plan(self, batch: FormedBatch) -> List[Tuple[int, int]]:
        """Split a batch's padded prompt into (start, length) spans."""

    def prefill_chunk(self, job: PrefillJob, idx: int) -> float:
        """Execute chunk ``idx`` of ``job``; return its duration."""

    def transfer_seconds(self, batch: FormedBatch) -> float:
        """Prefill->decode KV transfer time for the whole batch."""

    def decode_iter(self, pool: Sequence[Request],
                    context_tokens: int) -> float:
        """One decode iteration over the pool (one token per request);
        return its duration.  ``context_tokens`` is the KV volume the
        loop's mode says this iteration reads (exact live tokens, or the
        padded batch context in ``static`` mode)."""

    def release(self, req: Request) -> None:
        """A pooled request finished: end-of-life for its slot/state.
        Retention-aware backends route this through
        ``KvRetention.on_release`` (register the transcript's full
        pages on the radix, pin the partial tail under the session key)
        instead of freeing unconditionally."""

    def generated_tokens(self, req: Request) -> "np.ndarray":
        """Token ids this backend generated for ``req`` so far (the
        engine's actual argmax outputs; the cost model's deterministic
        synthetic ids).  The loop composes the next session turn's
        prompt from them — each backend is self-consistent, which is
        all transcript reuse needs."""

    def maintain(self, now: float) -> None:
        """Periodic housekeeping at clock time ``now`` (the retention
        layer's session-TTL tick).  Called once per loop iteration."""


# -------------------------------------------------------------- results ---
@dataclasses.dataclass
class ServeResult:
    """Per-run outcome + executor accounting (works for both virtual and
    wall backends; ``makespan`` is in the backend clock's seconds)."""

    requests: List[Request]
    makespan: float
    busy_prefill: float
    busy_decode: float
    useful_flops: float
    padded_flops: float
    oom_events: int
    bucketing_overhead_s: float
    prefill_time_total: float = 0.0
    decode_time_total: float = 0.0
    transfer_time_total: float = 0.0
    interleaved_decode_steps: int = 0    # decode iters run mid-prefill-job
    peak_pool: int = 0                   # max concurrent decode requests
    preempt_events: int = 0              # paged-pool mid-decode evictions
    slice_yields: int = 0                # ... that preserved generated work
    # ---- prefix-cache accounting (core/prefix_cache.py) ----
    prefill_tokens_processed: int = 0    # padded prompt tokens actually run
    prefill_tokens_skipped: int = 0      # prompt tokens served from cache
    prefix_lookups: int = 0              # admitted requests matched
    prefix_hits: int = 0                 # ... with >= 1 cached page
    prefix_hit_tokens: int = 0
    prefix_pages_saved: int = 0
    prefix_evictions: int = 0
    shared_pages_peak: int = 0
    # ---- session retention accounting (core/retention.py) ----
    session_lookups: int = 0             # admitted requests with a session id
    session_hits: int = 0                # ... resumed from a live entry
    session_hit_tokens: int = 0          # transcript tokens restored
    sessions_retained: int = 0           # release-time entries created
    sessions_expired: int = 0            # TTL-tick unpins
    sessions_evicted: int = 0            # pressure unpins
    tail_pages_reused: int = 0           # pinned partial tails handed back
    # ---- host spill tier accounting (core/retention.py, PR 5) ----
    spilled_pages: int = 0               # device->host copies initiated
    restored_pages: int = 0              # host->device copies completed
    restored_tokens: int = 0             # KV tokens restored, not re-prefilled
    spill_drops: int = 0                 # spilled entries destroyed
    spill_hold_events: int = 0           # requests held on a restore
    spill_time_total: float = 0.0        # priced device->host transfer s
    restore_time_total: float = 0.0      # priced host->device transfer s
    spilled_bytes: int = 0               # COMPRESSED bytes moved dev->host
    restored_bytes: int = 0              # COMPRESSED bytes moved host->dev
    # ---- observability gauges (core/telemetry.py, PR 8) ----
    # time-weighted mean KV-pool occupancy over the run (paged: used
    # pages / pool pages; token-budget: live tokens / budget; slot
    # engine: occupied slots / slots)
    kv_util_time_weighted: float = 0.0
    # per dispatched prefill batch, in dispatch order: measured Eq.-(1)
    # padding waste and min/max-length homogeneity
    batch_padding_fractions: List[float] = dataclasses.field(
        default_factory=list)
    batch_homogeneity: List[float] = dataclasses.field(default_factory=list)
    # ---- fault/recovery plane (core/faults.py, core/recovery.py) ----
    fault_events: int = 0            # injected faults absorbed by the loop
    fault_retries: int = 0           # backoff retries (prefill/decode)
    fault_kills: int = 0             # decode pools killed after max retries
    quarantined: int = 0             # poisoned requests dropped (ledger-closed)
    restore_stalls: int = 0          # injected restore-channel stalls
    restore_retries: int = 0         # restore-channel retries (backoff)
    restore_failures: int = 0        # restore runs abandoned after retries
    restore_sheds: int = 0           # restores shed by the slack rule
    restore_timeouts: int = 0        # held requests unparked by the timeout
    corruptions: int = 0             # host-slot checksum mismatches caught

    def finished(self):
        return [r for r in self.requests if r.finished >= 0]

    # ---- tail-latency percentiles (PR 7) ----------------------------
    # Gates are on P99, not means: a mean hides the convoy-effect tail
    # that SLO attainment is actually about (DESIGN.md §6).  Nearest-
    # rank percentiles (ceil(q/100 * n)-th sorted sample) so hand-built
    # test series have exact expected values — no interpolation.

    def classes(self) -> List[str]:
        """Distinct request class tags present (sorted; '' excluded)."""
        return sorted({r.cls for r in self.requests if r.cls})

    def incomplete(self, cls: Optional[str] = None) -> int:
        """Requests that never produced a first token (dropped or still
        queued at time limit).  EXCLUDED from the TTFT series — an inf
        sample would poison every percentile above its rank — but
        reported here so a run can't quietly shed its tail."""
        return sum(1 for r in self.requests
                   if r.first_token < 0 and (cls is None or r.cls == cls))

    def ttft_series(self, cls: Optional[str] = None) -> List[float]:
        return [r.ttft() for r in self.requests
                if r.first_token >= 0 and (cls is None or r.cls == cls)]

    def tpot_series(self, cls: Optional[str] = None) -> List[float]:
        # needs >= 2 tokens for a per-token interval to exist
        return [r.tpot() for r in self.requests
                if r.finished >= 0 and r.generated > 1
                and (cls is None or r.cls == cls)]

    def percentile(self, q: float, metric: str = "ttft",
                   cls: Optional[str] = None) -> float:
        assert metric in ("ttft", "tpot"), metric
        xs = self.ttft_series(cls) if metric == "ttft" \
            else self.tpot_series(cls)
        if not xs:
            return float("nan")
        # the SAME nearest-rank rule GlobalMonitor snapshots use
        # (monitor._nearest_rank) — live and post-run percentile
        # definitions cannot diverge
        return _nearest_rank(xs, q)

    def p50(self, metric: str = "ttft", cls: Optional[str] = None) -> float:
        return self.percentile(50.0, metric, cls)

    def p95(self, metric: str = "ttft", cls: Optional[str] = None) -> float:
        return self.percentile(95.0, metric, cls)

    def p99(self, metric: str = "ttft", cls: Optional[str] = None) -> float:
        return self.percentile(99.0, metric, cls)

    def throughput_tok_s(self) -> float:
        toks = sum(r.generated + r.prompt_len for r in self.finished())
        return toks / max(self.makespan, 1e-9)

    def output_tok_s(self) -> float:
        return sum(r.generated for r in self.finished()) / max(self.makespan,
                                                               1e-9)

    def server_rps(self) -> float:
        return len(self.finished()) / max(self.makespan, 1e-9)

    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / max(self.prefix_lookups, 1)

    def session_hit_rate(self) -> float:
        return self.session_hits / max(self.session_lookups, 1)

    def slo_attainment(self, cls: Optional[str] = None) -> float:
        """Fraction of requests meeting BOTH SLO budgets (per-request
        budgets — under a heterogeneous mix each class carries its own).
        Optional ``cls`` filters to one class."""
        reqs = [r for r in self.requests if cls is None or r.cls == cls]
        if not reqs:
            return 0.0
        return sum(r.slo_met() for r in reqs) / len(reqs)

    def goodput(self, cls: Optional[str] = None) -> float:
        """Requests per second that FINISHED inside both SLO budgets —
        the deadline-aware throughput the goodput scheduler optimizes
        (DESIGN.md §8).  Unlike ``slo_attainment`` (a fraction) this is
        denominated in absolute work, so shedding load can never game
        it; unlike ``server_rps`` a late finish earns nothing."""
        n = sum(1 for r in self.requests
                if r.slo_met() and (cls is None or r.cls == cls))
        return n / max(self.makespan, 1e-9)

    def utilization(self, hw) -> float:
        """Model-FLOPs utilization over the busy window (the cost model's
        analogue of the paper's GPU-utilization metric)."""
        chips = hw.prefill_chips + hw.decode_chips
        return self.useful_flops / max(
            chips * hw.peak_flops * self.makespan, 1e-9)

    def padding_efficiency(self) -> float:
        return self.useful_flops / max(self.padded_flops, 1e-9)

    # ---- latency blame (core/telemetry.py ledgers, PR 8) -------------
    def padding_waste_ratio(self) -> float:
        """Mean measured per-batch padding fraction (Eq. 1's overhead,
        observed at dispatch rather than modeled)."""
        fr = self.batch_padding_fractions
        return sum(fr) / len(fr) if fr else 0.0

    def blame(self, cls: Optional[str] = None) -> Dict[str, float]:
        """Mean end-to-end phase breakdown (seconds per request) over
        retired requests — where a request's lifetime actually went."""
        return blame_means(
            [r.ledger.phases for r in self.requests
             if r.ledger is not None and r.ledger.closed
             and (cls is None or r.cls == cls)])

    def ttft_blame(self, cls: Optional[str] = None,
                   tail_q: Optional[float] = None) -> Dict[str, float]:
        """Mean phase breakdown of the time UP TO first token, over
        requests that produced one; ``tail_q`` restricts to the TTFT
        tail at/above that percentile (e.g. 99 -> the P99 convoy)."""
        reqs = [r for r in self.requests
                if r.first_token >= 0 and r.ledger is not None
                and r.ledger.ttft_phases is not None
                and (cls is None or r.cls == cls)]
        if tail_q is not None and reqs:
            thresh = self.percentile(tail_q, "ttft", cls)
            reqs = [r for r in reqs if r.ttft() >= thresh]
        return blame_means([r.ledger.ttft_phases for r in reqs])

    def ttft_wait_share(self, cls: Optional[str] = None,
                        tail_q: Optional[float] = None) -> float:
        """Fraction of (tail) TTFT spent WAITING (queue / clamp /
        requeue / restore hold) vs compute+transfer — the one number
        the burst-tail blame gate reads: static batching's P99 TTFT is
        queue-dominated, BucketServe's is not."""
        b = self.ttft_blame(cls, tail_q)
        tot = sum(b.values())
        if tot <= 0.0:
            return 0.0
        return sum(b.get(p, 0.0) for p in WAIT_PHASES) / tot

    def busy_utilization(self, n_executors: int = 2) -> float:
        """Fraction of executor-time busy — the closest analogue of the
        paper's 'average GPU utilization' (Fig. 5b)."""
        return min(1.0, (self.busy_prefill + self.busy_decode)
                   / max(n_executors * self.makespan, 1e-9))


@dataclasses.dataclass
class _LoopState:
    kv_budget: float
    ai: int = 0
    done: int = 0
    busy_p: float = 0.0
    busy_d: float = 0.0
    useful: float = 0.0
    padded: float = 0.0
    oom: int = 0
    t_pre: float = 0.0
    t_dec: float = 0.0
    t_xfer: float = 0.0
    interleaved: int = 0
    peak: int = 0
    preempts: int = 0
    slice_yields: int = 0
    prefill_tok: int = 0
    prefill_skip: int = 0
    # time-weighted KV occupancy integral (level x dt, advanced once
    # per loop iteration in _maintain) and per-batch waste gauges
    util_acc: float = 0.0
    util_t: float = 0.0
    pad_fracs: List[float] = dataclasses.field(default_factory=list)
    homog: List[float] = dataclasses.field(default_factory=list)
    # fault/recovery counters (core/faults.py)
    faults: int = 0
    retries: int = 0
    kills: int = 0
    quarantined: int = 0
    restore_timeouts: int = 0


# ---------------------------------------------------------------- config --
@dataclasses.dataclass(frozen=True)
class LoopConfig:
    mode: str = "disagg"              # disagg | coupled | static
    decode_slot_cap: int = 256
    restart_penalty: float = 0.5
    tick: float = 0.005
    # slice-boundary preemption (DESIGN.md §8, arXiv 2406.13511): a
    # preempted decode request keeps its generated tokens up to the
    # last multiple of ``slice_tokens`` — they are promoted into its
    # prompt, so the requeued request RE-PREFILLS the preserved work
    # (bounded, parallel) instead of re-decoding it (serial).  None
    # disables (legacy full-restart preemption).  Disagg mode only.
    slice_tokens: Optional[int] = None
    # restore-hold timeout (DESIGN.md §9, satellite of the fault plane
    # but active in EVERY run): a request parked on a host->device
    # restore for longer than this re-enters the queue COLD — a stalled
    # PCIe channel costs a re-prefill, never a hang.  <= 0 disables.
    restore_timeout: float = 30.0


# ------------------------------------------------------------------ loop --
class ServingLoop:
    """Drives a scheduler policy against an :class:`ExecutionBackend`."""

    def __init__(self, scheduler, backend: ExecutionBackend,
                 config: LoopConfig = LoopConfig(), recorder=None,
                 tracer=None, faults=None,
                 recovery: Optional[RecoveryPolicy] = None):
        assert config.mode in ("disagg", "coupled", "static"), config.mode
        # slice resume re-enters through chunked prefill + transfer/join;
        # the fused loops stamp first_token/generated unconditionally
        assert config.slice_tokens is None or config.mode == "disagg", \
            "slice-boundary preemption requires the disagg topology"
        # the decode-step/prefill-chunk injection sites live on the
        # overlapped executors; chaos runs use the disagg topology
        assert faults is None or config.mode == "disagg", \
            "fault injection requires the disagg topology"
        self.sched = scheduler
        self.backend = backend
        self.cfg = config
        # fault-injection / recovery plane (core/faults.py, DESIGN.md
        # §9).  The policy is ALWAYS armed (the restore-hold timeout
        # protects fault-free runs too); the injector defaults off.
        self._faults = faults
        self._recovery = recovery if recovery is not None \
            else DEFAULT_RECOVERY
        # optional TraceRecorder (data/trace.py): pristine request
        # snapshots after backend.begin + the run's dispatch/requeue/
        # turn event log (the replay bit-identity surface)
        self.recorder = recorder
        # optional event timeline (core/telemetry.py).  Call sites guard
        # on tracer.enabled before building any event argument — the
        # disabled default costs no allocations on the hot path.
        self.tracer = tracer if tracer is not None else NULL_TRACER

    # ------------------------------------------------------------- run ----
    def run(self, requests: List[Request], time_limit: float = 3600.0,
            max_wall_s: Optional[float] = None,
            drain_at: Optional[float] = None,
            resume_clock: Optional[float] = None) -> ServeResult:
        # Later session turns are HELD until their predecessor finishes
        # — only then can their prompt (prior transcript + utterance) be
        # composed and their arrival (finish + think gap) be known.  A
        # turn whose tokens are ALREADY composed needs no predecessor:
        # it was unlocked before a checkpointed drain (its predecessor
        # finished pre-drain and is absent here), so it re-enters as a
        # plain arrival with its recorded think-gap arrival time.
        self._held: Dict[Tuple[int, int], Request] = {
            (r.session_id, r.turn): r for r in requests
            if r.session_id is not None and r.turn > 0
            and r.tokens is None}
        self._arrivals = sorted(
            (r for r in requests
             if r.session_id is None or r.turn == 0
             or r.tokens is not None),
            key=lambda r: r.arrival)
        self._requests = requests                # drain() snapshots these
        self._n = len(requests)
        self._max_wall_s = max_wall_s
        self._drain_at = drain_at
        self._drained: Optional[LoopCheckpoint] = None
        self._drain_demoted = 0
        self.pool: List[Request] = []
        self.pending_join: List[list] = []       # [ready_time, request]
        # restore-in-flight requests, PARKED (not re-prefilled) until
        # their host->device copy lands: [ready, request, held_since]
        self._held_restore: List[list] = []
        self._spill_seen = (0, 0)                # (spilled, restored) fed
        self.job: Optional[PrefillJob] = None
        self._decode_fault_attempts = 0          # consecutive decode faults
        self._decode_faulted = False             # pool needs a re-stamp
        self.st = _LoopState(kv_budget=self.backend.kv_budget_tokens())
        self._last_util = -1.0                   # last emitted kv counter
        # fresh ledgers: phase stamping starts from a clean slate even
        # when a request object is reused across runs
        for r in requests:
            r.ledger = LatencyLedger()
        self.backend.begin(requests)
        rebase = 0.0
        if resume_clock is not None:
            if self.backend.clock.virtual:
                # cold resume from a LoopCheckpoint: continue at the
                # drain clock so resumed timings compose with pre-drain
                # anchors
                self.backend.clock.advance(resume_clock)
            else:
                # a wall clock cannot jump to the drain time: instead
                # rebase every checkpoint-frame stamp (anchors,
                # arrivals) into THIS clock's frame — deadlines and
                # think gaps are relative ages, so shifting both ends
                # preserves them exactly (AFTER begin: it restarts the
                # wall clock)
                rebase = self.backend.clock.now() - resume_clock
        for r in requests:
            if rebase:
                r.arrival += rebase
                if r.t0_anchor >= 0.0:
                    r.t0_anchor = r.t0_anchor + rebase
            # resumed requests carry their ORIGINAL first-arrival anchor
            # across the checkpoint boundary: deadlines survive a drain
            if r.t0_anchor >= 0.0:
                r.ledger.start(r.t0_anchor)
        # arm the fault/recovery seam on the retention layer AFTER begin
        # (backends rebuild retention there).  Recovery is armed only
        # with an injector — the fault-free restore path stays priced by
        # the channel model alone; the LOOP-level restore-hold timeout
        # (cfg.restore_timeout) protects every run regardless.
        if self._faults is not None:
            rt_f = getattr(self.backend, "retention", None)
            if rt_f is not None:
                rt_f.faults = self._faults
                rt_f.recovery = self._recovery
        # deadline-slack sacrifice wiring (DESIGN.md §8): when the
        # scheduler is slack-aware, every sacrifice point — decode
        # victim choice, retention eviction rungs, restore-hold release
        # — prefers the request/session with the MOST remaining slack.
        # AFTER begin: backends rebuild retention there.  The victim
        # key is the CLOCK-FREE class-budget proxy so both substrates
        # pick identical victims regardless of clock skew.
        self._slack_aware = bool(getattr(self.sched, "slack_aware", False))
        if self._slack_aware:
            self.backend.slack_of = Request.sacrifice_slack
            rt0 = getattr(self.backend, "retention", None)
            if rt0 is not None:
                rt0.slack_aware = True
        if self.tracer.enabled:
            # propagate the seam to the layers that emit their own
            # events; AFTER begin — backends rebuild retention there
            self.sched.tracer = self.tracer
            rt = getattr(self.backend, "retention", None)
            if rt is not None:
                rt.tracer = self.tracer
        if self.recorder is not None:
            # AFTER begin (prompt ids materialized), BEFORE the loop
            # mutates state (requeues overwrite arrivals, session turns
            # get composed prompts) — see data/trace.py contract
            self.recorder.on_begin(requests)
        if self.cfg.mode == "disagg":
            self._run_overlapped(time_limit)
        else:
            self._run_fused(time_limit, static=self.cfg.mode == "static")
        st = self.st
        self._note_util(self.backend.clock.now())   # close the integral
        overhead = getattr(getattr(self.sched, "buckets", None),
                           "overhead_s", 0.0)
        extra = {}
        pc = getattr(self.backend, "prefix_cache", None)
        if pc is not None:
            extra = dict(prefix_lookups=pc.stats.lookups,
                         prefix_hits=pc.stats.hits,
                         prefix_hit_tokens=pc.stats.hit_tokens,
                         prefix_pages_saved=pc.pages_saved(),
                         prefix_evictions=pc.stats.evictions,
                         shared_pages_peak=pc.stats.peak_shared)
        rt = getattr(self.backend, "retention", None)
        if rt is not None:
            extra.update(session_lookups=rt.stats.session_lookups,
                         session_hits=rt.stats.session_hits,
                         session_hit_tokens=rt.stats.session_hit_tokens,
                         sessions_retained=rt.stats.sessions_retained,
                         sessions_expired=rt.stats.sessions_expired,
                         sessions_evicted=rt.stats.sessions_evicted,
                         tail_pages_reused=rt.stats.tail_reuses,
                         spilled_pages=rt.stats.pages_spilled,
                         restored_pages=rt.stats.pages_restored,
                         restored_tokens=rt.stats.restored_tokens,
                         spill_drops=rt.stats.spill_drops,
                         spill_hold_events=rt.stats.restore_holds,
                         spill_time_total=rt.stats.spill_seconds,
                         restore_time_total=rt.stats.restore_seconds,
                         spilled_bytes=rt.stats.bytes_spilled,
                         restored_bytes=rt.stats.bytes_restored,
                         restore_stalls=rt.stats.restore_stalls,
                         restore_retries=rt.stats.restore_retries,
                         restore_failures=rt.stats.restore_failures,
                         restore_sheds=rt.stats.restore_sheds,
                         corruptions=rt.stats.corruptions)
        return ServeResult(
            requests=requests, makespan=self.backend.clock.now(),
            busy_prefill=st.busy_p, busy_decode=st.busy_d,
            useful_flops=st.useful, padded_flops=st.padded,
            oom_events=st.oom, bucketing_overhead_s=overhead,
            prefill_time_total=st.t_pre, decode_time_total=st.t_dec,
            transfer_time_total=st.t_xfer,
            interleaved_decode_steps=st.interleaved,
            peak_pool=st.peak, preempt_events=st.preempts,
            slice_yields=st.slice_yields,
            prefill_tokens_processed=st.prefill_tok,
            prefill_tokens_skipped=st.prefill_skip,
            kv_util_time_weighted=st.util_acc
            / max(self.backend.clock.now(), 1e-9),
            batch_padding_fractions=st.pad_fracs,
            batch_homogeneity=st.homog,
            fault_events=st.faults, fault_retries=st.retries,
            fault_kills=st.kills, quarantined=st.quarantined,
            restore_timeouts=st.restore_timeouts, **extra)

    # ------------------------------------------------- drain / resume -----
    def drain(self) -> LoopCheckpoint:
        """Checkpointed drain (DESIGN.md §9): quiesce every in-flight
        request WORK-PRESERVINGLY — pooled decodes yield at their last
        slice boundary, transfer-waits and mid-prefill rows fold back
        onto their preserved prompts, parked restores abandon their
        holds — demote live session tails to the host tier, and emit
        the serializable checkpoint a COLD loop ``resume``s from.
        Call after ``run(..., drain_at=t)`` returned."""
        now = self.backend.clock.now()
        evict = getattr(self.backend, "evict_request", None)
        for r in list(self.pool):
            self.pool.remove(r)
            self.sched.release_decode(r)
            if evict is not None:
                evict(r)
            self._yield_or_reset(r)
        for item in list(self.pending_join):
            r = item[1]
            self.sched.release_decode(r)   # admitted at prefill end
            if evict is not None:
                evict(r)
            self._yield_or_reset(r)
        self.pending_join.clear()
        if self.job is not None:
            abort = getattr(self.backend, "abort_prefill", None)
            for r in self.job.batch.requests:
                if abort is not None:
                    abort(r)
                self._yield_or_reset(r)
            self.job = None
        rt = getattr(self.backend, "retention", None)
        for item in list(self._held_restore):
            r = item[1]
            r.spill_wait = -1.0
            if rt is not None:
                rt.cancel_hold(r, timeout=False)
        self._held_restore.clear()
        self._drain_demoted = 0
        alloc = getattr(self.backend, "alloc", None)
        if rt is not None and alloc is not None:
            self._drain_demoted = rt.demote_all(alloc)
        ck = build_checkpoint(self, now)
        self._drained = ck
        if self.tracer.enabled:
            self.tracer.instant("loop", "drain", now, cat="drain",
                                args={"requests": len(ck.requests),
                                      "held_turns": len(ck.held_turns),
                                      "tails_demoted": ck.tails_demoted})
        return ck

    def resume(self, ck: LoopCheckpoint, time_limit: float = 3600.0,
               max_wall_s: Optional[float] = None) -> ServeResult:
        """Continue a drained run on THIS loop (typically a cold one in
        a new process): the checkpoint's requests re-enter in original
        arrival order carrying their deadline anchors, and the clock
        starts at the drain time so post-resume stamps compose with
        pre-drain anchors.  Preserved work re-prefills from each
        request's prompt — continuation token ids are bit-identical to
        the undrained run (the PR 9 slice-resume argument, applied
        across a process boundary)."""
        return self.run(ck.restore_requests(), time_limit=time_limit,
                        max_wall_s=max_wall_s, resume_clock=ck.now)

    # ------------------------------------------------------------ shared --
    def _wall_exceeded(self) -> bool:
        return (self._max_wall_s is not None
                and not self.backend.clock.virtual
                and self.backend.clock.wall_elapsed() > self._max_wall_s)

    def _after(self, start: float, duration: float) -> float:
        """Completion time of a backend call dispatched at ``start``: in
        virtual time the event is scheduled; in wall time it already
        happened — read the clock."""
        if self.backend.clock.virtual:
            return start + duration
        return self.backend.clock.now()

    def _admit_arrivals(self, now: float) -> None:
        # _arrivals can be SHORTER than _n (held session turns join it
        # only when their predecessor finishes) and can grow mid-run
        st = self.st
        while st.ai < len(self._arrivals) \
                and self._arrivals[st.ai].arrival <= now:
            r = self._arrivals[st.ai]
            t = r.arrival if self.backend.clock.virtual else now
            self.sched.on_arrival(r, t)
            if r.ledger is not None and not r.ledger.started:
                r.ledger.start(t)
            if self.tracer.enabled:
                self.tracer.async_begin(
                    "requests", f"req-{r.rid}", t, r.rid,
                    args={"cls": r.cls, "prompt_len": r.prompt_len})
            st.ai += 1

    def _process_joins(self, now: float) -> None:
        for item in list(self.pending_join):
            if item[0] <= now and len(self.pool) < self.cfg.decode_slot_cap:
                r = item[1]
                self.pool.append(r)
                self.pending_join.remove(item)
                if r.ledger is not None:
                    # the transfer phase absorbs any decode-slot wait
                    # past the modeled copy time (join is slot-gated)
                    r.ledger.to("decode", now)
        self.st.peak = max(self.st.peak, len(self.pool))

    @staticmethod
    def _live_tokens(pool: Sequence[Request]) -> int:
        return sum(r.prompt_len + r.generated for r in pool)

    def _requeue(self, r: Request, t: float, cause: str = "clamp",
                 at: Optional[float] = None) -> None:
        """THE re-queue funnel: every path that puts a request back in
        the arrival queue (OOM restart, slot/page clamp, preemption,
        restore-hold release) goes through here, so the recorder sees
        every re-arrival and stats are never double-counted.

        ``cause`` picks the ledger phase the coming wait is blamed on:
        "clamp" -> ``admission_block`` (bounced off a slot/page limit),
        "restore" -> back to plain ``queue`` (the hold itself was
        already accounted as ``restore_hold``), "oom"/"preempt"/"fault"
        -> the restart-penalty ``requeue_gap``, which begins at ``at``
        (the eviction instant), not at the post-penalty re-arrival
        ``t``."""
        led = r.ledger
        if led is not None and led.started and not led.closed:
            if cause == "clamp":
                led.to("admission_block", at if at is not None else t)
            elif cause == "restore":
                led.to("queue", at if at is not None else t)
            else:                                    # oom | preempt | fault
                led.gap(at if at is not None else t, r.arrival)
        self.sched.on_arrival(r, t, requeue=True)
        if self.recorder is not None:
            self.recorder.on_requeue(r, t)

    def _handle_oom(self, batch: FormedBatch, now: float) -> None:
        """Evict + re-queue; oversized singletons are dropped (unservable);
        the scheduler's retry backoff (notify_oom) shrinks its next cap.
        Re-queues use ``requeue=True`` so arrival stats are not
        double-counted."""
        if hasattr(self.sched, "notify_oom"):
            self.sched.notify_oom()
        for r in batch.requests:
            if r.prompt_len + r.max_new_tokens > self.st.kv_budget:
                r.dropped = True
                r.finished = -1.0
                self._retire(r, now)
                continue
            r.arrival = now + self.cfg.restart_penalty
            self._requeue(r, r.arrival, cause="oom", at=now)

    def _note_first(self, r: Request) -> None:
        """First token just stamped: feed the TTFT sample to the monitor
        so snapshots expose live tail percentiles."""
        mon = getattr(self.sched, "monitor", None)
        if mon is not None and hasattr(mon, "on_first_token"):
            mon.on_first_token(r.ttft(), r.cls)

    # ----------------------------------------------- sessions (retirement) --
    def _retire(self, r: Request, end: float) -> None:
        """A request left the system (finished or dropped): count it
        done and, if it was a session turn, unlock the next one."""
        self.st.done += 1
        led = r.ledger
        if led is not None and led.started and not led.closed:
            # close at the request's OWN finish stamp when it has one
            # (static mode retires the whole batch at the batch end);
            # drops close at the drop instant — they conserve too
            led.close(r.finished if r.finished >= 0 else end)
        if self.tracer.enabled:
            self.tracer.async_end(
                "requests", f"req-{r.rid}",
                r.finished if r.finished >= 0 else end, r.rid,
                args={"dropped": r.dropped})
        mon = getattr(self.sched, "monitor", None)
        if mon is not None:
            if r.finished >= 0 and r.generated > 1 \
                    and hasattr(mon, "on_tpot"):
                mon.on_tpot(r.tpot(), r.cls)
            if led is not None and led.closed and hasattr(mon, "on_retire"):
                mon.on_retire(r.cls, led.phases, slo_met=r.slo_met())
        self._unlock_next_turn(r, end)

    def _unlock_next_turn(self, r: Request, end: float) -> None:
        """Compose and release the successor turn of ``r``'s session:
        prompt = prior prompt + this turn's ACTUAL generated tokens +
        the successor's utterance, arriving after the think-time gap.
        Each backend supplies its own generated ids (the engine's real
        argmax outputs, the cost model's deterministic synthetics), so
        transcripts are self-consistent per substrate — which is what
        makes a resumed turn's prefill skip bit-exact.  A dropped turn
        cascades: its successors can never be composed."""
        if r.session_id is None:
            return
        nxt = self._held.pop((r.session_id, r.turn + 1), None)
        if nxt is None:
            return
        if r.dropped or r.finished < 0:
            while nxt is not None:
                nxt.dropped = True
                nxt.finished = -1.0
                led = nxt.ledger
                if led is not None and not led.closed:
                    # never admitted: open-and-shut at the cascade time
                    # so dropped turns still satisfy conservation
                    if not led.started:
                        led.start(end)
                    led.close(end)
                self.st.done += 1
                nxt = self._held.pop((r.session_id, nxt.turn + 1), None)
            return
        if r.tokens is not None and nxt.utterance is not None:
            gen = np.asarray(self.backend.generated_tokens(r),
                             dtype=np.int32)
            prompt = np.concatenate([
                np.asarray(r.tokens[:r.prompt_len], dtype=np.int32),
                gen, nxt.utterance])
            assert len(prompt) == nxt.prompt_len, \
                (len(prompt), nxt.prompt_len, r.rid, nxt.rid)
            nxt.tokens = prompt
            nxt.history_tokens = r.prompt_len + len(gen)
        nxt.arrival = end + max(nxt.think_gap, 0.0)
        if self.recorder is not None:
            self.recorder.on_turn(nxt, nxt.arrival)
        bisect.insort(self._arrivals, nxt, lo=self.st.ai,
                      key=lambda q: q.arrival)

    def _maintain(self, now: float) -> None:
        """Backend housekeeping (session-TTL tick + spill/restore
        completion polling) once per iteration; forwards spill traffic
        deltas to the monitor."""
        self._note_util(now)
        m = getattr(self.backend, "maintain", None)
        if m is not None:
            if self._faults is not None \
                    and self._faults.fire("maintain_tick"):
                # maintain-tick clock hiccup: this housekeeping tick is
                # lost.  TTL expiry and spill/restore completion polling
                # are deadline-idempotent, so a skipped tick only delays
                # them to the next iteration — which is the invariant
                # the chaos suite pins down.
                self.st.faults += 1
            else:
                m(now)
        rt = getattr(self.backend, "retention", None)
        mon = getattr(self.sched, "monitor", None)
        if rt is not None and mon is not None:
            sp, re = rt.stats.pages_spilled, rt.stats.pages_restored
            if (sp, re) != self._spill_seen:
                mon.on_spill_traffic(sp - self._spill_seen[0],
                                     re - self._spill_seen[1])
                self._spill_seen = (sp, re)
            # restore-aware admission pricing: expose the in-flight
            # restore LEVEL so Eq. (6) leaves headroom for reserved
            # pages and the compressed channel backlog
            if hasattr(mon, "on_restore_state"):
                mon.on_restore_state(rt.restore_pages_in_flight(),
                                     rt.restore_backlog_bytes())

    def _kv_level(self) -> float:
        """Instantaneous KV-pool occupancy in [0, 1]: used pages for
        paged backends, occupied slots for the slot engine, live tokens
        against the Eq. (6) budget otherwise."""
        alloc = getattr(self.backend, "alloc", None)
        if alloc is not None:
            n = getattr(alloc, "n_pages", 0)
            if n:
                return 1.0 - alloc.free_pages() / n
        if self.backend.prefill_needs_slots:
            cap = max(self.cfg.decode_slot_cap, 1)
            return min(1.0, max(0.0,
                                1.0 - self.backend.free_slots() / cap))
        if math.isfinite(self.st.kv_budget) and self.st.kv_budget > 0:
            return min(1.0,
                       self._live_tokens(self.pool) / self.st.kv_budget)
        return 0.0

    def _note_util(self, now: float) -> None:
        """Advance the time-weighted pool-occupancy integral to ``now``
        (sampled once per loop iteration — level changes only at events,
        which always run through an iteration boundary)."""
        st = self.st
        if now <= st.util_t:
            return
        level = self._kv_level()
        st.util_acc += level * (now - st.util_t)
        st.util_t = now
        if self.tracer.enabled and abs(level - self._last_util) > 1e-9:
            self.tracer.counter("kv", "kv_util", now, {"util": level})
            self._last_util = level

    def _release_held(self, now: float) -> None:
        """Re-queue parked requests whose restore landed — their next
        admission finds the restored pages LIVE and resumes past them.
        Under a slack-aware scheduler the batch of due releases re-enters
        tightest-budget first, so a same-tick admission race between two
        resumed requests is settled in deadline order."""
        timeout = self.cfg.restore_timeout
        due, timed_out = [], []
        for item in self._held_restore:
            if item[0] <= now:
                due.append(item)
            elif timeout > 0 and now >= item[2] + timeout:
                timed_out.append(item)
        for item in timed_out:
            # restore-hold timeout (DESIGN.md §9): the channel never
            # delivered — abandon the claimed restore and re-enter COLD.
            # A stalled PCIe link costs a re-prefill, never a hang.
            self._held_restore.remove(item)
            r = item[1]
            r.spill_wait = -1.0
            rt = getattr(self.backend, "retention", None)
            if rt is not None:
                rt.cancel_hold(r)
            self.st.restore_timeouts += 1
            self._requeue(r, now, cause="restore")
        if not due:
            return
        if getattr(self, "_slack_aware", False):
            due.sort(key=lambda it: (it[1].sacrifice_slack(), it[1].rid))
        for item in due:
            self._held_restore.remove(item)
            r = item[1]
            r.spill_wait = -1.0
            # arrival stays untouched: the hold is queueing delay,
            # so the restore latency lands on this request's TTFT
            self._requeue(r, now, cause="restore")

    def _form_batch(self, now: float, *,
                    count_pending: bool) -> Tuple[Optional[FormedBatch], bool]:
        """One scheduler tick -> (batch, oomed).  Applies the backend KV
        budget (virtual substrates) and the decode-slot clamp (real
        substrates, excess re-queued without stat double-counting)."""
        st = self.st
        if self.backend.prefill_needs_slots and self.backend.free_slots() <= 0:
            return None, False
        batch = self.sched.next_prefill_batch(now)
        if batch is None:
            return None, False
        if self.backend.prefill_needs_slots:
            free = self.backend.free_slots()
            if batch.size > free:                    # slot-capacity clamp
                for r in batch.requests[free:]:
                    self._requeue(r, now)
                batch = FormedBatch(batch.requests[:free], batch.pad_to,
                                    bucket=batch.bucket)
        if math.isfinite(st.kv_budget):
            batch_tokens = sum(r.prompt_len + r.max_new_tokens
                               for r in batch.requests)
            pending_tokens = sum(it[1].prompt_len + it[1].max_new_tokens
                                 for it in self.pending_join) \
                if count_pending else 0
            if (self._live_tokens(self.pool) + pending_tokens
                    + batch_tokens > st.kv_budget):
                st.oom += 1
                self._handle_oom(batch, now)
                return None, True
        n_blk = self.backend.admit_blocks(batch.requests)
        if n_blk < batch.size:                       # KV-page clamp (paged)
            for r in batch.requests[n_blk:]:
                if r.spill_wait >= 0.0:
                    # hit continues into spilled pages: PARK until the
                    # host->device restore lands — re-prefilling now
                    # would throw away restorable KV
                    if r.ledger is not None:
                        r.ledger.to("restore_hold", now)
                    self._held_restore.append([r.spill_wait, r, now])
                else:
                    self._requeue(r, now)
            if n_blk == 0:
                return None, False
            batch = FormedBatch(batch.requests[:n_blk], batch.pad_to,
                                bucket=batch.bucket)
        if hasattr(self.sched, "notify_dispatch"):
            self.sched.notify_dispatch()             # OOM-backoff recovery
        pc = getattr(self.backend, "prefix_cache", None)
        mon = getattr(self.sched, "monitor", None)
        if pc is not None and mon is not None:
            for r in batch.requests:
                mon.on_prefix_lookup(r.prefix_hit_tokens, pc.page_size)
                if r.session_hit_tokens:
                    mon.on_session_hit(r.session_hit_tokens)
        st.pad_fracs.append(batch.padding_fraction)
        st.homog.append(batch.homogeneity)
        for r in batch.requests:
            if r.ledger is not None:
                r.ledger.to("formed", now)
        if self.recorder is not None:
            self.recorder.on_dispatch("prefill", batch.requests, now)
        return batch, False

    def _account_prefill_batch(self, batch: FormedBatch,
                               skip: int = 0) -> None:
        """``skip`` prompt positions per row were served from the prefix
        cache — neither useful nor padded FLOPs were spent on them."""
        fpt = self.backend.flops_per_token
        if skip:
            self.st.useful += fpt * sum(max(r.prompt_len - skip, 0)
                                        for r in batch.requests)
            self.st.padded += fpt * max(batch.pad_to - skip, 0) * batch.size
        else:
            self.st.useful += fpt * batch.total_tokens
            self.st.padded += fpt * batch.padded_tokens

    def _preempt_for_decode(self, now: float) -> bool:
        """Paged backends may need to evict pooled requests to free KV
        pages for the survivors' next token (DESIGN.md §3; victim order
        is youngest-first, or most-slack-first under a slack-aware
        scheduler).  The backend tears down its own state and returns
        the victims; scheduling state is reset here and they re-enter
        the queue via the requeue path (restart penalty, no stat
        double-count).

        With ``slice_tokens = K`` set (DESIGN.md §8, arXiv 2406.13511),
        a victim yields at the last K-aligned SLICE BOUNDARY instead of
        restarting: generated tokens up to the boundary are promoted
        into its prompt (``Request.sliced_tokens`` tracks the split),
        so the requeued request re-PREFILLS the preserved work at
        identical absolute positions — RoPE and causal attention see
        the same stream, making the continuation bit-identical — and
        resumes decoding where it left off.  Only the unaligned tail
        past the boundary is recomputed.  Session turns never slice:
        the next turn's prompt composition assumes an unsliced
        transcript shape (``_unlock_next_turn``)."""
        victims = self.backend.decode_preempt(self.pool)
        for r in victims:
            self.pool.remove(r)
            self.sched.release_decode(r)
            sliced = self._yield_or_reset(r)
            r.arrival = now + self.cfg.restart_penalty
            self._requeue(r, r.arrival, cause="preempt", at=now)
            self.st.preempts += 1
            if self.tracer.enabled:
                self.tracer.instant(
                    "decode", "slice-yield" if sliced else "preempt", now,
                    cat="preempt",
                    args={"rid": r.rid,
                          "kept_tokens": r.sliced_tokens if sliced else 0})
        return bool(victims)

    def _yield_or_reset(self, r: Request) -> bool:
        """Work-preservation core shared by preemption, the decode-pool
        fault kill, and checkpointed drain: yield ``r`` at its last
        K-aligned slice boundary — generated tokens up to the boundary
        are promoted into the prompt (``Request.sliced_tokens`` tracks
        the split), so the re-queued request re-PREFILLS the preserved
        work at identical absolute positions and the continuation stays
        bit-identical — or reset it to scratch when slicing is off, no
        boundary is reached, or it is a session turn (the next turn's
        prompt composition assumes an unsliced transcript shape).
        Returns True when work was preserved.  The CALLER owns queue
        and backend slot/page disposition."""
        K = self.cfg.slice_tokens
        keep = (r.generated // K) * K if K else 0
        sliced = keep > 0 and r.session_id is None
        if sliced:
            # promote the newly preserved span into the prompt;
            # everything up to r.sliced_tokens was promoted by an
            # earlier yield and already sits inside tokens[:prompt_len]
            if r.tokens is not None:
                gen = np.asarray(self.backend.generated_tokens(r),
                                 dtype=np.int32)
                r.tokens = np.concatenate([
                    np.asarray(r.tokens[:r.prompt_len], dtype=np.int32),
                    gen[r.sliced_tokens:keep]])
            r.prompt_len += keep - r.sliced_tokens
            r.sliced_tokens = keep
            r.generated = keep
            # first_token survives: the tokens that defined it are
            # preserved, so TTFT stands and the preemption delay
            # lands on TPOT — exactly what slack accounting wants
            hook = getattr(self.backend, "on_slice_yield", None)
            if hook is not None:
                hook(r, keep)
            self.st.slice_yields += 1
        else:
            reset = getattr(self.backend, "on_preempt_reset", None)
            if reset is not None:
                reset(r)
            r.generated = 0
            r.first_token = -1.0
        r.prefill_start = -1.0
        r.prefix_hit_tokens = 0       # re-matched at the next admission
        r.session_hit_tokens = 0
        return sliced

    def _abandon_job(self, job: PrefillJob, now: float) -> None:
        """Retry budget exhausted on a prefill job: free its partial
        backend state (``abort_prefill`` — NOT ``release``, which would
        register garbage partial KV with the retention layer) and
        disposition the rows.  Poisoned rows (``fault_streak`` at the
        quarantine threshold) are dropped terminally with their ledgers
        closed — a single unservable request can never kill the loop —
        the rest re-enter the queue cold after the restart penalty.
        Work already promoted into a row's prompt by earlier slice
        yields survives: only the un-prefilled remainder is redone."""
        abort = getattr(self.backend, "abort_prefill", None)
        for r in job.batch.requests:
            if abort is not None:
                abort(r)
            r.prefill_start = -1.0
            r.prefix_hit_tokens = 0
            r.session_hit_tokens = 0
            if r.fault_streak >= self._recovery.quarantine_after:
                r.dropped = True
                r.quarantined = True
                r.finished = -1.0
                self.st.quarantined += 1
                if self.tracer.enabled:
                    self.tracer.instant(
                        "prefill", "quarantine", now, cat="fault",
                        args={"rid": r.rid, "streak": r.fault_streak})
                self._retire(r, now)
            else:
                r.arrival = now + self.cfg.restart_penalty
                self._requeue(r, r.arrival, cause="fault", at=now)
        if self.tracer.enabled:
            self.tracer.instant(
                "prefill", "job-abandoned", now, cat="fault",
                args={"rows": job.batch.size,
                      "attempts": job.fault_attempts})
        self.job = None

    def _kill_decode_pool(self, now: float) -> None:
        """Decode executor declared dead for this pool (consecutive
        fault budget exhausted): WORK-PRESERVING kill.  Every pooled
        request yields at its last slice boundary (or resets), its
        backend slot/pages are torn down via ``evict_request``, and it
        re-enters the queue — the loop outlives the device error."""
        st = self.st
        st.kills += 1
        n = len(self.pool)
        evict = getattr(self.backend, "evict_request", None)
        for r in list(self.pool):
            self.pool.remove(r)
            self.sched.release_decode(r)
            if evict is not None:
                evict(r)
            self._yield_or_reset(r)
            r.arrival = now + self.cfg.restart_penalty
            self._requeue(r, r.arrival, cause="fault", at=now)
        self._decode_faulted = False
        self._decode_fault_attempts = 0
        if self.tracer.enabled:
            self.tracer.instant("decode", "pool-kill", now, cat="fault",
                                args={"victims": n})

    def _advance_pool(self, end: float) -> None:
        """One token for every pooled request; retire finished ones."""
        for r in list(self.pool):
            r.generated += 1
            if r.generated >= r.max_new_tokens:
                r.finished = end
                self.pool.remove(r)
                self.backend.release(r)
                self.sched.release_decode(r)
                self._retire(r, end)

    @staticmethod
    def _bucket_track(batch: FormedBatch) -> str:
        """Timeline track a batch's spans land on: its bucket's length
        band, or the bare executor for bucketless policies."""
        b = batch.bucket
        return f"bucket[{b.low},{b.up})" if b is not None else "prefill"

    def _next_arrival(self) -> Optional[float]:
        if self.st.ai < len(self._arrivals):
            return self._arrivals[self.st.ai].arrival
        return None

    def _held_wakeups(self) -> List[float]:
        """Clock targets for parked restores: the copy's ready time or
        the hold timeout, whichever comes first — the idle advance must
        never jump past the timeout to a stalled channel's far-future
        ready stamp."""
        to = self.cfg.restore_timeout
        return [min(it[0], it[2] + to) if to > 0 else it[0]
                for it in self._held_restore]

    # -------------------------------------------- disagg (overlapped) -----
    def _run_overlapped(self, time_limit: float) -> None:
        """Separate prefill/decode executors (+ KV transfer between).  On
        a wall clock the two 'executors' are the same synchronous device
        stream — chunked prefill is what lets decode interleave."""
        clock, st, sched = self.backend.clock, self.st, self.sched
        prefill_free = decode_free = 0.0

        while st.done < self._n and clock.now() < time_limit:
            if self._wall_exceeded():
                break
            now = clock.now()
            if self._drain_at is not None and now >= self._drain_at:
                break                      # caller drains to a checkpoint
            self._maintain(now)
            self._release_held(now)
            self._admit_arrivals(now)
            self._process_joins(now)

            progressed = False
            # ---------------------------------------- prefill executor ----
            if prefill_free <= now:
                if self.job is None and sched.queued():
                    batch, oomed = self._form_batch(now, count_pending=True)
                    if oomed:
                        prefill_free = now + self.cfg.restart_penalty
                    elif batch is not None:
                        self.job = PrefillJob(
                            batch, self.backend.chunk_plan(batch))
                if self.job is not None:
                    end = self._run_chunk(self.job, now)
                    prefill_free = end
                    progressed = True
            # ----------------------------------------- decode executor ----
            if decode_free <= now and self.pool:
                if self._preempt_for_decode(now):
                    progressed = True
                if self.pool:
                    decode_free = self._run_decode_iter(now)
                    progressed = True

            if not progressed:
                cands = [c for c in
                         [prefill_free if sched.queued() or self.job
                          else None,
                          decode_free if self.pool else None,
                          self._next_arrival()]
                         + [it[0] for it in self.pending_join]
                         + self._held_wakeups()
                         if c is not None and c > now]
                if cands:
                    clock.advance(min(cands))
                elif clock.virtual:
                    clock.advance(now + self.cfg.tick)
                elif (not sched.queued() and not self.pool
                      and not self.pending_join and not self._held_restore
                      and self.job is None
                      and self._next_arrival() is None):
                    break                      # drained: nothing can progress
                else:
                    clock.advance(now + self.cfg.tick)

    def _run_chunk(self, job: PrefillJob, now: float) -> float:
        """Execute the job's next prefill chunk; on the last chunk stamp
        first-token times and hand requests to transfer/decode.

        Fault seam (DESIGN.md §9): an injected ``prefill_chunk`` fault
        costs a backoff'd retry of the SAME chunk; past the retry budget
        the whole job is abandoned (``_abandon_job``) — poisoned rows
        quarantined, the rest re-queued cold."""
        st, sched, batch = self.st, self.sched, job.batch
        if self._faults is not None and self._faults.fire("prefill_chunk"):
            st.faults += 1
            job.fault_attempts += 1
            job.faulted = True
            for r in batch.requests:
                r.fault_streak += 1
                if r.ledger is not None and not r.ledger.closed:
                    r.ledger.to("fault_retry", now)
            if self.tracer.enabled:
                self.tracer.instant(
                    "prefill", "chunk-fault", now, cat="fault",
                    args={"attempt": job.fault_attempts,
                          "rows": batch.size})
            if job.fault_attempts > self._recovery.max_retries:
                self._abandon_job(job, now)
                return now
            st.retries += 1
            return now + self._recovery.backoff(job.fault_attempts - 1)
        stamp = job.started_at < 0 or job.faulted
        if job.started_at < 0:
            job.started_at = now
            for r in batch.requests:
                r.prefill_start = now
        if stamp:
            for r in batch.requests:
                if job.faulted:
                    r.fault_streak = 0       # survived: streak broken
                if r.ledger is not None and not r.ledger.closed:
                    r.ledger.to("prefill", now)
            job.faulted = False
        idx = job.next_chunk
        dur = self.backend.prefill_chunk(job, idx)
        job.next_chunk += 1
        end = self._after(now, dur)
        dur = dur if self.backend.clock.virtual else end - now
        st.busy_p += dur
        st.t_pre += dur * batch.size
        st.prefill_tok += job.chunks[idx][1] * batch.size
        for r in batch.requests:
            r.prefilled_tokens += job.chunks[idx][1]
        if self.tracer.enabled:
            self.tracer.complete(
                self._bucket_track(batch), f"chunk {idx}", now, dur,
                cat="prefill", args={"rows": batch.size,
                                     "tokens": job.chunks[idx][1]})

        if job.done:
            # a chunk plan starting past 0 skipped a cached prefix: those
            # positions were never run through the prefill executor
            skip = job.chunks[0][0]
            st.prefill_skip += skip * batch.size
            self._account_prefill_batch(batch, skip=skip)
            xfer = self.backend.transfer_seconds(batch)
            if self.tracer.enabled:
                self.tracer.complete(
                    self._bucket_track(batch), f"batch x{batch.size}",
                    job.started_at, end - job.started_at, cat="batch",
                    args={"size": batch.size, "pad_to": batch.pad_to,
                          "padding_fraction": batch.padding_fraction,
                          "homogeneity": batch.homogeneity})
            for r in batch.requests:
                # prefill's last position predicts one token: for a
                # fresh request that's the FIRST token (0 -> 1); for a
                # slice-yield resume (generated == sliced_tokens > 0)
                # it's the next token after the preserved span —
                # first_token was stamped on the original pass and
                # stands, so the preemption delay shows up in TPOT
                r.generated += 1
                if r.first_token < 0:
                    r.first_token = end
                    if r.ledger is not None:
                        r.ledger.mark_first(end)
                    self._note_first(r)
                if r.generated >= r.max_new_tokens \
                        or not self.backend.supports_decode:
                    r.finished = end
                    self.backend.release(r)     # retention/free of KV pages
                    self._retire(r, end)
                else:
                    # KV allocated AT PREFILL: account it now so the
                    # batcher's Eq. (6) sees in-transfer caches too
                    # (prevents admission overshoot).
                    sched.admit_decode(r)
                    if r.ledger is not None:
                        r.ledger.to("transfer", end)
                    self.pending_join.append([end + xfer, r])
            st.t_xfer += xfer * batch.size
            self.job = None
            # zero-latency transfers (real engine) join before the next
            # decode dispatch — the substrate already holds their slots
            self._process_joins(self.backend.clock.now())
        return end

    def _run_decode_iter(self, now: float) -> float:
        st = self.st
        if self._faults is not None and self._faults.fire("decode_step"):
            # transient decode-step device error: the whole iteration is
            # lost; pooled ledgers park in fault_retry until a step
            # lands.  Past the consecutive-retry budget the pool is
            # killed work-preservingly instead of spinning forever.
            st.faults += 1
            self._decode_fault_attempts += 1
            self._decode_faulted = True
            # wall clocks advance DURING a loop iteration (a prefill
            # finishing first stamps joiners with fresh samples): clamp
            # so fault stamps never run backwards on a joiner's ledger
            now = max(now, self.backend.clock.now())
            for r in self.pool:
                if r.ledger is not None and not r.ledger.closed:
                    r.ledger.to("fault_retry", now)
            if self.tracer.enabled:
                self.tracer.instant(
                    "decode", "decode-fault", now, cat="fault",
                    args={"attempt": self._decode_fault_attempts,
                          "pool": len(self.pool)})
            if self._decode_fault_attempts > self._recovery.max_retries:
                self._kill_decode_pool(now)
                return now
            st.retries += 1
            return now + self._recovery.backoff(
                self._decode_fault_attempts - 1)
        if self._decode_faulted:
            # a step landed: streak broken, ledgers resume decode
            self._decode_faulted = False
            self._decode_fault_attempts = 0
            ts = max(now, self.backend.clock.now())   # see fault clamp
            for r in self.pool:
                if r.ledger is not None and not r.ledger.closed:
                    r.ledger.to("decode", ts)
        n = len(self.pool)
        dur = self.backend.decode_iter(self.pool, self._live_tokens(self.pool))
        end = self._after(now, dur)
        dur = dur if self.backend.clock.virtual else end - now
        st.busy_d += dur
        st.t_dec += dur * n
        fpt = self.backend.flops_per_token
        st.useful += fpt * n
        st.padded += fpt * n
        if self.job is not None:
            st.interleaved += 1       # decode ran between prefill chunks
        if self.tracer.enabled:
            self.tracer.complete("decode", "decode-iter", now, dur,
                                 cat="decode", args={"pool": n})
            self.tracer.counter("decode", "pool", now, {"requests": n})
        self._advance_pool(end)
        return end

    # --------------------------------------- coupled / static (fused) -----
    def _run_fused(self, time_limit: float, static: bool) -> None:
        """Single executor.  ``coupled``: each iteration fuses the new
        prefill batch (if any) with one decode step over the live pool
        (Orca).  ``static``: a formed batch runs prefill + decode TO
        COMPLETION with padded context reads (convoy effect)."""
        clock, st, sched = self.backend.clock, self.st, self.sched
        cooldown = 0.0

        while st.done < self._n and clock.now() < time_limit:
            if self._wall_exceeded():
                break
            now = clock.now()
            if self._drain_at is not None and now >= self._drain_at:
                break                      # caller drains to a checkpoint
            self._maintain(now)
            self._release_held(now)
            self._admit_arrivals(now)

            batch = None
            can_admit = ((not static) or not self.pool) and now >= cooldown
            if sched.queued() and can_admit and \
                    len(self.pool) < self.cfg.decode_slot_cap:
                batch, oomed = self._form_batch(now, count_pending=False)
                if oomed:
                    cooldown = now + self.cfg.restart_penalty

            if static:
                if batch is not None:
                    self._run_batch_to_completion(batch, now)
                else:
                    cands = [c for c in [self._next_arrival()]
                             + self._held_wakeups()
                             if c is not None and c > now]
                    if sched.queued():
                        cands.append(now + self.cfg.tick)
                    clock.advance(min(cands) if cands else now
                                  + self.cfg.tick)
                continue

            if batch is None and not self.pool:
                cands = [c for c in [self._next_arrival()]
                         + self._held_wakeups()
                         if c is not None and c > now]
                clock.advance(min(cands) if cands else now + self.cfg.tick)
                continue

            # one fused iteration: prefill the new batch + one decode step
            dt = 0.0
            if batch is not None:
                job = PrefillJob(batch, [(0, batch.pad_to)])
                pdt = self.backend.prefill_chunk(job, 0)
                job.next_chunk = 1
                dt += pdt
            if self.pool:
                self._preempt_for_decode(now)
            n_pool = len(self.pool)
            if n_pool:
                ddt = self.backend.decode_iter(
                    self.pool, self._live_tokens(self.pool))
                dt += ddt
            end = now + dt if clock.virtual else clock.now()
            if batch is not None:
                if self.tracer.enabled:
                    self.tracer.complete(
                        self._bucket_track(batch), f"batch x{batch.size}",
                        now, end - now, cat="batch",
                        args={"size": batch.size, "pad_to": batch.pad_to,
                              "padding_fraction": batch.padding_fraction,
                              "homogeneity": batch.homogeneity})
                for r in batch.requests:
                    r.prefill_start = now
                    if r.ledger is not None:
                        r.ledger.to("prefill", now)
                    r.first_token = end          # interference: full iter
                    r.generated = 1
                    if r.ledger is not None:
                        r.ledger.mark_first(end)
                    self._note_first(r)
                st.busy_p += pdt
                st.t_pre += pdt * batch.size
                st.prefill_tok += batch.pad_to * batch.size
                for r in batch.requests:
                    r.prefilled_tokens += batch.pad_to
                self._account_prefill_batch(batch)
            if n_pool:
                st.busy_d += ddt
                st.t_dec += ddt * n_pool
                fpt = self.backend.flops_per_token
                st.useful += fpt * n_pool
                st.padded += fpt * n_pool
                self._advance_pool(end)
            if batch is not None:
                for r in batch.requests:
                    if r.generated >= r.max_new_tokens \
                            or not self.backend.supports_decode:
                        r.finished = end
                        self.backend.release(r)
                        self._retire(r, end)
                    else:
                        self.pool.append(r)
                        if r.ledger is not None:
                            r.ledger.to("decode", end)
                        sched.admit_decode(r)
                st.peak = max(st.peak, len(self.pool))
            clock.advance(end)

    def _run_batch_to_completion(self, batch: FormedBatch,
                                 now: float) -> None:
        """Static/batch-granularity execution with padded decode reads:
        every iteration reads the PADDED batch context (all slots padded
        to the batch max) and the executor is held until the longest
        member finishes."""
        st, sched, clock = self.st, self.sched, self.backend.clock
        n, pad = batch.size, batch.pad_to
        fpt = self.backend.flops_per_token
        job = PrefillJob(batch, [(0, pad)])
        pdt = self.backend.prefill_chunk(job, 0)
        job.next_chunk = 1
        st.busy_p += pdt
        st.t_pre += pdt * n
        st.prefill_tok += pad * n
        for r in batch.requests:
            r.prefilled_tokens += pad
        self._account_prefill_batch(batch)
        t = self._after(now, pdt)
        for r in batch.requests:
            r.prefill_start = now
            if r.ledger is not None:
                r.ledger.to("prefill", now)
            r.first_token = t
            r.generated = 1
            if r.ledger is not None:
                r.ledger.mark_first(t)
                r.ledger.to("decode", t)
            self._note_first(r)
            sched.admit_decode(r)
        iters = max(r.max_new_tokens for r in batch.requests) - 1
        for i in range(1, iters + 1):
            context = n * (pad + i)              # PADDED batch KV read
            ddt = self.backend.decode_iter(batch.requests, context)
            t = self._after(t, ddt)
            st.busy_d += ddt
            st.t_dec += ddt * n
            st.useful += fpt * sum(
                1 for r in batch.requests if r.generated < r.max_new_tokens)
            st.padded += fpt * n
            for r in batch.requests:
                if r.generated < r.max_new_tokens:
                    r.generated += 1
                    if r.generated >= r.max_new_tokens:
                        r.finished = t
        for r in batch.requests:
            if r.finished < 0:
                r.finished = t
            sched.release_decode(r)
            self.backend.release(r)
            self._retire(r, t)
        if self.tracer.enabled:
            # one span per batch covering the FULL executor hold —
            # static mode's convoy effect, visible on the timeline
            self.tracer.complete(
                self._bucket_track(batch), f"batch x{n}", now, t - now,
                cat="batch",
                args={"size": n, "pad_to": pad,
                      "padding_fraction": batch.padding_fraction,
                      "homogeneity": batch.homogeneity})
        clock.advance(t)
