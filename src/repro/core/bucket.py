"""Adaptive bucketing — faithful implementation of paper Algorithm 1.

* System starts with one bucket [0, L_max).
* Requests are assigned to the bucket whose [low, up) contains S.
* ``adjust(n_max)``:
    - if total queued < n_max: merge everything back into one bucket
      (low-load fast path, lines 11-13);
    - else one split round: every bucket with more than ``min_split``
      (= n_max in the paper) requests of which a fraction > θ lies below
      the interval midpoint is bisected (lines 14-29).
  Midpoint bisection approximates the Eq.-(4) optimal boundary; repeated
  rounds (one per scheduling tick) converge as the workload demands.

Beyond-paper extensions (flagged, off by default for the faithful path):
  * ``assignment="bisect"`` — O(log k) bucket lookup on sorted bounds
    (the paper's own "binary tree" suggestion, §IV).
  * ``refine="eq4"`` — instead of the midpoint, split at the empirical
    conditional expectation (Eq. 4) of the bucket's requests.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Callable, List, Optional

from .request import Request, TaskType


@dataclasses.dataclass
class Bucket:
    low: int
    up: int
    requests: List[Request] = dataclasses.field(default_factory=list)
    # cached min over ONLINE members' arrivals (None = no online member).
    # The scheduler's bucket pick reads this every tick — maintained
    # incrementally (O(1) on add, recomputed only when a bucket loses
    # members) instead of rescanned over every request in every bucket.
    _online_min: Optional[float] = dataclasses.field(default=None,
                                                     repr=False)

    def __contains__(self, s: int) -> bool:
        return self.low <= s < self.up

    @property
    def midpoint(self) -> float:
        return (self.low + self.up) / 2

    def __len__(self) -> int:
        return len(self.requests)

    # ----------------------------------- earliest-online maintenance --
    def append(self, r: Request) -> None:
        """The ONE way a request enters a bucket: keeps the cached
        earliest-online arrival exact in O(1)."""
        self.requests.append(r)
        if r.task_type == TaskType.ONLINE and (
                self._online_min is None or r.arrival < self._online_min):
            self._online_min = r.arrival

    def refresh_online(self) -> None:
        """Recompute the cache after members were REMOVED (the dropped
        one may have been the min) — O(len), paid only by buckets that
        actually changed."""
        arr = [r.arrival for r in self.requests
               if r.task_type == TaskType.ONLINE]
        self._online_min = min(arr) if arr else None

    def earliest_online(self) -> Optional[float]:
        """Arrival of the earliest ONLINE member (None if none)."""
        return self._online_min


class BucketManager:
    def __init__(self, l_max: int, theta: float = 0.5,
                 assignment: str = "linear", refine: str = "midpoint",
                 trigger: str = "majority", min_bucket_span: int = 16,
                 waste_gain_min: float = 0.005):
        self.l_max = l_max
        self.theta = theta
        self.assignment = assignment
        self.refine = refine
        # "majority": the paper's line-19 rule (fraction below midpoint
        #   > theta).  Degenerates on 50/50 bimodal mixes: 49.9% short
        #   never splits (see benchmarks/waste_model.py).
        # "waste": beyond-paper — split whenever bisection reduces the
        #   bucket's empirical Eq.-(3) waste by > waste_gain_min.  This is
        #   the "distribution-aware splitting criteria" the paper names as
        #   future work (§IV).
        self.trigger = trigger
        self.min_bucket_span = min_bucket_span
        self.waste_gain_min = waste_gain_min
        self.buckets: List[Bucket] = [Bucket(0, l_max)]
        # instrumentation (Fig. 6 overhead accounting)
        self.overhead_s = 0.0
        self.n_splits = 0
        self.n_merges = 0

    # ------------------------------------------------------------ assign --
    def add(self, req: Request) -> None:
        t0 = time.perf_counter()
        s = min(req.prompt_len, self.l_max - 1)
        if self.assignment == "bisect":
            lows = [b.low for b in self.buckets]
            i = bisect.bisect_right(lows, s) - 1
            assert s in self.buckets[i]
            self.buckets[i].append(req)
        else:  # paper lines 2-8: linear scan
            for b in self.buckets:
                if s in b:
                    b.append(req)
                    break
            else:  # pragma: no cover
                raise RuntimeError("bucket cover violated")
        self.overhead_s += time.perf_counter() - t0

    # ------------------------------------------------------------ adjust --
    def total(self) -> int:
        return sum(len(b) for b in self.buckets)

    def adjust(self, n_max: int) -> None:
        """Paper AdjustBuckets (lines 10-31); one split round per call."""
        t0 = time.perf_counter()
        total = self.total()
        if total < n_max:
            if len(self.buckets) > 1:
                merged = Bucket(0, self.l_max)
                for b in self.buckets:
                    merged.requests.extend(b.requests)
                merged.refresh_online()
                self.buckets = [merged]
                self.n_merges += 1
        else:
            split_list = []
            min_split = n_max                       # paper: m = N_max
            for b in self.buckets:
                if len(b) <= min_split:
                    continue
                if b.up - b.low <= self.min_bucket_span:
                    continue                        # do not split degenerate spans
                if self.trigger == "waste":
                    if self._waste_gain(b) > self.waste_gain_min:
                        split_list.append(b)
                    continue
                mid = b.midpoint
                c_s = sum(1 for r in b.requests if r.prompt_len < mid)
                if c_s / len(b) > self.theta:
                    split_list.append(b)
            for b in split_list:
                mid = self._split_point(b)
                b_l = Bucket(b.low, mid)
                b_r = Bucket(mid, b.up)
                for r in b.requests:
                    (b_l if min(r.prompt_len, self.l_max - 1) < mid
                     else b_r).append(r)
                i = self.buckets.index(b)
                self.buckets[i:i + 1] = [b_l, b_r]
                self.n_splits += 1
        self.overhead_s += time.perf_counter() - t0

    def _waste_gain(self, b: Bucket) -> float:
        """Empirical Eq.-(3) waste reduction a bisection would bring."""
        mid = self._split_point(b)
        lens = [min(r.prompt_len, self.l_max - 1) for r in b.requests]
        lo = [s for s in lens if s < mid]
        hi = [s for s in lens if s >= mid]
        if not lo or not hi:
            return 0.0
        before = 1.0 - (sum(lens) / len(lens)) / b.up
        after = (len(lo) * (1.0 - (sum(lo) / len(lo)) / mid)
                 + len(hi) * (1.0 - (sum(hi) / len(hi)) / b.up)) / len(lens)
        return before - after

    def _split_point(self, b: Bucket) -> int:
        if self.refine == "eq4":
            # beyond-paper: empirical conditional expectation (Eq. 4)
            mid = sum(r.prompt_len for r in b.requests) / len(b)
            mid = int(min(max(mid, b.low + 1), b.up - 1))
            return mid
        return int(b.midpoint)                      # paper: bisection

    # ------------------------------------------------------------- query --
    def boundaries(self) -> List[int]:
        return [b.low for b in self.buckets] + [self.buckets[-1].up]

    def nonempty(self) -> List[Bucket]:
        return [b for b in self.buckets if len(b)]

    def pop(self, reqs: List[Request]) -> None:
        ids = {id(r) for r in reqs}
        for b in self.buckets:
            kept = [r for r in b.requests if id(r) not in ids]
            if len(kept) != len(b.requests):
                b.requests = kept
                b.refresh_online()      # the min may have been removed

    def order_bucket(self, b: Bucket, policy: str) -> List[Request]:
        """Within-bucket ordering (paper §IV): SJF / LJF for offline,
        earliest-arrival for online SLO compliance."""
        if policy == "sjf":
            return sorted(b.requests, key=lambda r: r.prompt_len)
        if policy == "ljf":
            return sorted(b.requests, key=lambda r: -r.prompt_len)
        if policy == "fcfs":
            return sorted(b.requests, key=lambda r: r.arrival)
        raise ValueError(policy)
