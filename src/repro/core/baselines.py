"""Baseline schedulers (paper §V baselines, re-implemented as policies).

All share the BucketServeScheduler interface so the simulator and the
real engine can drive any of them:

* ``StaticBatchScheduler``   — naive: waits for a fixed batch size (or a
  timeout), FCFS, pads to batch max.  The paper's motivating strawman.
* ``OrcaLikeScheduler``      — continuous batching, FCFS, exact lengths,
  no bucketing (run COUPLED: iteration-level single executor) [Orca].
* ``UELLMLikeScheduler``     — profiles-predicted batching: groups by a
  fine-tuned-LLM *prediction* of resource demand (we model the paper's
  reported >15% prediction error), couples P/D, no dynamic adaptation
  [UELLM].  Prediction error causes both OOM evictions and conservative
  under-batching — the two failure modes BucketServe's Eq. (6) removes.
* ``DistServeLikeScheduler`` — disaggregated P/D, FCFS prefill batches
  under a static conservative token cap, continuous decode, NO
  length-aware grouping (heterogeneous batches -> padding waste)
  [DistServe].
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.models.config import ModelConfig
from .batcher import FormedBatch, MemoryBudget
from .request import Request
from .scheduler import SchedulerBase


class _BaseScheduler(SchedulerBase):
    """FCFS-queue baseline base: the shared queue/monitor/OOM-backoff
    boilerplate lives in SchedulerBase (the loop-facing surface); this
    adds the flat list queue and greedy take."""

    name = "base"

    def __init__(self, cfg: ModelConfig, budget: MemoryBudget,
                 max_batch: int = 512, decode_reserve: float = 0.5):
        super().__init__(cfg, budget, memory_model="sum",
                         max_batch=max_batch, decode_reserve=decode_reserve)
        self.queue: List[Request] = []

    def _enqueue(self, req: Request) -> None:
        self.queue.append(req)

    def queued(self) -> int:
        return len(self.queue)

    def _take(self, reqs: List[Request]) -> FormedBatch:
        for r in reqs:
            self.queue.remove(r)
        self.monitor.queue_len -= len(reqs)
        pad = self.batcher.round_up(
            max((r.prompt_len for r in reqs), default=0))
        return FormedBatch(list(reqs), pad)


class StaticBatchScheduler(_BaseScheduler):
    name = "static"

    def __init__(self, cfg, budget, batch_size: int = 8,
                 timeout_s: float = 0.5, **kw):
        super().__init__(cfg, budget, **kw)
        self.batch_size = batch_size
        self.timeout_s = timeout_s

    def next_prefill_batch(self, now):
        if not self.queue:
            return None
        self.queue.sort(key=lambda r: r.arrival)
        oldest = self.queue[0].arrival
        if len(self.queue) < self.batch_size and now - oldest < self.timeout_s:
            return None                      # wait for a full batch
        return self._take(self.queue[:self.batch_size])


class OrcaLikeScheduler(_BaseScheduler):
    """Continuous batching; iteration-level admission; FCFS; coupled."""
    name = "orca"

    def next_prefill_batch(self, now):
        if not self.queue:
            return None
        ordered = sorted(self.queue, key=lambda r: r.arrival)
        batch = self.batcher.form_batch(ordered,
                                        self.monitor.in_flight_tokens)
        if not batch.requests:
            return None
        return self._take(batch.requests)


class UELLMLikeScheduler(_BaseScheduler):
    """Batches on *predicted* lengths with ~15% error; coupled P/D."""
    name = "uellm"

    def __init__(self, cfg, budget, pred_error: float = 0.15, seed: int = 0,
                 **kw):
        # UELLM trusts its predictor: no decode headroom is reserved, so
        # under-predictions overfill memory (OOM evictions under long/mixed
        # traffic) — the failure mode the paper ascribes to it (§V).
        kw.setdefault("decode_reserve", 0.0)
        super().__init__(cfg, budget, **kw)
        self.rng = np.random.default_rng(seed)
        self.pred_error = pred_error
        self._pred = {}

    def _predict(self, r: Request) -> float:
        if r.rid not in self._pred:
            noise = self.rng.lognormal(0.0, self.pred_error)
            self._pred[r.rid] = (r.prompt_len + r.max_new_tokens) * noise
        return self._pred[r.rid]

    def next_prefill_batch(self, now):
        if not self.queue:
            return None
        # deployment-profile batching: sort by predicted demand, greedy fill
        ordered = sorted(self.queue, key=self._predict)
        cap = self.batcher.token_budget(self.monitor.in_flight_tokens) \
            * (1 - self.batcher.decode_reserve) * self._cap_scale()
        take, tot = [], 0.0
        for r in ordered:
            pred = self._predict(r)
            if take and tot + pred > cap:
                break
            take.append(r)
            tot += pred                      # predicted, not actual -> OOM risk
            if len(take) >= self.batcher.max_batch:
                break
        if not take:
            return None
        return self._take(take)


class DistServeLikeScheduler(_BaseScheduler):
    """Disaggregated FCFS; conservative static cap; no length grouping."""
    name = "distserve"

    def __init__(self, cfg, budget, conservatism: float = 0.7, **kw):
        # DistServe sizes its prefill/decode instances statically (per-phase
        # placement optimization); there is no cross-phase decode-headroom
        # coupling like BucketServe's Eq.-(6) reserve -> admission is bounded
        # only by the conservative static cap.
        kw.setdefault("decode_reserve", 0.0)
        super().__init__(cfg, budget, **kw)
        self.conservatism = conservatism

    def next_prefill_batch(self, now):
        if not self.queue:
            return None
        ordered = sorted(self.queue, key=lambda r: r.arrival)
        cap = self.batcher.token_budget(self.monitor.in_flight_tokens) \
            * (1 - self.batcher.decode_reserve) * self.conservatism \
            * self._cap_scale()
        take, tot = [], 0
        for r in ordered:
            clen = r.prompt_len + r.max_new_tokens
            if take and tot + clen > cap:
                break
            take.append(r)
            tot += clen
            if len(take) >= self.batcher.max_batch:
                break
        if not take:
            return None
        return self._take(take)


def make_scheduler(name: str, cfg: ModelConfig, budget: MemoryBudget, **kw):
    from .scheduler import BucketServeScheduler, SchedulerConfig
    if name == "bucketserve":
        sk = {k: v for k, v in kw.items()
              if k in SchedulerConfig.__dataclass_fields__}
        return BucketServeScheduler(cfg, budget, SchedulerConfig(**sk))
    cls = {"static": StaticBatchScheduler, "orca": OrcaLikeScheduler,
           "uellm": UELLMLikeScheduler,
           "distserve": DistServeLikeScheduler}[name]
    return cls(cfg, budget, **kw)


# Execution mode per system (see Simulator): UELLM batches by predicted
# profiles at BATCH granularity (it predates iteration-level scheduling,
# coupling P/D per the paper's critique); Orca is iteration-level coupled;
# DistServe/BucketServe are disaggregated.
SIM_MODE = {"static": "static", "orca": "coupled", "uellm": "static",
            "distserve": "disagg", "bucketserve": "disagg"}

# Chip split on the paper's 4-GPU testbed: disaggregated systems dedicate
# 2 chips to each phase; coupled systems use all 4 for everything.
def hardware_for(name: str, base_hw):
    import dataclasses as _dc
    if SIM_MODE[name] == "disagg":
        return base_hw, base_hw.decode_chips, 2
    total = base_hw.prefill_chips + base_hw.decode_chips
    return (_dc.replace(base_hw, prefill_chips=total, decode_chips=total),
            total, 1)
