"""Dynamic batching controller — paper Eqs. (1), (5), (6).

Memory safety:
    M_safe = 0.9 × M_remain                                   (Eq. 5)
    N_max  = max{ N : Σ_{i<=N} S_i  <=  M_safe / (2·L·H·D·B) } (Eq. 6)

The 2LHDB factor is ``ModelConfig.kv_bytes_per_token`` (which correctly
zeroes attention-free layers and window-caps SWA/local-attention layers —
the TPU adaptation of the paper's A100 memory model, DESIGN.md §4).

Three memory models:
  * ``"sum"``    — the paper's Eq. (6): footprint ∝ Σ S_i (per-request
    exact allocation; the idealized lower bound).
  * ``"padded"`` — footprint ∝ N × S_pad (bucket-upper padding; what the
    real engine's contiguous slot pool actually allocates).  Beyond-paper
    but required for honest TPU memory accounting.
  * ``"paged"``  — footprint ∝ Σ ceil(S_i / page) × page: Eq. (6) made
    EXACT for the block-table decode pool (core/paging.py, DESIGN.md §3)
    — within one page of "sum" per request, and what the paged engine
    physically pins.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.models.config import ModelConfig
from .bucket import Bucket, BucketManager
from .request import Request, TaskType


@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    hbm_bytes_per_device: int = 16 * 2 ** 30      # v5e
    n_devices: int = 1                             # devices holding this cache
    weight_bytes: int = 0                          # model weights (sharded)
    activation_reserve: float = 0.05               # fraction held back
    reserve: float = 0.10                          # paper's 10% (Eq. 5)

    def m_safe(self) -> float:
        total = self.hbm_bytes_per_device * self.n_devices
        remain = total - self.weight_bytes - self.activation_reserve * total
        return max(0.0, (1.0 - self.reserve) * remain)   # Eq. (5)


@dataclasses.dataclass
class FormedBatch:
    requests: List[Request]
    pad_to: int                                    # padded sequence length
    bucket: Optional[Bucket] = None

    @property
    def size(self) -> int:
        return len(self.requests)

    @property
    def total_tokens(self) -> int:
        return sum(r.prompt_len for r in self.requests)

    @property
    def padded_tokens(self) -> int:
        return self.pad_to * len(self.requests)

    # ---- per-batch waste gauges (core/telemetry.py timeline args) ----
    @property
    def padding_fraction(self) -> float:
        """Fraction of the padded prefill compute that is pure padding
        — Eq. (1)'s overhead MEASURED per dispatched batch."""
        padded = self.padded_tokens
        return 1.0 - self.total_tokens / padded if padded else 0.0

    @property
    def homogeneity(self) -> float:
        """min/max prompt length across rows: 1.0 = perfectly uniform
        batch (the bucket did its job), ->0 = pathological mixing."""
        if not self.requests:
            return 1.0
        lens = [r.prompt_len for r in self.requests]
        return min(lens) / max(max(lens), 1)


class DynamicBatchController:
    def __init__(self, cfg: ModelConfig, budget: MemoryBudget,
                 memory_model: str = "sum", bytes_per_el: int = 2,
                 max_batch: int = 512, decode_reserve: float = 0.5,
                 pad_multiple: int = 128, page_size: int = 128):
        assert memory_model in ("sum", "padded", "paged"), memory_model
        self.cfg = cfg
        self.budget = budget
        self.memory_model = memory_model
        self.page_size = page_size
        # quantized-KV variant: Eq. (6) admits ~2x the live tokens
        self.kv_per_tok = max(cfg.cache_bytes_per_token(), 1)
        self.state_per_req = cfg.state_bytes(bytes_per_el)
        self.max_batch = max_batch
        # fraction of the KV budget reserved for in-flight decode caches
        self.decode_reserve = decode_reserve
        self.pad_multiple = pad_multiple

    # -------------------------------------------------------------- Eq 6 --
    def token_budget(self, in_flight_tokens: int = 0) -> float:
        """M_safe / 2LHDB minus what live decode caches already hold."""
        cap = self.budget.m_safe() / self.kv_per_tok
        return max(0.0, cap - in_flight_tokens)

    def n_max(self, mean_len: float, in_flight_tokens: int = 0) -> int:
        """Scalar N_max used by Algorithm 1's split threshold."""
        cap = self.token_budget(in_flight_tokens) * (1 - self.decode_reserve)
        return max(1, min(self.max_batch, int(cap / max(mean_len, 1.0))))

    #: min-slack scale (s) over which the restore-backlog admission
    #: throttle fades out: a queue whose tightest deadline has less
    #: than this much slack left gets the restore pressure discounted
    #: proportionally (zero slack = no throttle at all)
    slack_relief_s = 1.0

    def admission_pressure_tokens(self, restore_pages: int,
                                  restore_backlog_bytes: int,
                                  min_slack: Optional[float] = None) -> int:
        """Restore-aware admission pricing (DESIGN.md §4): Eq.-(6)
        token-equivalents of host-tier restore traffic the plain
        in-flight sum misses.

        Two terms: (1) device pages already RESERVED by in-flight
        restores (``restore_begin`` took them off the free list, but no
        block table holds them yet) — real KV occupancy under paged
        accounting; (2) the COMPRESSED bytes still queued on the PCIe
        channel, converted through Eq. (6)'s own denominator
        (``kv_per_tok``) — restores about to land and occupy pages get
        priced before admission overfills the pool and forces the
        evict/restore thrash the reservations exist to prevent.  A
        compressed spill tier (int8/int4) queues fewer bytes per page,
        so its backlog term is proportionally cheaper — quantized spill
        shows up in admission exactly as it does on the wire.

        ``min_slack`` (DESIGN.md §8, fed by the goodput scheduler from
        the monitor's minimum-slack gauge) scales the BACKLOG term by
        how much deadline slack the queue still has: throttling
        admission to protect a restore's resume-TTFT is the wrong trade
        while a near-deadline request starves, so the channel-backlog
        pressure fades linearly to zero as min slack approaches zero.
        Reserved pages are never discounted — they are physically
        occupied."""
        pages = restore_pages * self.page_size \
            if self.memory_model == "paged" else 0
        backlog = int(restore_backlog_bytes / self.kv_per_tok)
        if min_slack is not None:
            backlog = int(backlog * min(
                max(min_slack / self.slack_relief_s, 0.0), 1.0))
        return pages + backlog

    def _cache_len(self, r: Request) -> int:
        win = self.cfg.sliding_window or (
            self.cfg.local_window if self.cfg.arch_type == "hybrid" else 0)
        need = r.prompt_len + r.max_new_tokens
        return min(need, win) if win else need

    def form_batch(self, ordered: List[Request],
                   in_flight_tokens: int = 0) -> FormedBatch:
        """Greedy prefix of `ordered` under Eq. (6) (or padded model)."""
        cap = self.token_budget(in_flight_tokens) * (1 - self.decode_reserve)
        take, tot, pad = [], 0, 0
        for r in ordered:
            if len(take) >= self.max_batch:
                break
            clen = self._cache_len(r)
            if self.memory_model in ("sum", "paged"):
                new_tot = tot + self.charge_tokens(clen)
                if take and new_tot > cap:
                    break
                tot = new_tot
            else:  # padded
                new_pad = max(pad, self.round_up(clen))
                if take and new_pad * (len(take) + 1) > cap:
                    break
                pad = new_pad
                tot = pad * (len(take) + 1)
            take.append(r)
            # SSM/hybrid per-request state counts against the budget too
            tot += self.state_per_req / self.kv_per_tok
        pad_to = self.round_up(max((r.prompt_len for r in take), default=0))
        return FormedBatch(take, pad_to)

    def round_up(self, n: int) -> int:
        """Round a sequence length up to the controller's pad multiple —
        the padded shape a formed batch compiles/executes at."""
        m = self.pad_multiple
        return -(-n // m) * m if n else 0

    def charge_tokens(self, cache_tokens: int, shared_tokens: int = 0) -> int:
        """Tokens a cache of ``cache_tokens`` is CHARGED against the
        budget: exact under "sum"/"padded" accounting, ceil-to-page under
        "paged" (a request pins whole pages — Eq. (6) on page granules).
        ``shared_tokens`` (paged model only) is the retention hit:
        shared pages are charged ONCE by whoever first materialized
        them, so a sharer pays only its private suffix.  The discount is
        FLOORED to full pages — a session-resumed hit is unaligned, but
        its partial tail page is handed over PRIVATE to the request
        (core/retention.py), so the request pays for that whole page."""
        if self.memory_model != "paged":
            return cache_tokens
        p = self.page_size
        return max((-(-cache_tokens // p) - shared_tokens // p) * p, 0)
