"""ShapeDtypeStruct stand-ins for every (arch x input-shape) pair.

No device allocation: the dry-run lowers against these.  Decode shapes
build a cache spec via jax.eval_shape over init_cache.

Shapes (task spec):
    train_4k     seq 4096   global_batch 256   train_step
    prefill_32k  seq 32768  global_batch 32    prefill
    decode_32k   seq 32768  global_batch 128   serve_step (1 token + cache)
    long_500k    seq 524288 global_batch 1     serve_step, sub-quadratic only
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped).  Encoder-only archs have no decode;
    full-attention archs need the SWA variant for long_500k."""
    kind = SHAPES[shape]["kind"]
    if kind == "decode" and not cfg.has_decode:
        return False, "encoder-only: no autoregressive decode step"
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "full attention at 524k context: requires +swa variant"
    if kind == "train" and cfg.arch_type == "vlm" and False:
        pass
    return True, ""


def _audio_frames(cfg, B, T, dtype):
    return jax.ShapeDtypeStruct((B, T, cfg.d_model), dtype)


def input_specs(cfg: ModelConfig, shape: str, dtype=jnp.bfloat16):
    """Returns a dict of ShapeDtypeStructs for the given input shape."""
    info = SHAPES[shape]
    B, T, kind = info["global_batch"], info["seq_len"], info["kind"]
    tok = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)

    if kind == "train":
        if cfg.is_encoder:
            batch = {"embeds": _audio_frames(cfg, B, T, dtype),
                     "labels": tok((B, T))}
        else:
            batch = {"tokens": tok((B, T))}
            if cfg.arch_type == "vlm":
                batch["vision_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_vision_tokens, cfg.d_vision), dtype)
        return {"batch": batch}

    if kind == "prefill":
        out = {"lengths": tok((B,))}
        if cfg.is_encoder:
            out["embeds"] = _audio_frames(cfg, B, T, dtype)
        else:
            out["tokens"] = tok((B, T))
            if cfg.arch_type == "vlm":
                out["vision_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_vision_tokens, cfg.d_vision), dtype)
        return out

    # decode: ONE new token + cache covering `seq_len` context
    cache = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, T, dtype))
    return {"token": tok((B,)), "cache": cache}


def params_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: tfm.init_params(cfg, jax.random.PRNGKey(0), dtype))


def model_flops(cfg: ModelConfig, shape: str) -> float:
    """MODEL_FLOPS per §Roofline: 6·N_active·D for training, 2·N_active·D
    for inference forward passes (D = tokens processed)."""
    info = SHAPES[shape]
    B, T, kind = info["global_batch"], info["seq_len"], info["kind"]
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * B * T
    if kind == "prefill":
        return 2.0 * n * B * T
    return 2.0 * n * B          # decode: one token per sequence
