"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis / cost_analysis, and record roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape decode_32k [--multi-pod] [--all] [--out results/dryrun.json]

Writes one JSON record per combination into --out (appending/merging), so
the full 40x2 sweep can run incrementally and benchmarks/roofline.py can
read the table without recompiling.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import (device count locks on first init).

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.launch import hlo_analysis
from repro.launch.input_specs import (SHAPES, applicable, input_specs,
                                      model_flops, params_shapes)
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import attention as attn_mod
from repro.models import transformer as tfm
from repro.sharding import partition
from repro.train import optimizer as opt_mod
from repro.train import train_loop

DTYPE = jnp.bfloat16


def _cfg_for(arch: str, shape: str, extra_variant: str = ""):
    cfg = get_config(arch, variant=extra_variant)
    ok, why = applicable(cfg, shape)
    variant = extra_variant
    if not ok and shape == "long_500k" and cfg.has_decode:
        variant = ("swa+" + extra_variant) if extra_variant else "swa"
        cfg = get_config(arch, variant=variant)  # serving variant (DESIGN §4)
        ok, why = applicable(cfg, shape)
    return cfg, ok, why, variant


def lower_one(arch: str, shape: str, multi_pod: bool, moe_impl: str = "ep",
              pin_attn: bool = True, variant: str = ""):
    """Returns (lowered, compiled, record) or raises.

    pin_attn=False reproduces the pre-optimization baseline (no attention
    activation sharding pin — EXPERIMENTS.md §Perf iteration 1);
    variant="int8" lowers the quantized-KV serving variant."""
    cfg, ok, why, variant = _cfg_for(arch, shape, variant)
    if not ok:
        return None, None, {"arch": arch, "shape": shape,
                            "mesh": "multi" if multi_pod else "single",
                            "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    attn_mod.set_mesh(mesh if pin_attn else None)
    kind = SHAPES[shape]["kind"]
    B = SHAPES[shape]["global_batch"]
    T = SHAPES[shape]["seq_len"]
    specs = input_specs(cfg, shape, DTYPE)
    pshapes = params_shapes(cfg, DTYPE)
    pspec = partition.param_specs(cfg, pshapes, mesh)
    sh = lambda tree: partition.to_shardings(mesh, tree)
    mi = moe_impl if cfg.n_experts else "local"

    if kind == "train":
        opt_shapes = jax.eval_shape(opt_mod.init, pshapes)
        ospec = {"m": pspec, "v": pspec, "step": P()}
        bspec = partition.batch_specs(cfg, specs["batch"], mesh)
        step = train_loop.make_train_step(
            cfg, opt_mod.AdamWConfig(), moe_impl=mi, mesh=mesh, remat=True)
        jitted = jax.jit(
            step,
            in_shardings=(sh(pspec), sh(ospec), sh(bspec)),
            out_shardings=(sh(pspec), sh(ospec),
                           sh(jax.tree.map(lambda _: P(),
                                           {"loss": 0, "tokens": 0,
                                            "grad_norm": 0, "lr": 0}))),
            donate_argnums=(0, 1))
        lowered = jitted.lower(pshapes, opt_shapes, specs["batch"])
    elif kind == "prefill":
        bspec = partition.batch_specs(
            cfg, {k: v for k, v in specs.items()}, mesh)
        cache_shapes = jax.eval_shape(
            lambda: tfm.init_cache(cfg, B, T, DTYPE))
        cspec = partition.cache_specs(cfg, cache_shapes, mesh, B)
        lspec = partition.logits_spec(cfg, mesh, B)

        def prefill_fn(params, inputs):
            return tfm.prefill(cfg, params, cache_len=T, moe_impl=mi,
                               mesh=mesh, **inputs)
        jitted = jax.jit(
            prefill_fn,
            in_shardings=(sh(pspec), sh(bspec)),
            out_shardings=(sh(lspec), sh({"pos": P(batch_axes(mesh, B)),
                                          "groups": cspec["groups"]})))
        lowered = jitted.lower(pshapes, specs)
    else:  # decode
        cspec = partition.cache_specs(cfg, specs["cache"], mesh, B)
        lspec = partition.logits_spec(cfg, mesh, B)
        tok_spec = P(batch_axes(mesh, B))

        def decode_fn(params, token, cache):
            return tfm.decode_step(cfg, params, token, cache, moe_impl=mi,
                                   mesh=mesh)
        jitted = jax.jit(
            decode_fn,
            in_shardings=(sh(pspec), NamedSharding(mesh, tok_spec),
                          sh(cspec)),
            out_shardings=(sh(lspec), sh(cspec)),
            donate_argnums=(2,))
        lowered = jitted.lower(pshapes, specs["token"], specs["cache"])

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    n_dev = mesh.size
    hlo_txt = compiled.as_text()
    mf = model_flops(cfg, shape)
    terms = hlo_analysis.analyze(compiled, n_dev, mf)
    stats_fused = hlo_analysis.module_stats(hlo_txt, fused_kernels=True)
    terms_fused = hlo_analysis.RooflineTerms(
        flops_per_device=stats_fused.flops,
        bytes_per_device=stats_fused.bytes,
        coll_bytes_per_device=sum(stats_fused.coll.values()),
        n_devices=n_dev, model_flops=mf)
    record = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev, "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "roofline": terms.as_dict(),
        "roofline_fused": terms_fused.as_dict(),
        "collectives": {k: v for k, v in
                        hlo_analysis.module_stats(hlo_txt).coll.items()},
    }
    return lowered, compiled, record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = list(ASSIGNED) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    try:
        with open(args.out) as f:
            results = {tuple(k.split("|")): v
                       for k, v in json.load(f).items()}
    except (FileNotFoundError, json.JSONDecodeError):
        results = {}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "multi" if mp else "single")
                if key in results and "error" not in results[key]:
                    continue
                label = f"{arch} x {shape} x {key[2]}"
                print(f"=== {label} ===", flush=True)
                try:
                    t0 = time.time()
                    _, compiled, rec = lower_one(arch, shape, mp)
                    if compiled is None:
                        print(f"  SKIP: {rec['skipped']}")
                    else:
                        per_dev_arg = rec["memory"]["argument_bytes"]
                        print(f"  compiled in {rec['compile_s']}s; "
                              f"args/dev={per_dev_arg/2**30:.2f}GiB "
                              f"temp/dev={rec['memory']['temp_bytes']/2**30:.2f}GiB")
                        for tag in ("roofline", "roofline_fused"):
                            r = rec[tag]
                            print(f"  {tag}: compute={r['compute_s']:.4f}s "
                                  f"memory={r['memory_s']:.4f}s "
                                  f"collective={r['collective_s']:.4f}s "
                                  f"dominant={r['dominant']} "
                                  f"useful={r['useful_ratio']:.2f}")
                    results[key] = rec
                except Exception as e:
                    print(f"  FAIL: {type(e).__name__}: {e}")
                    traceback.print_exc()
                    results[key] = {"arch": arch, "shape": shape,
                                    "mesh": key[2],
                                    "error": f"{type(e).__name__}: {e}"}
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump({"|".join(k): v for k, v in results.items()},
                              f, indent=1)

    n_err = sum(1 for v in results.values() if "error" in v)
    print(f"\n{len(results)} records, {n_err} errors -> {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
