"""Serving launcher: BucketServe on the unified serving loop.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        [--backend jax|sim] [--chunk 128] [--paged --page-size 128] \
        [--requests 32] [--dataset mixed] [--data 2 --model 2]

``--backend jax`` (default) runs the real engine: jitted prefill/decode
with slot-pool continuous batching; ``--chunk N`` enables chunked
prefill (decode iterations interleave between N-token prompt chunks);
``--paged`` swaps the per-slot KV caches for the shared page pool
(block-table admission + youngest-preemption, DESIGN.md §3) — the
scheduler then runs the ceil-to-page Eq. (6) memory model.
``--backend sim`` drives the SAME scheduler through the analytic cost
model instead — both are ExecutionBackends under one ServingLoop
(core/serving_loop.py), which is how the cost model's scheduling
behaviour is validated against real execution.

``--sessions N --turns T`` serves a multi-turn conversation workload
through the KV retention layer (core/retention.py): each finished
turn's transcript stays retained (full pages on the radix, partial
tail pinned under the session key for ``--session-ttl`` seconds) and
the next turn of the same conversation resumes past it instead of
re-prefilling (DESIGN.md §3 "Session retention"; implies
--prefix-cache and therefore --paged).

On this CPU container use --smoke (reduced config, real execution).  On
a TPU slice the same entrypoint loads the full config, registers the
production mesh (sharding/context.py) and shards params with
repro/sharding/partition.py.
"""
from __future__ import annotations

import argparse
import copy
import dataclasses
import time

import jax

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core import (BucketServeScheduler, GoodputScheduler,
                        MemoryBudget, SchedulerConfig)
from repro.core.engine import ServingEngine
from repro.core.faults import FaultPlan
from repro.core.recovery import LoopCheckpoint
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.core.telemetry import Tracer, validate_perfetto
from repro.data.trace import TraceRecorder, TraceWorkload
from repro.data.workload import DEFAULT_CLASS_MIX, WorkloadSpec, generate
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.sharding import context as shctx
from repro.sharding import partition


def _sched_config(args) -> SchedulerConfig:
    return SchedulerConfig(
        max_batch=args.slots, trigger=args.trigger,
        memory_model="paged" if args.paged else "sum",
        page_size=args.page_size)


def _make_sched(cfg, budget, args):
    """--sched picks the queue policy: arrival-order BucketServe or the
    deadline-slack goodput scheduler (DESIGN.md §8) — same buckets,
    same Eq.-(6) controller, different candidate ordering."""
    cls = GoodputScheduler if args.sched == "goodput" \
        else BucketServeScheduler
    return cls(cfg, budget, _sched_config(args))


def _tail_line(res) -> str:
    """Percentile tails (overall + per class) — what the benchmark
    gates read; means hide exactly the burst tail this PR is about."""
    out = (f"tails: TTFT p50/p95/p99 {res.p50('ttft'):.3f}/"
           f"{res.p95('ttft'):.3f}/{res.p99('ttft'):.3f} s, "
           f"TPOT p50/p95/p99 {res.p50('tpot') * 1e3:.1f}/"
           f"{res.p95('tpot') * 1e3:.1f}/{res.p99('tpot') * 1e3:.1f} ms, "
           f"{res.incomplete()} incomplete")
    for c in res.classes():
        out += (f"\nclass {c}: TTFT p50/p95/p99 "
                f"{res.p50('ttft', c):.3f}/{res.p95('ttft', c):.3f}/"
                f"{res.p99('ttft', c):.3f} s, TPOT p99 "
                f"{res.p99('tpot', c) * 1e3:.1f} ms, "
                f"attainment {res.slo_attainment(c):.2f}, "
                f"goodput {res.goodput(c):.3f} req/s")
    if res.classes():
        out += (f"\ngoodput {res.goodput():.3f} req/s "
                f"({res.server_rps():.3f} finished req/s)")
    return out


def _finish_timeline(args, tracer) -> None:
    """Export + schema-validate the Perfetto timeline (--trace-out)."""
    if tracer is None:
        return
    doc = tracer.save(args.trace_out)
    errs = validate_perfetto(doc)
    n_ev = sum(1 for e in doc["traceEvents"] if e.get("ph") != "M")
    if errs:
        for e in errs[:10]:
            print(f"[trace] INVALID: {e}")
        raise SystemExit(f"--trace-out produced an invalid trace "
                         f"({len(errs)} schema violations)")
    print(f"[trace] {n_ev} events on {len(tracer._tracks)} tracks -> "
          f"{args.trace_out} (open in ui.perfetto.dev)")


def _make_sim(cfg, args, plan=None, recorder=None, tracer=None):
    hw = A100X4
    budget = MemoryBudget(hbm_bytes_per_device=hw.hbm_bytes,
                          n_devices=hw.decode_chips,
                          weight_bytes=cfg.param_count() * 2)
    sched = _make_sched(cfg, budget, args)
    sim = Simulator(sched, CostModel(cfg, hw), mode="disagg",
                    decode_slot_cap=args.slots, chunk_tokens=args.chunk,
                    paged=args.paged, page_size=args.page_size,
                    kv_pool_tokens=args.pool_tokens,
                    prefix_cache=args.prefix_cache,
                    session_ttl=args.session_ttl if args.sessions else None,
                    host_pool_tokens=args.host_pool_tokens,
                    spill_bw=args.spill_bw * 1e9,
                    spill_dtype=args.spill_dtype,
                    slice_tokens=args.slice_tokens,
                    recorder=recorder, tracer=tracer,
                    fault_plan=plan)
    return sim, sched


def _fault_line(res, plan) -> str:
    """Recovery counters under an armed plan — what the chaos smoke
    greps; replays of the same SPEC must print this line verbatim."""
    return (f"faults[{plan.spec()}]: {res.fault_events} injected, "
            f"{res.fault_retries} retried, {res.fault_kills} killed, "
            f"{res.quarantined} quarantined; restore channel: "
            f"{res.restore_stalls} stalls, {res.restore_retries} retries, "
            f"{res.restore_failures} failures, {res.restore_sheds} sheds, "
            f"{res.restore_timeouts} timeouts, "
            f"{res.corruptions} corruptions")


def _run_sim(cfg, args, reqs, recorder=None, tracer=None, plan=None):
    """Cost-model pass over the identical workload (validation mode)."""
    sim, sched = _make_sim(cfg, args, plan, recorder, tracer)
    # recovery backoff + restart penalties inflate virtual makespan
    # under an armed plan — give the storm room to finish
    res = sim.run(reqs, time_limit=40000.0 if plan is not None else 3600.0)
    prefix_info = ""
    if args.prefix_cache:
        prefix_info = (f"prefix hits {res.prefix_hits}/{res.prefix_lookups} "
                       f"({res.prefix_hit_rate():.2f}), "
                       f"{res.prefill_tokens_skipped} prompt tokens "
                       f"skipped, {res.prefix_pages_saved} pages saved; ")
    if args.sessions:
        prefix_info += (
            f"session hits {res.session_hits}/{res.session_lookups}, "
            f"{res.session_hit_tokens} transcript tokens restored, "
            f"{res.tail_pages_reused} tails reused, "
            f"{res.sessions_expired} expired; ")
    if args.kv_spill:
        prefix_info += (
            f"spill[{args.spill_dtype}]: {res.spilled_pages} pages "
            f"({res.spilled_bytes} B) out, "
            f"{res.restored_pages} back ({res.restored_tokens} tokens, "
            f"{res.restored_bytes} B), "
            f"{res.spill_drops} dropped, "
            f"{res.spill_hold_events} holds; ")
    print(f"[sim] served {len(res.finished())}/{len(reqs)} requests in "
          f"{res.makespan:.2f} virtual s; {res.throughput_tok_s():.0f} tok/s; "
          f"SLO {res.slo_attainment():.2f}; OOM {res.oom_events}; "
          f"peak pool {res.peak_pool}; preemptions {res.preempt_events}; "
          f"{prefix_info}"
          f"buckets: {[(b.low, b.up) for b in sched.buckets.buckets]}")
    print(f"[sim] {_tail_line(res)}")
    print(f"[sim] kv util (time-weighted) {res.kv_util_time_weighted:.2f}; "
          f"padding waste {res.padding_waste_ratio():.3f}; "
          f"blame {_fmt_blame(res.blame())}")
    if plan is not None:
        print(f"[sim] {_fault_line(res, plan)}")
    return res


def _transcript(backend, r):
    """Full token path: prompt (slice promotion included) + synthetic
    generated continuation past the promoted boundary — the identity
    the drain/resume smoke compares bit-for-bit."""
    toks = [] if r.tokens is None else \
        [int(t) for t in r.tokens[:r.prompt_len]]
    gen = backend.generated_tokens(r)[r.sliced_tokens:]
    return toks + [int(t) for t in gen]


def _drain_resume_sim(cfg, args, reqs, plan):
    """--drain-after smoke: reference run, a second run checkpointed at
    T virtual seconds (drain -> JSON round-trip), then a COLD loop
    resuming the checkpoint.  Every request must finish exactly once
    across the drained+resumed pair with token ids bit-identical to the
    uninterrupted reference, else exit nonzero (the CI gate greps the
    identity line)."""
    t = args.drain_after
    ref_sim, _ = _make_sim(cfg, args, plan)
    ref = ref_sim.run(copy.deepcopy(reqs), time_limit=40000.0)
    want = {r.rid: _transcript(ref_sim.loop.backend, r)
            for r in ref.requests if r.finished >= 0 and not r.dropped}

    sim1, _ = _make_sim(cfg, args, plan)
    res1 = sim1.run(copy.deepcopy(reqs), time_limit=40000.0, drain_at=t)
    ck = LoopCheckpoint.from_json(sim1.loop.drain().to_json())
    sim2, _ = _make_sim(cfg, args, plan)
    res2 = sim2.run(ck.restore_requests(), time_limit=40000.0,
                    resume_clock=ck.now)

    done1 = {r.rid: r for r in res1.requests
             if r.finished >= 0 and not r.dropped}
    done2 = {r.rid: r for r in res2.requests
             if r.finished >= 0 and not r.dropped}
    print(f"[drain] checkpoint at t={ck.now:.2f}s: {len(done1)} finished "
          f"pre-drain, {len(ck.requests)} in-flight/queued + "
          f"{len(ck.held_turns)} held turns serialized, "
          f"{len(done2)} finished after cold resume")
    if plan is not None:
        print(f"[drain] {_fault_line(res2, plan)}")
    errs = []
    if set(done1) & set(done2):
        errs.append(f"duplicated rids {sorted(set(done1) & set(done2))}")
    if set(done1) | set(done2) != set(want):
        lost = set(want) - (set(done1) | set(done2))
        extra = (set(done1) | set(done2)) - set(want)
        errs.append(f"lost {sorted(lost)} / extra {sorted(extra)}")
    for rid, r in done1.items():
        if rid in want and _transcript(sim1.loop.backend, r) != want[rid]:
            errs.append(f"rid {rid} diverged pre-drain")
    for rid, r in done2.items():
        if rid in want and _transcript(sim2.loop.backend, r) != want[rid]:
            errs.append(f"rid {rid} diverged after resume")
    if errs:
        raise SystemExit("[drain] resume NOT work-preserving: "
                         + "; ".join(errs))
    print(f"[drain] drain-resume token ids identical "
          f"({len(done1)}+{len(done2)}/{len(want)} requests, "
          f"checkpoint {len(ck.to_json())} B)")


def _fmt_blame(b) -> str:
    return "{" + ", ".join(f"{k}: {v:.3f}s" for k, v in b.items()) + "}"


def _finish_trace(args, recorder) -> None:
    if recorder is None:
        return
    print("batch log:", recorder.batch_log)
    if args.trace_record:
        recorder.save(args.trace_record,
                      meta={"arch": args.arch, "backend": args.backend,
                            "burst_factor": args.burst_factor})
        print(f"recorded {len(recorder.snapshots)} requests -> "
              f"{args.trace_record}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--backend", default="jax", choices=["jax", "sim"],
                    help="real JAX engine or analytic cost model")
    ap.add_argument("--chunk", type=int, default=None,
                    help="chunked-prefill span in tokens (default: whole "
                         "prompt)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV decode pool (block-table admission)")
    ap.add_argument("--page-size", type=int, default=128,
                    help="KV page size in tokens (with --paged)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cross-request prefix cache on the paged pool "
                         "(radix lookup + refcounted shared pages; "
                         "implies --paged)")
    ap.add_argument("--prefix-scenarios", type=int, default=0,
                    help="shared-prefix workload family: N distinct "
                         "system prompts with Zipf reuse (0 = classic "
                         "length-only workload)")
    ap.add_argument("--prefix-tokens", type=int, default=128,
                    help="tokens per shared system prompt (with "
                         "--prefix-scenarios)")
    ap.add_argument("--sessions", type=int, default=0,
                    help="multi-turn conversation workload: N sessions "
                         "of --turns turns each; enables the session "
                         "retention layer (implies --prefix-cache)")
    ap.add_argument("--turns", type=int, default=3,
                    help="turns per session (with --sessions)")
    ap.add_argument("--session-ttl", type=float, default=60.0,
                    help="seconds a finished conversation's KV stays "
                         "pinned awaiting the next turn")
    ap.add_argument("--think-time", type=float, default=0.0,
                    help="mean think-time gap (s) between a session's "
                         "turns; > --session-ttl exercises the "
                         "expiry/demote path (with --kv-spill the next "
                         "turn RESTORES instead of re-prefilling)")
    ap.add_argument("--kv-spill", action="store_true",
                    help="host-RAM spill tier under the retention layer "
                         "(core/retention.py): pressure/TTL eviction "
                         "copies cold retained pages device->host and a "
                         "later hit restores them instead of "
                         "re-prefilling (implies --prefix-cache)")
    ap.add_argument("--host-pool-tokens", type=int, default=None,
                    help="host-RAM spill budget in KV tokens (default: "
                         "4x the device pool)")
    ap.add_argument("--spill-bw", type=float, default=16.0,
                    help="host<->device link bandwidth in GB/s used to "
                         "price spill/restore transfers")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "int8"],
                    help="device KV pool precision: int8 halves the "
                         "per-token cache bytes, so the SAME HBM byte "
                         "budget holds ~2x the pages (Eq. 6 and the "
                         "paged pool are both byte-denominated)")
    ap.add_argument("--spill-dtype", default="bf16",
                    choices=["bf16", "int8", "int4"],
                    help="host spill tier precision: compressed spill "
                         "retains 2-4x more transcript pages under the "
                         "same --host-pool-tokens budget and each "
                         "restore moves proportionally fewer PCIe bytes")
    ap.add_argument("--pool-tokens", type=int, default=None,
                    help="total pooled KV tokens (default: slots x "
                         "cache_len — the contiguous pool's budget — on "
                         "the jax backend; the cost model's HBM-derived "
                         "KV budget on --backend sim)")
    ap.add_argument("--trace-record", default=None, metavar="PATH",
                    help="record this run's request stream to a "
                         "versioned JSONL trace (data/trace.py) that "
                         "replays bit-identically through either "
                         "backend")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the run's event timeline as Chrome "
                         "trace-event / Perfetto JSON "
                         "(core/telemetry.py Tracer; open in "
                         "ui.perfetto.dev — one track per bucket / "
                         "spill channel / executor)")
    ap.add_argument("--trace-replay", default=None, metavar="PATH",
                    help="serve a recorded trace instead of generating "
                         "a workload (arrival timestamps preserved; "
                         "smoke clamps are NOT applied — the trace is "
                         "authoritative)")
    ap.add_argument("--burst-factor", type=float, default=1.0,
                    help="> 1 switches to the heterogeneous trace "
                         "family: chat/longctx/batch class mix with "
                         "bursty diurnal arrivals peaking at this "
                         "multiple of --rps")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--dataset", default="mixed")
    ap.add_argument("--rps", type=float, default=8.0)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--trigger", default="waste",
                    choices=["majority", "waste"])
    ap.add_argument("--sched", default="bucket",
                    choices=["bucket", "goodput"],
                    help="queue policy: arrival-order BucketServe or "
                         "the deadline-slack goodput scheduler "
                         "(urgency-ordered buckets, slack-aware "
                         "preemption; DESIGN.md §8)")
    ap.add_argument("--slice-tokens", type=int, default=None,
                    help="slice-boundary preemption: a preempted decode "
                         "request keeps generated work up to the last "
                         "multiple of N tokens and resumes after "
                         "re-prefill instead of restarting")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="arm the deterministic fault injector "
                         "(core/faults.py), e.g. 'seed=7,"
                         "decode_step=0.02,restore_stall=0.3,stall_s=2'; "
                         "identical SPECs replay bit-identically on "
                         "either backend")
    ap.add_argument("--drain-after", type=float, default=None, metavar="T",
                    help="work-preserving drain/resume smoke (--backend "
                         "sim): checkpoint a run at T virtual seconds, "
                         "JSON round-trip, resume on a COLD loop and "
                         "require token ids bit-identical to an "
                         "uninterrupted reference")
    args = ap.parse_args()
    plan = FaultPlan.parse(args.fault_plan) if args.fault_plan else None
    # an explicit host budget means the user wants the tier on — don't
    # silently discard their sizing because --kv-spill was omitted
    args.kv_spill = args.kv_spill or args.host_pool_tokens is not None
    args.prefix_cache = (args.prefix_cache or args.sessions > 0
                         or args.kv_spill)
    args.paged = args.paged or args.prefix_cache

    if args.smoke:
        cfg = get_smoke_config(args.arch, max_seq_len=256)
    else:
        cfg = get_config(args.arch)
    if args.kv_dtype == "int8" and cfg.kv_cache_dtype != "int8":
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if args.kv_spill and args.host_pool_tokens is None:
        args.host_pool_tokens = 4 * (args.pool_tokens
                                     or args.slots * cfg.max_seq_len)
    if not args.kv_spill:
        args.host_pool_tokens = None
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; serve prefill-only "
                         "workloads via max_new_tokens=1")

    if args.trace_replay:
        tw = TraceWorkload(args.trace_replay)
        reqs = tw.requests()
        print(f"replaying {len(reqs)} recorded requests from "
              f"{args.trace_replay} (meta: {tw.meta})")
    elif args.sessions:
        # multi-turn conversations: lengths are sized to FIT the
        # window up front (a later clamp would break the loop's
        # transcript composition, which must hit prompt_len exactly)
        per_turn = max(cfg.max_seq_len // (2 * args.turns) - 8, 8)
        spec = WorkloadSpec(dataset=args.dataset, rps=args.rps,
                            max_model_len=cfg.max_seq_len,
                            vocab_size=cfg.vocab_size,
                            sessions=args.sessions, turns=args.turns,
                            utterance_tokens=per_turn, max_new_tokens=8,
                            think_time_s=args.think_time)
        reqs = generate(spec)
    elif args.burst_factor > 1.0:
        # heterogeneous trace family: three-class mix under bursty
        # diurnal arrivals (per-class SLOs ride on each request)
        spec = WorkloadSpec(dataset=args.dataset, rps=args.rps,
                            n_requests=args.requests,
                            max_model_len=cfg.max_seq_len,
                            prefix_groups=args.prefix_scenarios,
                            prefix_tokens=args.prefix_tokens,
                            vocab_size=cfg.vocab_size,
                            class_mix=DEFAULT_CLASS_MIX,
                            burst_factor=args.burst_factor)
        reqs = generate(spec)
        for r in reqs:   # keep CPU smoke runs short
            r.max_new_tokens = min(r.max_new_tokens, 8)
            r.prompt_len = min(r.prompt_len, cfg.max_seq_len - 16)
    else:
        spec = WorkloadSpec(dataset=args.dataset, rps=args.rps,
                            n_requests=args.requests,
                            max_model_len=cfg.max_seq_len,
                            prefix_groups=args.prefix_scenarios,
                            prefix_tokens=args.prefix_tokens,
                            vocab_size=cfg.vocab_size)
        reqs = generate(spec)
        for r in reqs:   # keep CPU smoke runs short
            r.max_new_tokens = min(r.max_new_tokens, 8)
            r.prompt_len = min(r.prompt_len, cfg.max_seq_len - 16)

    # the recorder doubles as the replay checker: both a recorded run
    # and its replay print the formed-batch log, so CI can diff them
    recorder = TraceRecorder() if (args.trace_record
                                   or args.trace_replay) else None
    tracer = Tracer() if args.trace_out else None

    if args.drain_after is not None:
        if args.backend != "sim":
            raise SystemExit("--drain-after is a cost-model smoke: "
                             "use --backend sim")
        _drain_resume_sim(cfg, args, reqs, plan)
        return

    if args.backend == "sim":
        _run_sim(cfg, args, reqs, recorder, tracer, plan)
        _finish_trace(args, recorder)
        _finish_timeline(args, tracer)
        return

    mesh = None
    if args.data * args.model > 1:
        mesh = make_host_mesh(args.data, args.model)
        shctx.set_mesh(mesh)

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    if mesh is not None:
        specs = partition.param_specs(cfg, params, mesh)
        params = jax.device_put(params, partition.to_shardings(mesh, specs))
        print(f"mesh: {dict(mesh.shape)}; params sharded")

    budget = MemoryBudget(hbm_bytes_per_device=16 * 2 ** 30,
                          n_devices=max(args.data * args.model, 1),
                          weight_bytes=cfg.param_count() * 2)
    sched = _make_sched(cfg, budget, args)
    engine = ServingEngine(cfg, params, sched, max_slots=args.slots,
                           cache_len=cfg.max_seq_len,
                           moe_impl="local", chunk_tokens=args.chunk,
                           paged=args.paged, page_size=args.page_size,
                           kv_pool_tokens=args.pool_tokens,
                           prefix_cache=args.prefix_cache,
                           session_ttl=args.session_ttl if args.sessions
                           else None,
                           host_pool_tokens=args.host_pool_tokens,
                           spill_bw=args.spill_bw * 1e9,
                           spill_dtype=args.spill_dtype,
                           slice_tokens=args.slice_tokens,
                           recorder=recorder, tracer=tracer,
                           fault_plan=plan)

    engine.submit(reqs)
    t0 = time.perf_counter()
    done = engine.run(max_wall_s=900)
    dt = time.perf_counter() - t0
    toks = sum(r.generated for r in done)
    paged_info = ""
    if args.paged:
        be = engine.backend
        paged_info = (f"pages: {be.alloc.n_pages} x {be.page_size} tok, "
                      f"free {be.free_blocks()}; "
                      f"peak pool {engine.result.peak_pool}; "
                      f"preemptions {engine.result.preempt_events}; ")
        if args.prefix_cache:
            r = engine.result
            paged_info += (
                f"prefix hits {r.prefix_hits}/{r.prefix_lookups} "
                f"({r.prefix_hit_rate():.2f}), {r.prefill_tokens_skipped} "
                f"prompt tokens skipped, {r.prefix_pages_saved} pages "
                f"saved, {r.shared_pages_peak} peak shared; ")
        if args.sessions:
            r = engine.result
            paged_info += (
                f"session hits {r.session_hits}/{r.session_lookups}, "
                f"{r.session_hit_tokens} transcript tokens restored, "
                f"{r.tail_pages_reused} tails reused, "
                f"{r.sessions_retained} retained; ")
        if args.kv_spill:
            r = engine.result
            paged_info += (
                f"spill[{args.spill_dtype}]: {r.spilled_pages} pages "
                f"({r.spilled_bytes} B) out, "
                f"{r.restored_pages} back ({r.restored_tokens} tokens, "
                f"{r.restored_bytes} B), "
                f"{r.spill_drops} dropped, "
                f"{r.spill_hold_events} holds; ")
    print(f"served {len(done)}/{len(reqs)} requests, {toks} tokens in "
          f"{dt:.1f}s; prefill shapes: {engine.n_prefill_shapes}; "
          f"decode steps interleaved between prefill chunks: "
          f"{engine.interleaved_decode_steps}; {paged_info}"
          f"buckets: {[(b.low, b.up) for b in sched.buckets.buckets]}")
    print(_tail_line(engine.result))
    print(f"kv util (time-weighted) "
          f"{engine.result.kv_util_time_weighted:.2f}; padding waste "
          f"{engine.result.padding_waste_ratio():.3f}; "
          f"blame {_fmt_blame(engine.result.blame())}")
    if plan is not None:
        print(_fault_line(engine.result, plan))
    _finish_trace(args, recorder)
    _finish_timeline(args, tracer)


if __name__ == "__main__":
    main()
