"""Training launcher with mesh-sharded params (pjit/GSPMD).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
        --steps 50 [--data 2 --model 2] [--ckpt results/ckpt.npz]

--smoke trains the reduced config on CPU (real steps, loss must drop);
without it the full config is sharded per repro/sharding/partition.py —
on this container that is only useful with fake devices (see dryrun for
the compile-only path).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config, list_archs
from repro.data import tokens as data_tokens
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.sharding import context as shctx
from repro.sharding import partition
from repro.train import checkpoint, optimizer, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if args.data * args.model > 1:
        mesh = make_host_mesh(args.data, args.model)
        shctx.set_mesh(mesh)

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    opt_cfg = optimizer.AdamWConfig(lr=args.lr, warmup_steps=10,
                                    total_steps=args.steps)
    opt_state = optimizer.init(params)
    mi = "local" if not cfg.n_experts or mesh is None else "ep"
    step_fn = train_loop.make_train_step(cfg, opt_cfg, moe_impl=mi,
                                         mesh=mesh, remat=not args.smoke)
    if mesh is not None:
        pspec = partition.param_specs(cfg, params, mesh)
        sh = lambda t: partition.to_shardings(mesh, t)
        params = jax.device_put(params, sh(pspec))
        opt_state = jax.device_put(
            opt_state, sh({"m": pspec, "v": pspec,
                           "step": jax.sharding.PartitionSpec()}))
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn)

    it = data_tokens.batches(cfg, args.batch, args.seq)
    t0 = time.perf_counter()
    first_loss = last_loss = None
    for step in range(args.steps):
        params, opt_state, metrics = step_fn(params, opt_state, next(it))
        if step == 0:
            first_loss = float(metrics["loss"])
        last_loss = float(metrics["loss"])
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={last_loss:.4f} "
                  f"lr={float(metrics['lr']):.2e}", flush=True)
    dt = time.perf_counter() - t0
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s); "
          f"loss {first_loss:.3f} -> {last_loss:.3f}")
    if args.ckpt:
        checkpoint.save(args.ckpt, params, opt_state,
                        meta={"steps": args.steps})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
