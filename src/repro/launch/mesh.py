"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (jax locks the device count on first init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by distributed tests and the serve/train launchers."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def batch_axes(mesh, batch_size: int):
    """Largest prefix of (pod, data) axes that divides batch_size."""
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    use = []
    div = 1
    for n in names:
        size = mesh.shape[n]
        if batch_size % (div * size) == 0:
            use.append(n)
            div *= size
    if not use:
        return None
    return tuple(use) if len(use) > 1 else use[0]
