"""HLO-module analysis: roofline terms from the compiled dry-run.

XLA:CPU's ``compiled.cost_analysis()`` counts each ``lax.scan`` body ONCE
(while-loop trip counts are ignored), which under-reports flops/bytes by
~n_layers for scanned models — useless for roofline work.  This module
parses the optimized HLO text instead:

  * per-computation symbol tables (instruction -> shape);
  * dot FLOPs = 2 · prod(output dims) · prod(lhs contracting dims);
  * HBM bytes ≈ Σ operand+output bytes of materializing top-level ops
    (post-fusion HLO materializes exactly fusion/dot/copy/collective
    outputs, so this approximates true traffic well);
  * while loops multiply their body by the trip count recovered from the
    loop-condition constant;
  * collective bytes per kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), trip-count aware.

All numbers are PER-DEVICE (the partitioned module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# v5e hardware constants (task spec)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # per chip
ICI_BW = 50e9                # per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[\w\[\]{},\s/]*?\)?)\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_ATTR_COMP = re.compile(r"(?:body|to_apply|condition|branch_computations)="
                        r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
    # `convert` at top level is XLA:CPU's bf16<->f32 staging (the TPU MXU
    # and VPU are bf16-native); counting it would charge the roofline for
    # traffic that does not exist on the target. (DESIGN.md §3)
    "convert",
    # loop/branch state is accounted inside their bodies, not at the op
    "while", "conditional",
}


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    return [(d, [int(x) for x in dims.split(",") if x])
            for d, dims in _SHAPE_RE.findall(shape_str)]


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    rest: str          # everything after the opening '('


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.instrs: List[_Instr] = []
        self.shapes: Dict[str, str] = {}

    def add(self, instr: _Instr):
        self.instrs.append(instr)
        self.shapes[instr.name] = instr.shape


def parse_module(hlo_text: str) -> Tuple[Dict[str, _Computation], str]:
    comps: Dict[str, _Computation] = {}
    entry = None
    cur: Optional[_Computation] = None
    for line in hlo_text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", line)   # strip /*index=N*/ comments
        stripped = line.strip()
        if not stripped:
            continue
        hdr = _COMP_HDR.match(line if not line.startswith(" ") else "")
        if hdr:
            cur = _Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            cur.add(_Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
    assert entry is not None, "no ENTRY computation found"
    return comps, entry


def _trip_count(comps, cond_name: str) -> int:
    """Scan conds compare the induction var against the trip count: find
    the compare instruction and resolve its constant operand."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.match(r"(\d+)\)?", ins.rest)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.op == "compare":
            for opnd in _OPERAND.findall(ins.rest):
                if opnd in consts:
                    return max(1, consts[opnd])
    return max(consts.values(), default=1)


def _dot_flops(comp: _Computation, ins: _Instr) -> float:
    out = 1
    for _, dims in _shape_dims(ins.shape):
        for d in dims:
            out *= d
    ops = _OPERAND.findall(ins.rest)
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if m and ops:
        lhs_shape = comp.shapes.get(ops[0], "")
        dims_list = _shape_dims(lhs_shape)
        if dims_list:
            lhs_dims = dims_list[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(lhs_dims):
                    k *= lhs_dims[idx]
    return 2.0 * out * k


@dataclasses.dataclass
class ModuleStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def scaled(self, n: int) -> "ModuleStats":
        return ModuleStats(self.flops * n, self.bytes * n,
                           {k: v * n for k, v in self.coll.items()})

    def __iadd__(self, o: "ModuleStats"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in self.coll:
            self.coll[k] += o.coll[k]
        return self


def _module_fused_names(comps) -> set:
    """Module-wide fixpoint: instruction names whose values are
    kernel-internal (vmem_fused / grouped_mm support tensors), propagated
    through metadata-less layout ops AND loop/tuple boundaries (the dense
    ragged-VJP intermediates travel through while carries — §Perf 2c)."""
    _PASS = ("transpose", "copy", "reshape", "convert", "bitcast",
             "broadcast", "get-tuple-element")
    fused = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if "vmem_fused:" in ins.rest or (
                    "grouped_mm:" in ins.rest
                    and ins.op not in ("dot", "dot_general")):
                fused.add(ins.name)
    for _ in range(6):            # fixpoint (chains are short)
        grew = False
        for comp in comps.values():
            for ins in comp.instrs:
                if ins.name in fused or ins.op not in _PASS:
                    continue
                if "op_name=" in ins.rest and "fused" not in ins.rest:
                    continue
                ops0 = _OPERAND.findall(ins.rest)
                if ops0 and ops0[0] in fused:
                    fused.add(ins.name)
                    grew = True
        if not grew:
            break
    return fused


def _body_fused_fraction(comp) -> float:
    """Fraction of metadata-carrying instrs inside a vmem_fused scope —
    GSPMD drops metadata on some rewritten ops, so whole-body majority
    vote beats per-op checks for the kernel-fusion model."""
    with_md = [i for i in comp.instrs if "op_name=" in i.rest]
    if not with_md:
        return 0.0
    return sum("vmem_fused:" in i.rest for i in with_md) / len(with_md)


def _eval_comp(comps, name: str, memo, trace=None, mult=1,
               fused_kernels=False, force_fused=False,
               fused_names=None) -> ModuleStats:
    key = (name, force_fused)
    if key in memo and trace is None:
        return memo[key]
    comp = comps.get(name)
    stats = ModuleStats()
    if comp is None:
        memo[key] = stats
        return stats
    memo[key] = stats        # guard cycles
    fused_names = fused_names if fused_names is not None else set()
    for ins in comp.instrs:
        opb = 0
        fused_away = force_fused or (
            fused_kernels and (ins.name in fused_names
                               or "vmem_fused:" in ins.rest))
        if ins.op not in _SKIP_BYTES_OPS and not fused_away \
                and not _is_pure_convert(comps, ins):
            out_b = _shape_bytes(ins.shape)
            operand_bytes = [
                _shape_bytes(comp.shapes.get(opnd, ""))
                for opnd in _OPERAND.findall(
                    ins.rest.split("), ")[0] if ")" in ins.rest
                    else ins.rest)]
            if ins.op == "fusion" and not _fusion_reduces(comps, ins):
                # kLoop fusions stream element-wise (or slice a window out
                # of a big operand): each operand contributes at most what
                # the fusion actually touches ~ its output extent.
                operand_bytes = [min(b, out_b) for b in operand_bytes]
            elif ins.op in ("dynamic-slice", "slice", "gather"):
                # reads only the slice, and the "write" fuses into the
                # consumer on TPU: count the slice once.
                operand_bytes = []
            opb = out_b + sum(operand_bytes)
            if _is_inplace_update(comps, comp, ins) and operand_bytes:
                # dynamic-update-slice / scatter execute IN PLACE under
                # buffer donation: true HBM traffic is ~2x the update
                # slice, not target+output.  Drop the aliased target.
                big = max(operand_bytes)
                opb = max(0, opb - big - min(out_b, big))
        kind = next((c for c in _COLLECTIVES if ins.op.startswith(c)), None)
        if kind and not ins.op.endswith("-done"):
            stats.coll[kind] += _shape_bytes(ins.shape)
            stats.bytes += opb
        elif ins.op in ("dot", "dot_general"):
            f = _dot_flops(comp, ins)
            gm = re.search(r"grouped_mm:(\d+)", ins.rest)
            if gm:
                # XLA:CPU lowers ragged_dot densely (all E experts per
                # row); the TPU grouped matmul computes active rows only.
                f /= max(int(gm.group(1)), 1)
            stats.flops += f
            stats.bytes += opb
        elif ins.op == "while":
            m = _ATTR_COMP_BODY.search(ins.rest)
            body = cond = None
            mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
            mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            if mb:
                body = mb.group(1)
            if mc:
                cond = mc.group(1)
            trips = _trip_count(comps, cond) if cond else 1
            if body:
                bf = force_fused or (
                    fused_kernels and
                    _body_fused_fraction(comps.get(body, _Computation("")))
                    > 0.5)
                stats += _eval_comp(comps, body, memo, trace, mult * trips,
                                    fused_kernels, bf,
                                    fused_names).scaled(trips)
        elif ins.op in ("fusion", "reduce", "map", "sort", "scatter",
                        "reduce-window", "select-and-scatter"):
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.rest)
            if m:
                stats += _eval_comp(comps, m.group(1), memo, trace, mult,
                                    fused_kernels, force_fused,
                                    fused_names)
            stats.bytes += opb
        elif ins.op == "call":
            m = re.search(r"to_apply=%?([\w.\-]+)", ins.rest)
            if m:
                stats += _eval_comp(comps, m.group(1), memo)
        elif ins.op == "conditional":
            m = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
            if m:
                branches = [_eval_comp(comps, b.strip().lstrip("%"), memo)
                            for b in m.group(1).split(",")]
                if branches:
                    big = max(branches, key=lambda s: s.flops + s.bytes)
                    stats += big
            stats.bytes += opb
        elif ins.op in ("convolution",):
            stats.flops += 2.0 * _shape_bytes(ins.shape)  # coarse fallback
            stats.bytes += opb
        else:
            stats.bytes += opb
        if trace is not None and opb * mult > trace:
            print(f"  [trace] {opb*mult/2**30:8.2f}GiB x{mult:<4d} {ins.op:>18s} {ins.shape[:52]} {ins.rest[:60]}")
    memo[key] = stats
    return stats


_ATTR_COMP_BODY = re.compile(r"body=%?([\w.\-]+)")

_INPLACE_OPS = {"dynamic-update-slice", "scatter", "select-and-scatter"}


def _fusion_reduces(comps, ins) -> bool:
    m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
    callee = comps.get(m.group(1)) if m else None
    if callee is None:
        return False
    return any(i.op in ("reduce", "reduce-window") for i in callee.instrs)


def _is_pure_convert(comps, ins) -> bool:
    """bf16->f32 convert fusions are XLA:CPU artifacts — the TPU MXU eats
    bf16 natively, so their traffic must not count toward the roofline."""
    if ins.op != "fusion":
        return False
    m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
    callee = comps.get(m.group(1)) if m else None
    if callee is None:
        return False
    real = [i for i in callee.instrs
            if i.op not in ("parameter", "bitcast", "copy", "transpose",
                            "reshape")]
    return bool(real) and all(i.op == "convert" for i in real)


def _is_inplace_update(comps, comp, ins) -> bool:
    if ins.op in _INPLACE_OPS:
        return True
    if ins.op == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
        callee = comps.get(m.group(1)) if m else None
        if callee and callee.instrs:
            return any(i.op in _INPLACE_OPS for i in callee.instrs[-2:])
    return False


def module_stats(hlo_text: str, trace=None,
                 fused_kernels: bool = False) -> ModuleStats:
    """fused_kernels=True models ops inside `vmem_fused:*` named scopes
    as VMEM-resident (zero HBM bytes) — they correspond 1:1 to the Pallas
    kernels in repro/kernels (flash_prefill, flash_decode, wkv6), so this
    is the roofline of the kernel-enabled deployment.  FLOPs and
    collectives are unaffected."""
    comps, entry = parse_module(hlo_text)
    fused_names = _module_fused_names(comps) if fused_kernels else set()
    return _eval_comp(comps, entry, {}, trace, 1, fused_kernels, False,
                      fused_names)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    return dict(module_stats(hlo_text).coll)


@dataclasses.dataclass
class RooflineTerms:
    """All per-device: the partitioned HLO module is one device's program."""
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    n_devices: int
    model_flops: float           # global useful flops (6ND / 2ND)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.flops_per_device
                                      * self.n_devices, 1.0)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "n_devices": self.n_devices, "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
        }


def analyze(compiled, n_devices: int, model_flops: float,
            fused_kernels: bool = False) -> RooflineTerms:
    stats = module_stats(compiled.as_text(), fused_kernels=fused_kernels)
    return RooflineTerms(
        flops_per_device=stats.flops,
        bytes_per_device=stats.bytes,
        coll_bytes_per_device=sum(stats.coll.values()),
        n_devices=n_devices,
        model_flops=model_flops,
    )
