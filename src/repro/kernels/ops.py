"""Jit'd public wrappers around the Pallas kernels.

``use_pallas(True/False)`` / the ``REPRO_USE_PALLAS`` env var pick between
the kernel path and the pure-jnp reference (models/attention.py et al.).
On this CPU container the kernels run in interpret mode; on TPU set
``interpret=False`` via ``configure(interpret=False)``.
"""
from __future__ import annotations

import functools
import os

import jax

from . import decode_attn as _decode
from . import flash_prefill as _prefill
from . import paged_decode_attn as _paged
from . import wkv6 as _wkv6
from . import ref

_STATE = {
    "use_pallas": os.environ.get("REPRO_USE_PALLAS", "0") == "1",
    "interpret": os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1",
}


def configure(use_pallas: bool | None = None, interpret: bool | None = None):
    if use_pallas is not None:
        _STATE["use_pallas"] = use_pallas
    if interpret is not None:
        _STATE["interpret"] = interpret


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_prefill(q, k, v, lengths=None, *, causal=True, window=0,
                  interpret=True):
    return _prefill.flash_prefill(q, k, v, lengths, causal=causal,
                                  window=window, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("ring", "interpret"))
def flash_decode(q, k_cache, v_cache, pos, *, ring=False, interpret=True):
    return _decode.flash_decode(q, k_cache, v_cache, pos, ring=ring,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6(r, k, v, w, u, s0, *, interpret=True):
    return _wkv6.wkv6(r, k, v, w, u, s0, interpret=interpret)


def prefill_attention(q, k, v, lengths=None, *, causal=True, window=0):
    """Dispatcher used by the engine: Pallas kernel or jnp reference."""
    if _STATE["use_pallas"]:
        return flash_prefill(q, k, v, lengths, causal=causal, window=window,
                             interpret=_STATE["interpret"])
    return ref.flash_prefill_ref(q, k, v, lengths, causal=causal,
                                 window=window)


def decode_attention(q, k_cache, v_cache, pos, *, ring=False):
    if _STATE["use_pallas"]:
        return flash_decode(q, k_cache, v_cache, pos, ring=ring,
                            interpret=_STATE["interpret"])
    return ref.flash_decode_ref(q, k_cache, v_cache, pos, ring=ring)


@functools.partial(jax.jit, static_argnames=("s_len", "ring", "interpret"))
def paged_flash_decode(q, k_pool, v_pool, block_tables, pos, *, s_len,
                       ring=False, interpret=True):
    return _paged.paged_flash_decode(q, k_pool, v_pool, block_tables, pos,
                                     s_len=s_len, ring=ring,
                                     interpret=interpret)


def paged_decode_attention(q, k_pool, v_pool, block_tables, pos, *, s_len,
                           ring=False):
    """Dispatcher: Pallas paged kernel (scalar-prefetched block tables)
    or the gather-then-attend jnp reference."""
    if _STATE["use_pallas"]:
        return paged_flash_decode(q, k_pool, v_pool, block_tables, pos,
                                  s_len=s_len, ring=ring,
                                  interpret=_STATE["interpret"])
    return ref.paged_flash_decode_ref(q, k_pool, v_pool, block_tables, pos,
                                      s_len=s_len, ring=ring)
