"""Pallas TPU kernel: causal/windowed FlashAttention for prefill.

TPU adaptation (DESIGN.md §3): instead of a CUDA warp-tiled kernel we
block HBM->VMEM transfers with ``BlockSpec`` and keep the running-softmax
statistics (m, l) and the output accumulator in VMEM scratch across the
sequential innermost grid dimension (TPU grids iterate minor-to-major on
a single core, so scratch persists across the kv-block loop).  Matmul
dims are multiples of 128 so both score and value products hit the MXU.

VMEM working set per grid step (defaults blk_q = blk_k = 128, Dh <= 256):
    q tile        128 x 256 x 4B = 128 KiB
    k,v tiles   2 x 128 x 256 x 4B = 256 KiB
    acc + stats  128 x 256 x 4B + 2 x 128 x 4B ~= 129 KiB
  ~= 0.5 MiB << 16 MiB VMEM  ->  plenty of room for double buffering.

Grid: (B, H, n_qblocks, n_kvblocks); GQA is handled by indexing the kv
head ``h // group`` in the k/v BlockSpecs.  Causally dead (q,kv) blocks
are skipped with ``pl.when`` (zero compute, still iterated).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            blk_q: int, blk_k: int, n_kv: int, causal: bool, window: int,
            scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * blk_q
    k_start = ki * blk_k
    live = True
    if causal:
        live = k_start <= q_start + blk_q - 1
    if window:
        live = jnp.logical_and(live, q_start - (k_start + blk_k - 1) < window)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (blk_q, Dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (blk_k, Dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < lens_ref[0]
        if causal:
            ok = jnp.logical_and(ok, qpos >= kpos)
        if window:
            ok = jnp.logical_and(ok, qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_prefill(q, k, v, lengths=None, *, causal: bool = True,
                  window: int = 0, blk_q: int = 128, blk_k: int = 128,
                  interpret: bool = True):
    """q: (B,T,H,Dh); k,v: (B,T,Hkv,Dh); lengths: (B,) valid key counts.

    Returns (B,T,H,Dh).  ``interpret=True`` executes the kernel body in
    Python on CPU (this container); on TPU pass interpret=False.
    """
    B, T, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    blk_q = min(blk_q, T)
    blk_k = min(blk_k, T)
    pad_q = (-T) % blk_q
    pad_k = (-T) % blk_k
    qt = jnp.moveaxis(q, 2, 1)                      # (B,H,T,Dh)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[2] // blk_q
    nk = kt.shape[2] // blk_k

    kern = functools.partial(
        _kernel, blk_q=blk_q, blk_k=blk_k, n_kv=nk, causal=causal,
        window=window, scale=Dh ** -0.5)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, qi, ki: (b,)),
            pl.BlockSpec((1, 1, blk_q, Dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, blk_k, Dh),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, blk_k, Dh),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, Dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qt, kt, vt)
    out = out[:, :, :T] if pad_q else out
    return jnp.moveaxis(out, 1, 2)
