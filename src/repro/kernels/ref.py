"""Pure-jnp oracles for every Pallas kernel (shape-for-shape equivalent)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_ref
from repro.models import rwkv as rwkv_ref


def flash_prefill_ref(q, k, v, lengths=None, *, causal=True, window=0):
    """Oracle for kernels.flash_prefill (exact softmax attention)."""
    return attn_ref.full_attention(q, k, v, causal=causal, lengths=lengths,
                                   window=window)


def flash_decode_ref(q, k_cache, v_cache, pos, *, ring=False):
    """Oracle for kernels.decode_attn.flash_decode."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    out = attn_ref.decode_attention(
        q, k_cache, v_cache, pos, window=k_cache.shape[1] if ring else 0)
    return out[:, 0] if squeeze else out


def paged_flash_decode_ref(q, k_pool, v_pool, block_tables, pos, *,
                           s_len, ring=False):
    """Oracle for kernels.paged_decode_attn.paged_flash_decode: gather
    pages into the contiguous layout, then contiguous decode attention
    (page placement must not change results)."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    out = attn_ref.paged_decode_attention(
        q, k_pool, v_pool, block_tables, pos, s_len=s_len,
        window=s_len if ring else 0)
    return out[:, 0] if squeeze else out


def wkv6_ref(r, k, v, w, u, s0):
    """Oracle for kernels.wkv6 (lax.scan over time)."""
    return rwkv_ref.wkv_scan(r, k, v, w, u, s0)
