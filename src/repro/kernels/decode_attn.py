"""Pallas TPU kernel: GQA flash-decode (one query token vs. KV cache).

Decode attention is memory-bound: the whole KV cache streams HBM->VMEM
once per step while compute is tiny.  The kernel therefore optimizes for
bandwidth: the cache is blocked along the sequence axis (innermost,
sequential grid dim), all G query heads of one kv head are processed
together (amortizing each K/V tile across G score rows — a GQA-specific
arithmetic-intensity win: bytes/token drop by G vs. per-head kernels),
and running softmax stats live in VMEM scratch.

Supports ring-buffer (sliding-window) caches: validity of slot ``s`` is
``s <= pos  or  pos >= S`` — softmax is permutation-invariant so ring
order never matters (see models/attention.py).

Grid: (B, Hkv, n_sblocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            blk_s: int, n_s: int, s_orig: int, ring: bool, scale: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (blk_s, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = pos_ref[0]
    slot = si * blk_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = slot <= pos
    if ring:
        valid = jnp.logical_or(valid, pos >= s_orig)
    valid = jnp.logical_and(valid, slot < s_orig)   # seq-padding slots dead
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, pos, *, ring: bool = False,
                 blk_s: int = 512, interpret: bool = True):
    """q: (B,1,H,Dh) or (B,H,Dh); caches: (B,S,Hkv,Dh); pos: (B,).

    Returns (B,1,H,Dh).  ``ring=True`` for sliding-window ring caches.
    """
    squeeze = q.ndim == 4
    if q.ndim == 4:
        q = q[:, 0]
    B, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    blk_s = min(blk_s, S)
    pad_s = (-S) % blk_s
    # The cache is consumed in its NATIVE (B, S, Hkv, Dh) layout — the
    # BlockSpec index map picks (b, si, h) tiles directly, so no transpose
    # of the multi-GiB cache ever materializes (§Perf iteration 3: a
    # relayout was measured 2.4x worse; tiling beats relayout).
    if pad_s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    n_s = k_cache.shape[1] // blk_s
    qg = q.reshape(B, Hkv, G, Dh)

    kern = functools.partial(_kernel, blk_s=blk_s, n_s=n_s, s_orig=S,
                             ring=ring, scale=Dh ** -0.5)
    out = pl.pallas_call(
        kern,
        grid=(B, Hkv, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, si: (b,)),
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, si: (b, h, 0, 0)),
            pl.BlockSpec((1, blk_s, 1, Dh), lambda b, h, si: (b, si, h, 0)),
            pl.BlockSpec((1, blk_s, 1, Dh), lambda b, h, si: (b, si, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, si: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(pos.astype(jnp.int32), qg, k_cache, v_cache)
    out = out.reshape(B, H, Dh)
    return out[:, None] if squeeze else out
