"""Pallas TPU kernel: GQA flash-decode over a PAGED KV cache.

Same bandwidth-bound problem as ``decode_attn.flash_decode`` — one query
token streams the whole KV cache HBM->VMEM — but the cache is no longer
a contiguous (B, S, Hkv, Dh) tensor.  It is a shared page POOL
(n_pages, page_size, Hkv, Dh) plus a per-request block table
(B, pages_per_seq): virtual slot ``s`` of request ``b`` lives in page
``block_tables[b, s // page_size]`` at offset ``s % page_size``
(DESIGN.md §3).

The indirection is done with SCALAR PREFETCH: the block table and the
per-request positions are ``PrefetchScalarGridSpec`` operands, so the
k/v BlockSpec index maps read ``bt[b, j]`` and DMA exactly the page the
(b, j) grid step needs — the pool is never gathered into a contiguous
cache in HBM.  Everything else mirrors the contiguous kernel: grid
(B, Hkv, n_pages_per_seq) with the page axis innermost/sequential, all
G query heads of one kv head processed together, running-softmax stats
in VMEM scratch.

Ring/sliding-window validity is preserved: position ``p`` lives at
virtual slot ``p % s_len`` and slot ``s`` is valid iff
``s <= pos or pos >= s_len`` — softmax is permutation-invariant, so
neither ring order nor PAGE order matters (models/attention.py).
Virtual slots past ``s_len`` (the partially-dead last page of a
non-divisible cache length) are masked exactly like sequence padding in
the contiguous kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, page: int, n_p: int, s_len: int, ring: bool,
            scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (page, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = pos_ref[b]
    slot = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = slot <= pos
    if ring:
        valid = jnp.logical_or(valid, pos >= s_len)
    valid = jnp.logical_and(valid, slot < s_len)    # dead tail of last page
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_p - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_flash_decode(q, k_pool, v_pool, block_tables, pos, *,
                       s_len: int, ring: bool = False,
                       interpret: bool = True):
    """q: (B,1,H,Dh) or (B,H,Dh); pools: (n_pages, page, Hkv, Dh);
    block_tables: (B, pages_per_seq) int32; pos: (B,).

    ``s_len`` is the request-level cache length (validity bound and ring
    modulus) — at most ``pages_per_seq * page``; the slack is the dead
    tail of the last page.  Returns the same shape as ``q``.
    """
    squeeze = q.ndim == 4
    if q.ndim == 4:
        q = q[:, 0]
    B, H, Dh = q.shape
    n_pages, page, Hkv = k_pool.shape[:3]
    G = H // Hkv
    n_p = block_tables.shape[1]
    assert s_len <= n_p * page, (s_len, n_p, page)
    qg = q.reshape(B, Hkv, G, Dh)
    # unallocated table tail entries may be garbage: valid-slot masking
    # hides their values, but the index map must still be in range
    bt = jnp.clip(block_tables.astype(jnp.int32), 0, n_pages - 1)

    kern = functools.partial(_kernel, page=page, n_p=n_p, s_len=s_len,
                             ring=ring, scale=Dh ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # block tables + positions
        grid=(B, Hkv, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, bt, pos: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, Dh),
                         lambda b, h, j, bt, pos: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, Dh),
                         lambda b, h, j, bt, pos: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, h, j, bt, pos: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(bt, pos.astype(jnp.int32), qg, k_pool, v_pool)
    out = out.reshape(B, H, Dh)
    return out[:, None] if squeeze else out
