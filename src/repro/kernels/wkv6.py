"""Pallas TPU kernel: RWKV6 WKV recurrence, time-blocked.

The WKV scan is inherently sequential in time (data-dependent decay), so
the TPU win is not parallelism-over-time but *state residency*: the
(hs x hs) per-head state matrix stays in VMEM scratch across the whole
sequence while r/k/v/w stream through in time blocks (one HBM read each,
no state round-trips — a lax.scan materializes the carry through HBM
between steps).  Inside a block we run a fori_loop of rank-1 updates on
the VMEM-resident state.

A chunked matmul formulation (process blk_t steps as one MXU contraction
using cumulative-decay ratios) is the classic GPU approach; its decay
ratios ``exp(cum[t]-cum[s])`` overflow f32 for strongly-decaying
channels, so we keep the numerically exact sequential-in-block form and
note the chunked variant as future work (EXPERIMENTS.md §Perf).

Grid: (B, H, n_tblocks) — time innermost (sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
            state_ref, *, blk_t: int, n_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                      # (hs,)

    def step(t, _):
        rt = r_ref[0, 0, t].astype(jnp.float32)           # (hs,)
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        s = state_ref[...]                                # (hs, hs) [k, v]
        kv = kt[:, None] * vt[None, :]
        y = jnp.einsum("k,kv->v", rt, s + u[:, None] * kv)
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        state_ref[...] = wt[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, blk_t, step, 0)

    @pl.when(ti == n_t - 1)
    def _fin():
        sT_ref[0, 0] = state_ref[...].astype(sT_ref.dtype)


def wkv6(r, k, v, w, u, s0, *, blk_t: int = 64, interpret: bool = True):
    """r,k,v,w: (B,T,H,hs); u: (H,hs); s0: (B,H,hs,hs).

    Returns (y (B,T,H,hs) f32, sT (B,H,hs,hs) f32).  Padding: callers mask
    w=1, k=0 on padded steps (identity update) — see models/rwkv.py.
    """
    B, T, H, hs = r.shape
    blk_t = min(blk_t, T)
    pad_t = (-T) % blk_t
    rt, kt, vt, wt = (jnp.moveaxis(x, (1, 2), (2, 1)) for x in (r, k, v, w))
    if pad_t:
        # identity updates on padding: w=1, k=0 -> state untouched
        rt = jnp.pad(rt, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        wt = jnp.pad(wt, ((0, 0), (0, 0), (0, pad_t), (0, 0)),
                     constant_values=1.0)
    n_t = rt.shape[2] // blk_t

    kern = functools.partial(_kernel, blk_t=blk_t, n_t=n_t)
    y, sT = pl.pallas_call(
        kern,
        grid=(B, H, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, blk_t, hs), lambda b, h, ti: (b, h, ti, 0)),
            pl.BlockSpec((1, 1, blk_t, hs), lambda b, h, ti: (b, h, ti, 0)),
            pl.BlockSpec((1, 1, blk_t, hs), lambda b, h, ti: (b, h, ti, 0)),
            pl.BlockSpec((1, 1, blk_t, hs), lambda b, h, ti: (b, h, ti, 0)),
            pl.BlockSpec((1, hs), lambda b, h, ti: (h, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda b, h, ti: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk_t, hs), lambda b, h, ti: (b, h, ti, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda b, h, ti: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, n_t * blk_t, hs), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hs, hs), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, s0)
    y = y[:, :, :T] if pad_t else y
    return jnp.moveaxis(y, (1, 2), (2, 1)), sT
