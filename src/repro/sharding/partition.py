"""Partition rules: params, caches, optimizer state, batches.

Strategy (DESIGN.md §5):
  * tensor-parallel over ``model``: fused q/kv/o projections, MLP d_ff,
    vocab embeddings, RWKV square projections, RG-LRU width;
  * expert-parallel over ``data`` + expert-ff over ``model`` for MoE
    (consumed by the shard_map EP path, models/moe.py);
  * batch over (pod, data) whenever divisible;
  * decode KV caches: kv-heads over ``model`` when divisible, else the
    SEQUENCE axis goes over ``model`` (bounds per-device cache bytes for
    the 100-layer VLM at 32k context — the thing that OOMs otherwise);
  * everything falls back to replication when a dim does not divide.

All rules are shape-driven (checked against the actual mesh axis sizes),
so the same code serves the 16x16 pod, the 2x16x16 multi-pod and tiny
test meshes.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.launch.mesh import batch_axes


def _div(n: int, mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def param_specs(cfg: ModelConfig, params, mesh):
    """PartitionSpec pytree matching `params` (which may be shapes)."""

    def rule(path, leaf):
        ndim = len(leaf.shape)
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        shape = leaf.shape

        def last2(spec_a, spec_b):
            """Spec for the trailing two dims, None-padded for scan dims."""
            return P(*([None] * (ndim - 2) + [spec_a, spec_b]))

        if name in ("embed", "unembed"):
            return P("model", None) if _div(shape[0], mesh, "model") \
                else P(None, None)
        if name == "vis_proj":
            return P(None, "model") if _div(shape[1], mesh, "model") \
                else P(None, None)
        if ndim < 2:
            return P(*([None] * ndim))
        # MoE experts: (R, E, d, f) / (R, E, f, d)
        if name in ("w_gate", "w_up"):
            e_ok = _div(shape[1], mesh, "data")
            f_ok = _div(shape[3], mesh, "model")
            return P(None, "data" if e_ok else None, None,
                     "model" if f_ok else None)
        if name == "w_down":
            e_ok = _div(shape[1], mesh, "data")
            f_ok = _div(shape[2], mesh, "model")
            return P(None, "data" if e_ok else None,
                     "model" if f_ok else None, None)
        if name == "router":
            return P(*([None] * ndim))
        # column-parallel (output dim sharded).  (§Perf 1b: a row-parallel
        # wk/wv variant measured neutral on the VLM and 1.7x WORSE on
        # recurrentgemma — reverted.)
        if name in ("wq", "wk", "wv", "gate", "up", "wx", "wg",
                    "wr", "wi", "ck", "cr"):
            return last2(None, "model") if _div(shape[-1], mesh, "model") \
                else P(*([None] * ndim))
        # row-parallel (input dim sharded, output reduced)
        if name in ("wo", "down", "cv"):
            return last2("model", None) if _div(shape[-2], mesh, "model") \
                else P(*([None] * ndim))
        if name == "conv":  # (R, cw, w)
            return last2(None, "model") if _div(shape[-1], mesh, "model") \
                else P(*([None] * ndim))
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_specs(cfg: ModelConfig, cache, mesh, batch: int):
    """PartitionSpec pytree for a decode cache."""
    baxes = batch_axes(mesh, batch)

    def rule(path, leaf):
        ndim = len(leaf.shape)
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        shape = leaf.shape
        if name == "pos":
            return P(baxes)
        if name in ("k", "v"):          # (R, B, S, Hkv, Dh)
            if _div(shape[3], mesh, "model"):
                return P(None, baxes, None, "model", None)   # kv-heads
            if shape[2] >= 2048 and _div(shape[2], mesh, "model"):
                return P(None, baxes, "model", None, None)   # seq-sharded
            return P(None, baxes, None, None, None)
        if name in ("k_s", "v_s"):       # int8 cache scales (R, B, S, Hkv)
            if _div(shape[3], mesh, "model"):
                return P(None, baxes, None, "model")
            if shape[2] >= 2048 and _div(shape[2], mesh, "model"):
                return P(None, baxes, "model", None)
            return P(None, baxes, None, None)
        if name == "s":                  # rwkv state (R, B, H, hs, hs)
            if _div(shape[2], mesh, "model"):
                return P(None, baxes, "model", None, None)
            return P(None, baxes, None, None, None)
        if name in ("x_tm", "x_cm"):     # (R, B, d)
            return P(None, baxes, "model") \
                if _div(shape[2], mesh, "model") else P(None, baxes, None)
        if name == "h":                  # (R, B, w)
            return P(None, baxes, "model") \
                if _div(shape[2], mesh, "model") else P(None, baxes, None)
        if name == "conv":               # (R, B, cw-1, w)
            return P(None, baxes, None, "model") \
                if _div(shape[3], mesh, "model") else P(None, baxes, None, None)
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(rule, cache)


def batch_specs(cfg: ModelConfig, batch_tree, mesh):
    """Input batch: shard the leading batch dim over (pod, data)."""

    def rule(path, leaf):
        ndim = len(leaf.shape)
        baxes = batch_axes(mesh, leaf.shape[0]) if ndim else None
        return P(*([baxes] + [None] * (ndim - 1))) if ndim else P()

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def logits_spec(cfg: ModelConfig, mesh, batch: int, with_time: bool = False):
    baxes = batch_axes(mesh, batch)
    v_ok = _div(cfg.vocab_size, mesh, "model")
    dims = [baxes] + ([None] if with_time else []) + \
        ["model" if v_ok else None]
    return P(*dims)


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
