"""Process-wide activation-sharding context.

Launchers (dryrun, serve, train) register the active mesh here; model
code pins key activations (residual stream, attention q/k/v) with
``with_sharding_constraint`` so GSPMD propagation cannot wander into
pathological layouts (measured: a T-sharded residual stream makes XLA
all-gather the MLP WEIGHTS every layer — §Perf iteration 1c).

No-ops when no mesh is registered (single-device tests/examples).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX = {"mesh": None}


def set_mesh(mesh) -> None:
    _CTX["mesh"] = mesh


def get_mesh():
    return _CTX["mesh"]


def _batch_axes(mesh, batch_size: int):
    from repro.launch.mesh import batch_axes
    return batch_axes(mesh, batch_size)


def pin(x, *spec_tail):
    """Constrain (B, *rest) activation: batch over (pod,data), tail as
    given (use None for replicated dims)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    spec = P(_batch_axes(mesh, x.shape[0]), *spec_tail)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def pin_residual(x):
    """(B, T, d) residual stream: batch-sharded, replicated over model."""
    return pin(x, None, None)


def pin_heads(x):
    """(B, T, H, Dh): heads over `model` when divisible."""
    mesh = _CTX["mesh"]
    if mesh is None or "model" not in mesh.axis_names:
        return x
    if x.shape[2] % mesh.shape["model"] != 0:
        return x
    return pin(x, None, "model", None)
