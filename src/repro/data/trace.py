"""Versioned JSONL request traces: record any serve run, replay it
bit-identically through either execution backend.

Schema (one JSON object per line):

* Line 0 — header: ``{"schema": "bucketserve.trace", "version": 1,
  "n": <request count>, "meta": {...}}``.  Readers HARD-FAIL
  (``TraceError``) on schema/version mismatch, corrupt JSON, or a
  body shorter than ``n`` lines (truncation is never silent).
* Lines 1..n — one request each, sorted by arrival (nondecreasing is
  VALIDATED on both write and read: an out-of-order trace is a bug in
  the producer, not something to quietly sort away).  Fields are the
  request's pre-run workload identity: ``rid``, ``arrival``,
  ``prompt_len``, ``max_new_tokens``, ``cls``, ``task``, per-class
  ``slo_ttft``/``slo_tpot``, session keys (``session_id``, ``turn``,
  ``think_gap``, ``history_tokens``), and materialized token ids —
  ``tokens`` for one-shot / turn-0 prompts, ``utterance`` for later
  session turns (their full prompt is composed at unlock time from the
  backend's actual generated ids, so a trace stores what the WORKLOAD
  supplied, never what a particular run composed).

Determinism contract: a trace captures requests AFTER
``backend.begin`` materializes prompt ids but BEFORE the run loop
mutates anything (arrivals get overwritten on requeue, session turns
get composed prompts).  Replaying preserves rids, so the rid-seeded
materialization rule (core/request.py) and the per-rid synthetic
generated-id rule (core/simulator.py) regenerate identical ids even
for fields a trace stores as null — the same backend-parity invariant
the existing cross-backend tests gate on.  JSON round-trips Python
floats exactly (repr-based shortest-repr), so arrivals and SLO budgets
survive record -> replay bit-identically.
"""
from __future__ import annotations

import copy
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.request import Request, TaskType

TRACE_SCHEMA = "bucketserve.trace"
TRACE_VERSION = 1


class TraceError(ValueError):
    """Raised for any malformed trace: wrong schema/version, corrupt
    JSON, truncation, or out-of-order arrivals."""


def _ids(arr: Optional[np.ndarray]) -> Optional[List[int]]:
    return None if arr is None else [int(x) for x in arr]


def _arr(ids) -> Optional[np.ndarray]:
    return None if ids is None else np.asarray(ids, np.int32)


def request_to_record(r: Request) -> Dict:
    """The pre-run identity of a request (see module doc)."""
    return {
        "rid": r.rid,
        "arrival": r.arrival,
        "prompt_len": r.prompt_len,
        "max_new_tokens": r.max_new_tokens,
        "cls": r.cls,
        "task": r.task_type.value,
        "slo_ttft": r.slo_ttft,
        "slo_tpot": r.slo_tpot,
        "session_id": r.session_id,
        "turn": r.turn,
        "think_gap": r.think_gap,
        "history_tokens": r.history_tokens,
        "tokens": None if r.turn > 0 else _ids(r.tokens),
        "utterance": _ids(r.utterance),
    }


def record_to_request(rec: Dict) -> Request:
    try:
        return Request(
            rid=int(rec["rid"]),
            prompt_len=int(rec["prompt_len"]),
            max_new_tokens=int(rec["max_new_tokens"]),
            arrival=float(rec["arrival"]),
            task_type=TaskType(rec["task"]),
            slo_ttft=float(rec["slo_ttft"]),
            slo_tpot=float(rec["slo_tpot"]),
            tokens=_arr(rec["tokens"]),
            cls=str(rec.get("cls", "")),
            session_id=rec["session_id"],
            turn=int(rec["turn"]),
            think_gap=float(rec["think_gap"]),
            utterance=_arr(rec["utterance"]),
            history_tokens=int(rec["history_tokens"]),
        )
    except (KeyError, TypeError) as e:
        raise TraceError(f"malformed trace record: {e!r}") from e


def write_trace(path: str, requests: List[Request],
                meta: Optional[Dict] = None) -> None:
    """Serialize ``requests`` (must already be sorted by arrival)."""
    last = float("-inf")
    for r in requests:
        if r.arrival < last:
            raise TraceError(
                f"out-of-order arrivals: rid={r.rid} at {r.arrival} "
                f"after {last}")
        last = r.arrival
    header = {"schema": TRACE_SCHEMA, "version": TRACE_VERSION,
              "n": len(requests), "meta": meta or {}}
    with open(path, "w") as f:
        f.write(json.dumps(header) + "\n")
        for r in requests:
            f.write(json.dumps(request_to_record(r)) + "\n")


def read_trace(path: str) -> Tuple[Dict, List[Request]]:
    """Parse and validate a trace; returns (header, requests)."""
    with open(path) as f:
        lines = f.read().splitlines()
    if not lines:
        raise TraceError(f"{path}: empty trace (missing header)")

    def parse(i: int) -> Dict:
        try:
            obj = json.loads(lines[i])
        except json.JSONDecodeError as e:
            raise TraceError(f"{path}:{i + 1}: corrupt JSON: {e}") from e
        if not isinstance(obj, dict):
            raise TraceError(f"{path}:{i + 1}: expected an object")
        return obj

    header = parse(0)
    if header.get("schema") != TRACE_SCHEMA:
        raise TraceError(
            f"{path}: schema {header.get('schema')!r}, "
            f"expected {TRACE_SCHEMA!r}")
    if header.get("version") != TRACE_VERSION:
        raise TraceError(
            f"{path}: trace version {header.get('version')!r}, this "
            f"reader understands version {TRACE_VERSION}")
    n = header.get("n")
    if not isinstance(n, int) or n < 0:
        raise TraceError(f"{path}: bad request count {n!r}")
    if len(lines) - 1 < n:
        raise TraceError(
            f"{path}: truncated trace — header promises {n} requests, "
            f"found {len(lines) - 1}")
    reqs = [record_to_request(parse(i)) for i in range(1, n + 1)]
    last = float("-inf")
    for r in reqs:
        if r.arrival < last:
            raise TraceError(
                f"{path}: out-of-order arrivals at rid={r.rid}")
        last = r.arrival
    return header, reqs


class TraceRecorder:
    """Attach to a ServingLoop (``recorder=`` kwarg) to capture a run.

    * ``on_begin``   — pristine per-request snapshots, taken after
      ``backend.begin`` (token ids materialized) and before the loop
      mutates state.  This is what ``save`` writes.
    * ``on_dispatch``/``on_requeue``/``on_turn`` — the run's event log:
      formed batches (the bit-identity surface replay is checked
      against), requeue arrivals, and session-turn compositions.
    """

    def __init__(self) -> None:
        self.snapshots: List[Request] = []
        self.batch_log: List[Tuple[str, Tuple[int, ...]]] = []
        self.requeues: List[Tuple[int, float]] = []
        self.turns: List[Tuple[int, float]] = []

    # -- ServingLoop hooks -------------------------------------------
    def on_begin(self, requests: List[Request]) -> None:
        self.snapshots = [copy.deepcopy(r) for r in requests]
        self.snapshots.sort(key=lambda r: (r.arrival, r.rid))

    def on_dispatch(self, kind: str, batch: List[Request],
                    t: float) -> None:
        self.batch_log.append((kind, tuple(r.rid for r in batch)))

    def on_requeue(self, r: Request, t: float) -> None:
        self.requeues.append((r.rid, t))

    def on_turn(self, r: Request, t: float) -> None:
        self.turns.append((r.rid, t))

    # -- outputs ------------------------------------------------------
    def save(self, path: str, meta: Optional[Dict] = None) -> None:
        m = dict(meta or {})
        m.setdefault("n_batches", len(self.batch_log))
        m.setdefault("n_requeues", len(self.requeues))
        m.setdefault("n_turns", len(self.turns))
        write_trace(path, self.snapshots, meta=m)


class TraceWorkload:
    """Load a trace back into the ``Request`` stream.  ``requests()``
    deep-copies on every call: serving mutates requests in place, so
    each run (and each backend in a parity check) must get a fresh,
    pristine stream with the recorded arrival timestamps."""

    def __init__(self, path: str) -> None:
        self.header, self._requests = read_trace(path)

    @property
    def meta(self) -> Dict:
        return self.header.get("meta", {})

    def __len__(self) -> int:
        return len(self._requests)

    def requests(self) -> List[Request]:
        return [copy.deepcopy(r) for r in self._requests]
