"""Synthetic serving workloads mirroring the paper's datasets (§V-A).

* ``alpaca``    — short instructions: lognormal, mean ≈ 83 tokens (paper
  Fig. 2a), outputs ~ geometric/lognormal around 120 tokens.
* ``longbench`` — long-document summarization: heavy-tailed lognormal with
  median ≈ 41k tokens, truncated to the model max (the paper does the
  same), outputs around 250 tokens.
* ``mixed``     — 50/50 of the two (paper's heterogeneous case).

Arrivals are Poisson at a given RPS.  Everything is seeded/deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np

from repro.core.request import Request, TaskType

ALPACA_MEAN = 83.0
LONGBENCH_MEDIAN = 41417.0


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    dataset: str = "alpaca"        # alpaca | longbench | mixed
    rps: float = 4.0
    n_requests: int = 256
    max_model_len: int = 32768
    task_type: TaskType = TaskType.ONLINE
    slo_ttft: float = 2.0
    slo_tpot: float = 0.2
    seed: int = 0
    max_new_tokens: int = 0        # 0 = sample per dataset


def _sample_prompt_lens(rng, dataset: str, n: int, max_len: int):
    if dataset == "alpaca":
        # lognormal with mean 83: mu + sigma^2/2 = ln 83
        sigma = 0.9
        mu = np.log(ALPACA_MEAN) - sigma ** 2 / 2
        lens = rng.lognormal(mu, sigma, n)
    elif dataset == "longbench":
        # heavy tail, median 41417 -> mu = ln(median)
        sigma = 1.1
        lens = rng.lognormal(np.log(LONGBENCH_MEDIAN), sigma, n)
    elif dataset == "mixed":
        half = rng.random(n) < 0.5
        a = _sample_prompt_lens(rng, "alpaca", n, max_len)
        b = _sample_prompt_lens(rng, "longbench", n, max_len)
        lens = np.where(half, a, b)
    else:
        raise ValueError(dataset)
    return np.clip(lens, 4, max_len - 1).astype(np.int64)


def _sample_output_lens(rng, dataset: str, n: int):
    # Output lengths sized so decode dominates e2e time (~90%, paper
    # Fig. 6a): chat/summary responses of a few hundred tokens.
    if dataset == "alpaca":
        out = rng.lognormal(np.log(300), 0.6, n)
    elif dataset == "longbench":
        out = rng.lognormal(np.log(350), 0.5, n)
    else:
        half = rng.random(n) < 0.5
        out = np.where(half, rng.lognormal(np.log(300), 0.6, n),
                       rng.lognormal(np.log(350), 0.5, n))
    return np.clip(out, 4, 1024).astype(np.int64)


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    n = spec.n_requests
    gaps = rng.exponential(1.0 / max(spec.rps, 1e-9), n)
    arrivals = np.cumsum(gaps)
    plens = _sample_prompt_lens(rng, spec.dataset, n, spec.max_model_len)
    olens = (_sample_output_lens(rng, spec.dataset, n)
             if spec.max_new_tokens == 0
             else np.full(n, spec.max_new_tokens, np.int64))
    # keep prompt+output within the model window
    olens = np.minimum(olens, spec.max_model_len - plens)
    return [
        Request(rid=i, prompt_len=int(plens[i]),
                max_new_tokens=max(int(olens[i]), 1),
                arrival=float(arrivals[i]), task_type=spec.task_type,
                slo_ttft=spec.slo_ttft, slo_tpot=spec.slo_tpot)
        for i in range(n)
    ]
