"""Synthetic serving workloads mirroring the paper's datasets (§V-A).

* ``alpaca``    — short instructions: lognormal, mean ≈ 83 tokens (paper
  Fig. 2a), outputs ~ geometric/lognormal around 120 tokens.
* ``longbench`` — long-document summarization: heavy-tailed lognormal with
  median ≈ 41k tokens, truncated to the model max (the paper does the
  same), outputs around 250 tokens.
* ``mixed``     — 50/50 of the two (paper's heterogeneous case).

Arrivals are Poisson at a given RPS.  Everything is seeded/deterministic.

Shared-prefix scenario family (PR 3, for the cross-request prefix
cache): ``prefix_groups > 0`` materializes ACTUAL token ids — each
request samples one of N distinct "system prompts" of
``prefix_tokens`` ids with Zipf-distributed reuse (a few prompts
dominate, the long tail is cold — standard multi-tenant agentic
traffic shape) and appends a per-request random suffix drawn from the
dataset's length distribution.  Requests carrying tokens flow through
both execution backends unchanged, so the engine and the cost model
see bit-identical prompts.

Heterogeneous trace family (PR 7, for trace-driven traffic +
tail-latency gates, data/trace.py): ``class_mix`` nonempty mixes three
request classes in ONE arrival stream — ``chat`` (short prompts, tight
TTFT SLO), ``longctx`` (heavy-tailed long-document prompts, relaxed
TTFT), ``batch`` (offline bulk generation, throughput-only SLO) — the
heterogeneous mix UELLM (arXiv 2409.14961) targets.  Arrivals are a
non-homogeneous Poisson process: a sinusoidal diurnal envelope plus
Poisson-arriving burst windows push the instantaneous rate up to
``burst_factor`` x the steady ``rps`` (sampled by thinning against the
peak rate, so the empirical rate tracks ``rate_envelope`` exactly in
expectation).  Each class carries its OWN SLO budgets (CLASS_SLOS)
attached per request.  Composable with the prefix/session knobs: with
``prefix_groups`` every request draws a shared system prompt; with
``sessions`` the first N chat-class arrivals become multi-turn
conversations.

Multi-turn conversation family (PR 4, for the session retention layer,
core/retention.py): ``sessions > 0`` generates ``sessions x turns``
requests.  Turn 0 of a session is a normal materialized prompt; turn
t > 0 re-sends the FULL transcript (previous prompt + generated
tokens) followed by a fresh user ``utterance`` — the standard chat
transcript-growth shape.  The transcript part cannot be sampled here
(generated ids are the serving backend's to produce), so later turns
carry only their utterance and ``prompt_len``/``history_tokens``
(lengths ARE known up front: the loop always generates exactly
``max_new_tokens``); the ServingLoop composes the actual prompt ids
when the previous turn finishes, after a per-turn think-time gap.
Everything sampled here is seeded/deterministic, so the same spec
regenerates bit-identical requests across calls and backends.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterator, List, Tuple

import numpy as np

from repro.core.request import Request, TaskType

ALPACA_MEAN = 83.0
LONGBENCH_MEDIAN = 41417.0

# Per-class SLO budgets (TTFT s, TPOT s) for the heterogeneous family.
# Values are attached to every emitted Request — trace record/replay
# and the SLO scheduler read budgets off the REQUEST, never off the
# spec.  "batch" is offline bulk work: budgets are deliberately loose
# (finite so they stay JSON-serializable in traces) — batch goodput is
# throughput, not latency.
CLASS_SLOS = {
    "chat": (2.0, 0.2),
    "longctx": (10.0, 0.4),
    "batch": (120.0, 2.0),
}

DEFAULT_CLASS_MIX: Tuple[Tuple[str, float], ...] = (
    ("chat", 0.60), ("longctx", 0.15), ("batch", 0.25))


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    dataset: str = "alpaca"        # alpaca | longbench | mixed
    rps: float = 4.0
    n_requests: int = 256
    max_model_len: int = 32768
    task_type: TaskType = TaskType.ONLINE
    slo_ttft: float = 2.0
    slo_tpot: float = 0.2
    seed: int = 0
    max_new_tokens: int = 0        # 0 = sample per dataset
    # ---- shared-prefix scenario family (0 = classic length-only) ----
    prefix_groups: int = 0         # N distinct shared system prompts
    prefix_tokens: int = 256       # length of each shared prefix
    prefix_zipf: float = 1.2       # Zipf skew of prefix reuse (> 1)
    vocab_size: int = 32000        # id range for materialized tokens
    # ---- multi-turn conversation family (0 = single-shot requests) ----
    sessions: int = 0              # number of conversations (overrides
    #                                n_requests: emits sessions x turns)
    turns: int = 4                 # turns per conversation
    think_time_s: float = 0.0      # mean think-time gap between turns
    utterance_tokens: int = 0      # new-user-tokens per later turn
    #                                (0 = sample the dataset distribution)
    # ---- heterogeneous trace family (empty = no class mixing) ----
    class_mix: Tuple[Tuple[str, float], ...] = ()   # ((name, weight), ...)
    burst_factor: float = 1.0      # peak/steady arrival-rate ratio
    diurnal_period_s: float = 60.0  # sinusoidal modulation period
    burst_every_s: float = 30.0    # mean gap between Poisson burst windows
    burst_duration_s: float = 3.0  # width of each burst window


def _sample_prompt_lens(rng, dataset: str, n: int, max_len: int):
    if dataset == "alpaca":
        # lognormal with mean 83: mu + sigma^2/2 = ln 83
        sigma = 0.9
        mu = np.log(ALPACA_MEAN) - sigma ** 2 / 2
        lens = rng.lognormal(mu, sigma, n)
    elif dataset == "longbench":
        # heavy tail, median 41417 -> mu = ln(median)
        sigma = 1.1
        lens = rng.lognormal(np.log(LONGBENCH_MEDIAN), sigma, n)
    elif dataset == "mixed":
        half = rng.random(n) < 0.5
        a = _sample_prompt_lens(rng, "alpaca", n, max_len)
        b = _sample_prompt_lens(rng, "longbench", n, max_len)
        lens = np.where(half, a, b)
    else:
        raise ValueError(dataset)
    return np.clip(lens, 4, max_len - 1).astype(np.int64)


def _sample_output_lens(rng, dataset: str, n: int):
    # Output lengths sized so decode dominates e2e time (~90%, paper
    # Fig. 6a): chat/summary responses of a few hundred tokens.
    if dataset == "alpaca":
        out = rng.lognormal(np.log(300), 0.6, n)
    elif dataset == "longbench":
        out = rng.lognormal(np.log(350), 0.5, n)
    else:
        half = rng.random(n) < 0.5
        out = np.where(half, rng.lognormal(np.log(300), 0.6, n),
                       rng.lognormal(np.log(350), 0.5, n))
    return np.clip(out, 4, 1024).astype(np.int64)


# ---------------------------------------- heterogeneous trace family ----
def trace_horizon(spec: WorkloadSpec) -> float:
    """Time window the burst-window process is materialized over: a
    generous multiple of the steady-state drain time, so the thinning
    sampler practically never outruns it (past the horizon the envelope
    degrades gracefully to the diurnal part alone)."""
    return 4.0 * spec.n_requests / max(spec.rps, 1e-9) \
        + 2.0 * max(spec.diurnal_period_s, 1.0)


def burst_windows(spec: WorkloadSpec) -> List[Tuple[float, float]]:
    """Poisson-arriving burst windows over [0, horizon).  Drawn from a
    DISJOINT rng stream keyed on the spec seed, so toggling burst knobs
    never shifts the length/class draws of the main stream."""
    if spec.burst_factor <= 1.0 or spec.burst_every_s <= 0:
        return []
    rng = np.random.default_rng([spec.seed, 0xB065])
    horizon = trace_horizon(spec)
    wins, t = [], 0.0
    while True:
        t += float(rng.exponential(spec.burst_every_s))
        if t >= horizon:
            return wins
        wins.append((t, t + spec.burst_duration_s))


def rate_envelope(spec: WorkloadSpec, t: float,
                  windows: List[Tuple[float, float]]) -> float:
    """Instantaneous arrival rate lambda(t): steady ``rps`` modulated by
    a sinusoidal diurnal swing, overridden to the full ``burst_factor``
    inside a burst window; never exceeds rps * burst_factor (the
    thinning bound)."""
    bf = max(spec.burst_factor, 1.0)
    m = 1.0
    if spec.diurnal_period_s > 0 and bf > 1.0:
        m += (bf - 1.0) * 0.5 * (1.0 - math.cos(
            2.0 * math.pi * t / spec.diurnal_period_s))
    for lo, hi in windows:
        if lo <= t < hi:
            m = bf
            break
        if lo > t:
            break
    return spec.rps * min(m, bf)


def envelope_fn(spec: WorkloadSpec) -> Callable[[float], float]:
    """The exact lambda(t) the generator thinned against — the property
    test compares empirical bin rates to this."""
    wins = burst_windows(spec)
    return lambda t: rate_envelope(spec, t, wins)


def _bursty_arrivals(spec: WorkloadSpec, rng) -> np.ndarray:
    """Non-homogeneous Poisson arrivals by thinning against the peak
    rate rps * burst_factor."""
    lam = envelope_fn(spec)
    lam_max = spec.rps * max(spec.burst_factor, 1.0)
    out, t = [], 0.0
    while len(out) < spec.n_requests:
        t += float(rng.exponential(1.0 / max(lam_max, 1e-9)))
        if float(rng.random()) * lam_max <= lam(t):
            out.append(t)
    return np.asarray(out)


def _generate_heterogeneous(spec: WorkloadSpec, rng) -> List[Request]:
    """Three-class mixed stream (see module doc).  All randomness flows
    through ``rng`` in a FIXED order (arrivals, classes, per-class
    length tables, then per-request materialization), so the same spec
    regenerates a bit-identical workload."""
    mix = spec.class_mix
    names = [c for c, _ in mix]
    w = np.asarray([max(float(p), 0.0) for _, p in mix])
    assert w.sum() > 0, "class_mix weights must not all be zero"
    for c in names:
        assert c in CLASS_SLOS, f"unknown request class {c!r}"
    n = spec.n_requests
    arrivals = _bursty_arrivals(spec, rng)
    cls_idx = rng.choice(len(names), size=n, p=w / w.sum())
    max_len = spec.max_model_len
    # per-class length tables (sampled in full, selected by mask — the
    # same pattern the "mixed" dataset uses, so draws stay vectorized
    # and deterministic)
    plens_by = {
        "chat": _sample_prompt_lens(rng, "alpaca", n, max_len),
        "longctx": _sample_prompt_lens(rng, "longbench", n, max_len),
        "batch": np.clip(rng.lognormal(np.log(900.0), 0.8, n),
                         4, max_len - 1).astype(np.int64),
    }
    olens_by = {
        "chat": _sample_output_lens(rng, "alpaca", n),
        "longctx": _sample_output_lens(rng, "longbench", n),
        "batch": np.clip(rng.lognormal(np.log(700.0), 0.6, n),
                         16, 2048).astype(np.int64),
    }
    plens = np.asarray([plens_by[names[c]][i]
                        for i, c in enumerate(cls_idx)], np.int64)
    olens = np.asarray([olens_by[names[c]][i]
                        for i, c in enumerate(cls_idx)], np.int64)
    if spec.max_new_tokens > 0:
        olens = np.full(n, spec.max_new_tokens, np.int64)
    # shared-prefix composability: identical materialization rule to the
    # classic family (N system prompts, Zipf reuse, dataset lengths
    # become suffix lengths)
    tokens: List = [None] * n
    if spec.prefix_groups > 0:
        assert spec.prefix_zipf > 1.0, "np Zipf needs skew > 1"
        pre = min(max(spec.prefix_tokens, 1), max_len - 2)
        prefixes = [rng.integers(0, spec.vocab_size, pre).astype(np.int32)
                    for _ in range(spec.prefix_groups)]
        groups = (rng.zipf(spec.prefix_zipf, n) - 1) % spec.prefix_groups
        slens = np.clip(plens, 1, max_len - 1 - pre)
        for i in range(n):
            suffix = rng.integers(0, spec.vocab_size,
                                  int(slens[i])).astype(np.int32)
            tokens[i] = np.concatenate([prefixes[int(groups[i])], suffix])
        plens = pre + slens
    olens = np.maximum(np.minimum(olens, max_len - plens), 1)
    # session composability: the first ``sessions`` chat-class arrivals
    # become multi-turn conversations (transcript growth, PR 4 shape)
    session_of: dict = {}
    if spec.sessions > 0:
        chat_ix = [i for i in range(n) if names[cls_idx[i]] == "chat"]
        for s, i in enumerate(chat_ix[:spec.sessions]):
            session_of[i] = s
    reqs: List[Request] = []
    rid = 0
    for i in range(n):
        cls = names[cls_idx[i]]
        slo_ttft, slo_tpot = CLASS_SLOS[cls]
        task = TaskType.OFFLINE if cls == "batch" else spec.task_type
        if i not in session_of:
            reqs.append(Request(
                rid=rid, prompt_len=int(plens[i]),
                max_new_tokens=int(olens[i]), arrival=float(arrivals[i]),
                task_type=task, slo_ttft=slo_ttft, slo_tpot=slo_tpot,
                tokens=tokens[i], cls=cls))
            rid += 1
            continue
        # a chat session head: emit its turns (window-budgeted exactly
        # like _generate_sessions; the ServingLoop composes turn > 0
        # prompts from actual generated ids at unlock time)
        sid = session_of[i]
        transcript = 0
        for t in range(spec.turns):
            room = max_len - transcript - 2
            if room < 1:
                break
            ulen = spec.utterance_tokens or int(_sample_prompt_lens(
                rng, "alpaca", 1, max_len)[0])
            ulen = max(1, min(ulen, room))
            out = int(spec.max_new_tokens
                      or _sample_output_lens(rng, "alpaca", 1)[0])
            out = max(1, min(out, max_len - transcript - ulen))
            utter = rng.integers(0, spec.vocab_size, ulen).astype(np.int32)
            gap = float(rng.exponential(spec.think_time_s)) if t else 0.0
            reqs.append(Request(
                rid=rid, prompt_len=transcript + ulen, max_new_tokens=out,
                arrival=float(arrivals[i]), task_type=task,
                slo_ttft=slo_ttft, slo_tpot=slo_tpot,
                tokens=utter if t == 0 else None, cls=cls,
                session_id=sid, turn=t, think_gap=gap, utterance=utter,
                history_tokens=transcript))
            transcript += ulen + out
            rid += 1
    return reqs


def _generate_sessions(spec: WorkloadSpec, rng) -> List[Request]:
    """sessions x turns transcript-growth requests (see module doc).
    Every turn's prompt_len/max_new_tokens/utterance are sampled HERE
    (deterministic); only the transcript token ids of turns > 0 are
    composed later by the ServingLoop from actual generated output."""
    assert spec.turns >= 1
    starts = np.cumsum(rng.exponential(1.0 / max(spec.rps, 1e-9),
                                       spec.sessions))
    reqs: List[Request] = []
    rid = 0
    for s in range(spec.sessions):
        transcript = 0                      # tokens of turns 0..t-1
        for t in range(spec.turns):
            # keep the whole conversation inside the model window: the
            # utterance and output budgets shrink as the transcript
            # grows, and a session whose transcript has exhausted the
            # window simply ENDS early (every emitted turn satisfies
            # prompt_len + max_new_tokens <= max_model_len — an
            # oversized turn could never be served)
            room = spec.max_model_len - transcript - 2
            if room < 1:
                break
            if spec.utterance_tokens > 0:
                ulen = spec.utterance_tokens
            else:
                ulen = int(_sample_prompt_lens(
                    rng, spec.dataset if t == 0 else "alpaca", 1,
                    spec.max_model_len)[0])
            ulen = max(1, min(ulen, room))
            out = int(spec.max_new_tokens
                      or _sample_output_lens(rng, spec.dataset, 1)[0])
            out = max(1, min(out, spec.max_model_len - transcript - ulen))
            utter = rng.integers(0, spec.vocab_size, ulen).astype(np.int32)
            gap = float(rng.exponential(spec.think_time_s)) if t else 0.0
            reqs.append(Request(
                rid=rid, prompt_len=transcript + ulen, max_new_tokens=out,
                arrival=float(starts[s]), task_type=spec.task_type,
                slo_ttft=spec.slo_ttft, slo_tpot=spec.slo_tpot,
                tokens=utter if t == 0 else None,
                session_id=s, turn=t, think_gap=gap, utterance=utter,
                history_tokens=transcript))
            transcript += ulen + out            # next turn's history
            rid += 1
    return reqs


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    if spec.class_mix:
        return _generate_heterogeneous(spec, rng)
    if spec.sessions > 0:
        return _generate_sessions(spec, rng)
    n = spec.n_requests
    gaps = rng.exponential(1.0 / max(spec.rps, 1e-9), n)
    arrivals = np.cumsum(gaps)
    plens = _sample_prompt_lens(rng, spec.dataset, n, spec.max_model_len)
    tokens: List = [None] * n
    if spec.prefix_groups > 0:
        assert spec.prefix_zipf > 1.0, "np Zipf needs skew > 1"
        pre = min(max(spec.prefix_tokens, 1), spec.max_model_len - 2)
        prefixes = [rng.integers(0, spec.vocab_size, pre).astype(np.int32)
                    for _ in range(spec.prefix_groups)]
        groups = (rng.zipf(spec.prefix_zipf, n) - 1) % spec.prefix_groups
        # dataset lengths become the SUFFIX lengths (>= 1 so at least
        # one uncached token always runs through prefill)
        slens = np.clip(plens, 1, spec.max_model_len - 1 - pre)
        for i in range(n):
            suffix = rng.integers(0, spec.vocab_size,
                                  int(slens[i])).astype(np.int32)
            tokens[i] = np.concatenate([prefixes[int(groups[i])], suffix])
        plens = pre + slens
    olens = (_sample_output_lens(rng, spec.dataset, n)
             if spec.max_new_tokens == 0
             else np.full(n, spec.max_new_tokens, np.int64))
    # keep prompt+output within the model window
    olens = np.minimum(olens, spec.max_model_len - plens)
    return [
        Request(rid=i, prompt_len=int(plens[i]),
                max_new_tokens=max(int(olens[i]), 1),
                arrival=float(arrivals[i]), task_type=spec.task_type,
                slo_ttft=spec.slo_ttft, slo_tpot=spec.slo_tpot,
                tokens=tokens[i])
        for i in range(n)
    ]
