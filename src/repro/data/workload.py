"""Synthetic serving workloads mirroring the paper's datasets (§V-A).

* ``alpaca``    — short instructions: lognormal, mean ≈ 83 tokens (paper
  Fig. 2a), outputs ~ geometric/lognormal around 120 tokens.
* ``longbench`` — long-document summarization: heavy-tailed lognormal with
  median ≈ 41k tokens, truncated to the model max (the paper does the
  same), outputs around 250 tokens.
* ``mixed``     — 50/50 of the two (paper's heterogeneous case).

Arrivals are Poisson at a given RPS.  Everything is seeded/deterministic.

Shared-prefix scenario family (PR 3, for the cross-request prefix
cache): ``prefix_groups > 0`` materializes ACTUAL token ids — each
request samples one of N distinct "system prompts" of
``prefix_tokens`` ids with Zipf-distributed reuse (a few prompts
dominate, the long tail is cold — standard multi-tenant agentic
traffic shape) and appends a per-request random suffix drawn from the
dataset's length distribution.  Requests carrying tokens flow through
both execution backends unchanged, so the engine and the cost model
see bit-identical prompts.

Multi-turn conversation family (PR 4, for the session retention layer,
core/retention.py): ``sessions > 0`` generates ``sessions x turns``
requests.  Turn 0 of a session is a normal materialized prompt; turn
t > 0 re-sends the FULL transcript (previous prompt + generated
tokens) followed by a fresh user ``utterance`` — the standard chat
transcript-growth shape.  The transcript part cannot be sampled here
(generated ids are the serving backend's to produce), so later turns
carry only their utterance and ``prompt_len``/``history_tokens``
(lengths ARE known up front: the loop always generates exactly
``max_new_tokens``); the ServingLoop composes the actual prompt ids
when the previous turn finishes, after a per-turn think-time gap.
Everything sampled here is seeded/deterministic, so the same spec
regenerates bit-identical requests across calls and backends.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np

from repro.core.request import Request, TaskType

ALPACA_MEAN = 83.0
LONGBENCH_MEDIAN = 41417.0


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    dataset: str = "alpaca"        # alpaca | longbench | mixed
    rps: float = 4.0
    n_requests: int = 256
    max_model_len: int = 32768
    task_type: TaskType = TaskType.ONLINE
    slo_ttft: float = 2.0
    slo_tpot: float = 0.2
    seed: int = 0
    max_new_tokens: int = 0        # 0 = sample per dataset
    # ---- shared-prefix scenario family (0 = classic length-only) ----
    prefix_groups: int = 0         # N distinct shared system prompts
    prefix_tokens: int = 256       # length of each shared prefix
    prefix_zipf: float = 1.2       # Zipf skew of prefix reuse (> 1)
    vocab_size: int = 32000        # id range for materialized tokens
    # ---- multi-turn conversation family (0 = single-shot requests) ----
    sessions: int = 0              # number of conversations (overrides
    #                                n_requests: emits sessions x turns)
    turns: int = 4                 # turns per conversation
    think_time_s: float = 0.0      # mean think-time gap between turns
    utterance_tokens: int = 0      # new-user-tokens per later turn
    #                                (0 = sample the dataset distribution)


def _sample_prompt_lens(rng, dataset: str, n: int, max_len: int):
    if dataset == "alpaca":
        # lognormal with mean 83: mu + sigma^2/2 = ln 83
        sigma = 0.9
        mu = np.log(ALPACA_MEAN) - sigma ** 2 / 2
        lens = rng.lognormal(mu, sigma, n)
    elif dataset == "longbench":
        # heavy tail, median 41417 -> mu = ln(median)
        sigma = 1.1
        lens = rng.lognormal(np.log(LONGBENCH_MEDIAN), sigma, n)
    elif dataset == "mixed":
        half = rng.random(n) < 0.5
        a = _sample_prompt_lens(rng, "alpaca", n, max_len)
        b = _sample_prompt_lens(rng, "longbench", n, max_len)
        lens = np.where(half, a, b)
    else:
        raise ValueError(dataset)
    return np.clip(lens, 4, max_len - 1).astype(np.int64)


def _sample_output_lens(rng, dataset: str, n: int):
    # Output lengths sized so decode dominates e2e time (~90%, paper
    # Fig. 6a): chat/summary responses of a few hundred tokens.
    if dataset == "alpaca":
        out = rng.lognormal(np.log(300), 0.6, n)
    elif dataset == "longbench":
        out = rng.lognormal(np.log(350), 0.5, n)
    else:
        half = rng.random(n) < 0.5
        out = np.where(half, rng.lognormal(np.log(300), 0.6, n),
                       rng.lognormal(np.log(350), 0.5, n))
    return np.clip(out, 4, 1024).astype(np.int64)


def _generate_sessions(spec: WorkloadSpec, rng) -> List[Request]:
    """sessions x turns transcript-growth requests (see module doc).
    Every turn's prompt_len/max_new_tokens/utterance are sampled HERE
    (deterministic); only the transcript token ids of turns > 0 are
    composed later by the ServingLoop from actual generated output."""
    assert spec.turns >= 1
    starts = np.cumsum(rng.exponential(1.0 / max(spec.rps, 1e-9),
                                       spec.sessions))
    reqs: List[Request] = []
    rid = 0
    for s in range(spec.sessions):
        transcript = 0                      # tokens of turns 0..t-1
        for t in range(spec.turns):
            # keep the whole conversation inside the model window: the
            # utterance and output budgets shrink as the transcript
            # grows, and a session whose transcript has exhausted the
            # window simply ENDS early (every emitted turn satisfies
            # prompt_len + max_new_tokens <= max_model_len — an
            # oversized turn could never be served)
            room = spec.max_model_len - transcript - 2
            if room < 1:
                break
            if spec.utterance_tokens > 0:
                ulen = spec.utterance_tokens
            else:
                ulen = int(_sample_prompt_lens(
                    rng, spec.dataset if t == 0 else "alpaca", 1,
                    spec.max_model_len)[0])
            ulen = max(1, min(ulen, room))
            out = int(spec.max_new_tokens
                      or _sample_output_lens(rng, spec.dataset, 1)[0])
            out = max(1, min(out, spec.max_model_len - transcript - ulen))
            utter = rng.integers(0, spec.vocab_size, ulen).astype(np.int32)
            gap = float(rng.exponential(spec.think_time_s)) if t else 0.0
            reqs.append(Request(
                rid=rid, prompt_len=transcript + ulen, max_new_tokens=out,
                arrival=float(starts[s]), task_type=spec.task_type,
                slo_ttft=spec.slo_ttft, slo_tpot=spec.slo_tpot,
                tokens=utter if t == 0 else None,
                session_id=s, turn=t, think_gap=gap, utterance=utter,
                history_tokens=transcript))
            transcript += ulen + out            # next turn's history
            rid += 1
    return reqs


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    if spec.sessions > 0:
        return _generate_sessions(spec, rng)
    n = spec.n_requests
    gaps = rng.exponential(1.0 / max(spec.rps, 1e-9), n)
    arrivals = np.cumsum(gaps)
    plens = _sample_prompt_lens(rng, spec.dataset, n, spec.max_model_len)
    tokens: List = [None] * n
    if spec.prefix_groups > 0:
        assert spec.prefix_zipf > 1.0, "np Zipf needs skew > 1"
        pre = min(max(spec.prefix_tokens, 1), spec.max_model_len - 2)
        prefixes = [rng.integers(0, spec.vocab_size, pre).astype(np.int32)
                    for _ in range(spec.prefix_groups)]
        groups = (rng.zipf(spec.prefix_zipf, n) - 1) % spec.prefix_groups
        # dataset lengths become the SUFFIX lengths (>= 1 so at least
        # one uncached token always runs through prefill)
        slens = np.clip(plens, 1, spec.max_model_len - 1 - pre)
        for i in range(n):
            suffix = rng.integers(0, spec.vocab_size,
                                  int(slens[i])).astype(np.int32)
            tokens[i] = np.concatenate([prefixes[int(groups[i])], suffix])
        plens = pre + slens
    olens = (_sample_output_lens(rng, spec.dataset, n)
             if spec.max_new_tokens == 0
             else np.full(n, spec.max_new_tokens, np.int64))
    # keep prompt+output within the model window
    olens = np.minimum(olens, spec.max_model_len - plens)
    return [
        Request(rid=i, prompt_len=int(plens[i]),
                max_new_tokens=max(int(olens[i]), 1),
                arrival=float(arrivals[i]), task_type=spec.task_type,
                slo_ttft=spec.slo_ttft, slo_tpot=spec.slo_tpot,
                tokens=tokens[i])
        for i in range(n)
    ]
