"""Synthetic token pipeline for training (offline container: no corpora).

Generates a deterministic Zipf-ish token stream with induced bigram
structure so the LM loss actually decreases; supports length-bucketed
packing (the beyond-paper reuse of BucketServe's idea at training time).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


class SyntheticLM:
    def __init__(self, vocab: int, seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.unigram = ranks ** -zipf_a
        self.unigram /= self.unigram.sum()
        # deterministic "grammar": each token prefers a fixed successor
        self.successor = self.rng.permutation(vocab)

    def sample(self, batch: int, seq: int):
        out = np.empty((batch, seq), np.int32)
        cur = self.rng.choice(self.vocab, size=batch, p=self.unigram)
        for t in range(seq):
            out[:, t] = cur
            follow = self.rng.random(batch) < 0.7
            nxt = self.rng.choice(self.vocab, size=batch, p=self.unigram)
            cur = np.where(follow, self.successor[cur], nxt)
        return out


def batches(cfg, batch_size: int, seq_len: int, seed: int = 0):
    """Yields train batches for any arch family."""
    gen = SyntheticLM(cfg.vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        if cfg.is_encoder:
            yield {
                "embeds": jnp.asarray(
                    rng.standard_normal((batch_size, seq_len, cfg.d_model),
                                        np.float32) * 0.02),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size,
                                 (batch_size, seq_len)).astype(np.int32)),
            }
        else:
            batch = {"tokens": jnp.asarray(gen.sample(batch_size, seq_len))}
            if cfg.arch_type == "vlm":
                batch["vision_embeds"] = jnp.asarray(
                    rng.standard_normal(
                        (batch_size, cfg.n_vision_tokens, cfg.d_vision),
                        np.float32) * 0.02)
            yield batch
