"""CI helper: schema-validate an exported Perfetto/Chrome trace and
assert the expected span categories are present.

    PYTHONPATH=src python tools/validate_trace.py run.perfetto.json \
        --require batch,spill,restore

Exits nonzero (with the violation list) on any schema error —
non-monotonic timestamps, negative complete-span durations,
non-numeric counter args, orphan or unbalanced async begin/end pairs —
or if a required event category has no events.  ``serve.py
--trace-out`` already refuses to write an invalid file; this re-checks
the artifact FROM DISK, so CI catches a serializer regression too.
"""
from __future__ import annotations

import argparse
import collections
import json
import sys

from repro.core.telemetry import validate_perfetto


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="trace-event JSON file to validate")
    ap.add_argument("--require", default="",
                    help="comma-separated event categories that must "
                         "each have >= 1 event")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        doc = json.load(f)
    errs = validate_perfetto(doc)
    if errs:
        sys.exit("\n".join(f"SCHEMA: {e}" for e in errs))

    cats = collections.Counter(
        e.get("cat") for e in doc["traceEvents"] if e.get("ph") != "M")
    missing = [c for c in args.require.split(",")
               if c and cats.get(c, 0) < 1]
    if missing:
        sys.exit(f"missing required span categories {missing}; "
                 f"present: {dict(cats)}")
    print(f"valid: {sum(cats.values())} events, "
          + ", ".join(f"{c}={n}" for c, n in sorted(cats.items())))


if __name__ == "__main__":
    main()
