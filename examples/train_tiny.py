"""Train a ~100M-parameter dense model for a few hundred steps on CPU,
with checkpointing and length-bucketed batch packing (BucketServe's idea
applied to training — DESIGN.md §4).

    PYTHONPATH=src python examples/train_tiny.py [--steps 300]
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.data import tokens as data_tokens
from repro.models.config import reduced
from repro.train import checkpoint, optimizer, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="results/train_tiny.npz")
    args = ap.parse_args()

    # ~100M params: 8 layers, d=512, ff=2048, vocab 8192
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-14b")),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab_size=8192, max_seq_len=args.seq,
        name="qwen3-tiny-100m")
    n_params = cfg.param_count()
    print(f"model={cfg.name}: ~{n_params/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq}")

    it = data_tokens.batches(cfg, args.batch, args.seq)
    t0 = time.perf_counter()
    losses = []

    def log(rec):
        losses.append(rec["loss"])
        print(f"  step {rec['step']:4d} loss={rec['loss']:.4f} "
              f"lr={rec['lr']:.2e} gnorm={rec['grad_norm']:.3f}")

    params, opt_state, hist = train_loop.train(
        cfg, args.steps, it,
        opt_cfg=optimizer.AdamWConfig(lr=1e-3, warmup_steps=20,
                                      total_steps=args.steps),
        callback=log, log_every=25)
    dt = time.perf_counter() - t0
    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s CPU)")
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}  (decreased)")

    checkpoint.save(args.ckpt, params, opt_state,
                    meta={"steps": args.steps})
    params2 = checkpoint.restore(args.ckpt, params)
    leaves = zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    assert all((a == b).all() for a, b in leaves)
    print(f"checkpoint round-trip OK -> {args.ckpt}")


if __name__ == "__main__":
    main()
