"""End-to-end serving driver at paper scale (Llama2-13B / 4xA100 cost
model): BucketServe vs the baselines on a bursty mixed workload.

    PYTHONPATH=src python examples/serve_paper_scale.py [--rps 4] [--n 200]

This is the paper's Fig. 5 experiment as a single runnable script; the
same scheduler objects also drive the real CPU engine (quickstart.py).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core.baselines import SIM_MODE, hardware_for, make_scheduler
from repro.core.batcher import MemoryBudget
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.workload import WorkloadSpec, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rps", type=float, default=4.0)
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--dataset", default="mixed",
                    choices=["alpaca", "longbench", "mixed"])
    args = ap.parse_args()

    cfg = get_config("llama2-13b")
    print(f"model={cfg.name}  dataset={args.dataset}  "
          f"client_rps={args.rps}  n={args.n}\n")
    print(f"{'system':12s} {'tok/s':>8s} {'srv_rps':>8s} {'SLO':>6s} "
          f"{'p50 TTFT':>9s} {'OOM':>4s} {'pad_eff':>8s}")
    for name in SIM_MODE:
        spec = WorkloadSpec(dataset=args.dataset, rps=args.rps,
                            n_requests=args.n,
                            max_model_len=cfg.max_seq_len)
        reqs = generate(spec)
        hw, nd, _ = hardware_for(name, A100X4)
        budget = MemoryBudget(hw.hbm_bytes, nd, cfg.param_count() * 2)
        sim = Simulator(make_scheduler(name, cfg, budget),
                        CostModel(cfg, hw), mode=SIM_MODE[name])
        res = sim.run(reqs)
        ttfts = sorted(r.ttft() for r in res.finished())
        p50 = ttfts[len(ttfts) // 2] if ttfts else float("nan")
        print(f"{name:12s} {res.throughput_tok_s():8.0f} "
              f"{res.server_rps():8.2f} {res.slo_attainment():6.2f} "
              f"{p50:8.2f}s {res.oom_events:4d} "
              f"{res.padding_efficiency():8.2f}")


if __name__ == "__main__":
    main()
