"""Long-context serving across architecture families (CPU, real exec).

Serves requests through reduced RWKV6 (O(1) state), recurrentgemma
(window-bounded) and a sliding-window dense variant — the three
long_500k-capable families — and prints the per-request live-memory
accounting the Eq.-(6) batcher uses for each.

    PYTHONPATH=src python examples/long_context_serving.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import (BucketServeScheduler, MemoryBudget, Request,
                        SchedulerConfig, TaskType)
from repro.core.engine import ServingEngine
from repro.models import transformer as tfm


def main():
    print("Eq.-(6) memory models at FULL config scale (per 32k-token "
          "request, bf16):")
    for arch in ("qwen3-14b", "rwkv6-3b", "recurrentgemma-2b"):
        for variant in ("", "swa"):
            cfg = get_config(arch, variant=variant)
            kv = cfg.kv_bytes_per_token()
            win = cfg.sliding_window or (
                cfg.local_window if cfg.arch_type == "hybrid" else 0)
            tokens = min(32768, win) if win else 32768
            live = kv * tokens + cfg.state_bytes()
            print(f"  {cfg.name:24s} [{cfg.arch_type:6s}] "
                  f"{live / 2**30:7.3f} GiB  "
                  f"({'window ' + str(win) if win else 'full cache'}"
                  f"{', state ' + str(cfg.state_bytes() // 1024) + 'KiB' if cfg.state_bytes() else ''})")
            if cfg.arch_type in ("ssm", "hybrid"):
                break   # no separate swa variant

    print("\nServing 8 long-ish prompts through each family (reduced "
          "configs, real CPU execution):")
    rng = np.random.default_rng(0)
    for arch, kw in (("rwkv6-3b", {}), ("recurrentgemma-2b", {}),
                     ("qwen3-14b", {"sliding_window": 48})):
        cfg = get_smoke_config(arch, max_seq_len=256, **kw)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        sched = BucketServeScheduler(
            cfg, MemoryBudget(2 ** 30, 1, 0), SchedulerConfig(max_batch=4))
        eng = ServingEngine(cfg, params, sched, max_slots=4, cache_len=256)
        reqs = [Request(rid=i, prompt_len=int(rng.integers(100, 200)),
                        max_new_tokens=6, arrival=0.0,
                        task_type=TaskType.OFFLINE) for i in range(8)]
        eng.submit(reqs)
        done = eng.run(max_wall_s=600)
        print(f"  {cfg.name:28s} served {len(done)}/8, "
              f"outputs e.g. {eng.outputs[done[0].rid]}")


if __name__ == "__main__":
    main()
