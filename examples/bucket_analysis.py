"""Waste-model walkthrough (paper Eqs. 1-4): how adaptive bucketing cuts
padding on the paper's workload mix, with ASCII histograms.

    PYTHONPATH=src python examples/bucket_analysis.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import analysis
from repro.core.bucket import BucketManager
from repro.core.request import Request, TaskType
from repro.data.workload import WorkloadSpec, generate

L_MAX = 32768


def hist(lens, bounds, width=48):
    bounds = sorted(bounds)
    counts, _ = np.histogram(lens, bins=bounds)
    top = max(counts.max(), 1)
    for i, c in enumerate(counts):
        bar = "#" * int(width * c / top)
        print(f"  [{bounds[i]:6.0f},{bounds[i+1]:6.0f}) {c:5d} {bar}")


def main():
    spec = WorkloadSpec(dataset="mixed", rps=1e6, n_requests=4096,
                        max_model_len=L_MAX)
    lens = np.array([r.prompt_len for r in generate(spec)])
    print(f"mixed workload: n={len(lens)} median={np.median(lens):.0f} "
          f"mean={lens.mean():.0f} p95={np.percentile(lens, 95):.0f}")

    for label, kw in (("paper (midpoint/majority)", {}),
                      ("beyond (eq4 refine + waste trigger)",
                       dict(refine="eq4", trigger="waste"))):
        bm = BucketManager(L_MAX, **kw)
        for i, s in enumerate(lens):
            bm.add(Request(rid=i, prompt_len=int(s), max_new_tokens=8,
                           arrival=0.0, task_type=TaskType.OFFLINE))
        for _ in range(8):
            bm.adjust(n_max=256)
        bounds = bm.boundaries()
        waste = analysis.expected_waste(lens, bounds)
        pad = analysis.padded_tokens(lens, bounds)
        print(f"\n{label}: {len(bm.buckets)} buckets, "
              f"E[waste]={waste:.3f}, padded slots={pad/1e6:.2f}M tokens")
        hist(lens, bounds)

    single = analysis.expected_waste(lens, [0, L_MAX])
    print(f"\nsingle bucket baseline: E[waste]={single:.3f} "
          f"(Eq. 2 for one batch of everything)")
    print("Eq. 1 check: KV bytes for a 16-request batch padded to 4096 on "
          "Llama2-13B-like dims:")
    print(f"  {analysis.kv_cache_bytes(40, 40, 128, 4096, 2, 16)/2**30:.2f} "
          f"GiB")


if __name__ == "__main__":
    main()
