"""Quickstart: BucketServe serving a tiny model on CPU, end to end.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-14b]
                                                 [--chunk 32]

Builds the reduced config, initializes real weights, submits a burst of
mixed-length requests and serves them through the full stack: adaptive
bucketing -> memory-safe batch formation -> jitted prefill (one compiled
executable per bucket pad shape) -> slot-based continuous-batching
decode, all orchestrated by the unified event-driven ServingLoop
(core/serving_loop.py).  ``--chunk N`` turns on chunked prefill: decode
iterations interleave between N-token prompt chunks instead of stalling
behind a whole long prefill.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_smoke_config, list_archs
from repro.core import (BucketServeScheduler, MemoryBudget, Request,
                        SchedulerConfig, TaskType)
from repro.core.engine import ServingEngine
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=None,
                    help="chunked-prefill span in tokens")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch, max_seq_len=128)
    print(f"arch={cfg.name} family={cfg.arch_type} "
          f"layers={cfg.n_layers} d_model={cfg.d_model}")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))

    budget = MemoryBudget(hbm_bytes_per_device=2 ** 30, n_devices=1,
                          weight_bytes=0)
    sched = BucketServeScheduler(cfg, budget,
                                 SchedulerConfig(max_batch=args.slots))
    engine = ServingEngine(cfg, params, sched, max_slots=args.slots,
                           cache_len=128, chunk_tokens=args.chunk)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt_len=int(rng.choice([12, 16, 60, 90])),
                    max_new_tokens=int(rng.integers(4, 12)),
                    arrival=0.0, task_type=TaskType.ONLINE)
            for i in range(args.requests)]
    engine.submit(reqs)
    t0 = time.perf_counter()
    done = engine.run(max_wall_s=600)
    dt = time.perf_counter() - t0

    tokens = sum(r.generated for r in done)
    print(f"\nserved {len(done)}/{len(reqs)} requests, {tokens} tokens "
          f"in {dt:.1f}s ({tokens / dt:.1f} tok/s on CPU)")
    print(f"buckets now: {[(b.low, b.up) for b in sched.buckets.buckets]}")
    print(f"prefill executables compiled: {engine.n_prefill_shapes} "
          f"(bucketing bounds recompilation — DESIGN.md §3)")
    if args.chunk:
        print(f"decode steps interleaved between prefill chunks: "
              f"{engine.interleaved_decode_steps}")
    for r in done[:5]:
        print(f"  rid={r.rid:3d} S={r.prompt_len:3d} new={r.generated:2d} "
              f"out={engine.outputs[r.rid][:8]}")


if __name__ == "__main__":
    main()
