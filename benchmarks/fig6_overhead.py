"""Fig. 6a/6b: execution-time breakdown + bucketing overhead scaling.

Paper claims: decode ≈ 90% of e2e time; bucketing+batching overhead < 1%
of total; overhead stays flat as the bucket count grows.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.bucket import BucketManager
from repro.core.request import Request, TaskType

from .common import emit, online_spec, run_system


def breakdown(quick: bool = False):
    rows = []
    for rps in ((8,) if quick else (2, 8, 32)):
        res, _, _ = run_system("bucketserve",
                               online_spec("mixed", rps,
                                           n=60 if quick else 200))
        tot = (res.prefill_time_total + res.decode_time_total
               + res.transfer_time_total + res.bucketing_overhead_s)
        rows.append(["fig6a_breakdown", rps,
                     round(res.prefill_time_total / tot, 4),
                     round(res.decode_time_total / tot, 4),
                     round(res.transfer_time_total / tot, 4),
                     round(res.bucketing_overhead_s / tot, 6),
                     round(res.bucketing_overhead_s / res.makespan, 6)])
    emit(rows, ["table", "rps", "prefill_frac", "decode_frac",
                "transfer_frac", "bucketing_frac", "overhead_vs_makespan"])


def overhead_scaling(quick: bool = False):
    """Algorithm 1 wall cost vs. number of buckets (paper Fig. 6b)."""
    rows = []
    rng = np.random.default_rng(0)
    n_lens = 512 if quick else 4096
    for target_buckets in ((1, 4) if quick else (1, 2, 4, 8, 16, 32)):
        bm = BucketManager(32768)
        lens = np.clip(rng.lognormal(5.5, 1.6, n_lens), 1, 32767).astype(int)
        reqs = [Request(rid=i, prompt_len=int(s), max_new_tokens=8,
                        arrival=0.0, task_type=TaskType.OFFLINE)
                for i, s in enumerate(lens)]
        t0 = time.perf_counter()
        for r in reqs:
            bm.add(r)
        # force splits down to the target bucket count
        while len(bm.buckets) < target_buckets:
            before = len(bm.buckets)
            bm.adjust(n_max=max(1, bm.total() // (2 * target_buckets)))
            if len(bm.buckets) == before:
                break
        wall = time.perf_counter() - t0
        rows.append(["fig6b_overhead", len(bm.buckets),
                     round(wall * 1e6 / len(reqs), 3),
                     round(wall * 1e3, 3)])
    emit(rows, ["table", "n_buckets", "us_per_request", "total_ms"])


def main(quick: bool = False):
    breakdown(quick)
    overhead_scaling(quick)


if __name__ == "__main__":
    main()
