"""Benchmark entrypoint: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5_offline,...]
                                            [--quick]

Prints CSV blocks (``table,...`` rows) plus derived paper-claim ratios.
``--quick`` runs every table at reduced load (CI smoke: exercises the
full scheduler/loop stack in a couple of minutes so the perf scripts
can't silently rot; the printed ratios are NOT paper-comparable).
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (arch_sweep, fig5_capacity, fig5_offline, fig5_slo,
               fig6_overhead, kv_quant, kv_spill, prefix_cache, roofline,
               session_reuse, trace_replay, waste_model)

TABLES = {
    "fig5_offline": fig5_offline.main,     # Fig. 5a/5b
    "fig5_slo": fig5_slo.main,             # Fig. 5c/5d
    "fig5_capacity": fig5_capacity.main,   # Fig. 5e/5f
    "fig6_overhead": fig6_overhead.main,   # Fig. 6a/6b
    "waste_model": waste_model.main,       # Eqs. (2)-(4)
    "arch_sweep": arch_sweep.main,         # beyond-paper: all 10 archs
    "kv_quant": kv_quant.main,             # beyond-paper: int8 KV cache
    "prefix_cache": prefix_cache.main,     # beyond-paper: prefix sharing
    "session_reuse": session_reuse.main,   # beyond-paper: session resume
    "kv_spill": kv_spill.main,             # beyond-paper: host spill tier
    "trace_replay": trace_replay.main,     # beyond-paper: burst tails
    "roofline": roofline.main,             # §Roofline (dry-run derived)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="reduced-load smoke pass (CI)")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    failed = []
    for name, fn in TABLES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"### {name}")
        try:
            fn(quick=args.quick)
        except Exception as e:  # keep the harness running
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        print(f"### {name} done in {time.time() - t0:.1f}s\n", flush=True)
    if failed:
        sys.exit(f"benchmarks failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
