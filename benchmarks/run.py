"""Benchmark entrypoint: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5_offline,...]
                                            [--quick]

Prints CSV blocks (``table,...`` rows) plus derived paper-claim ratios.
``--quick`` runs every table at reduced load (CI smoke: exercises the
full scheduler/loop stack in a couple of minutes so the perf scripts
can't silently rot; the printed ratios are NOT paper-comparable).

Each table ALSO persists a machine-readable ``BENCH_<table>.json``
artifact (``--out-dir``, default cwd): every CSV block it printed, the
gate verdict (a table FAILS by raising — usually an AssertionError from
one of its paper-claim gates), wall time, git sha, and run config —
``--quick`` emits them too, so CI uploads a comparable trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from . import (arch_sweep, chaos, common, fig5_capacity, fig5_offline,
               fig5_slo, fig6_overhead, kv_quant, kv_spill, prefix_cache,
               roofline, session_reuse, trace_replay, waste_model)

TABLES = {
    "fig5_offline": fig5_offline.main,     # Fig. 5a/5b
    "fig5_slo": fig5_slo.main,             # Fig. 5c/5d
    "fig5_capacity": fig5_capacity.main,   # Fig. 5e/5f
    "fig6_overhead": fig6_overhead.main,   # Fig. 6a/6b
    "waste_model": waste_model.main,       # Eqs. (2)-(4)
    "arch_sweep": arch_sweep.main,         # beyond-paper: all 10 archs
    "kv_quant": kv_quant.main,             # beyond-paper: int8 KV cache
    "prefix_cache": prefix_cache.main,     # beyond-paper: prefix sharing
    "session_reuse": session_reuse.main,   # beyond-paper: session resume
    "kv_spill": kv_spill.main,             # beyond-paper: host spill tier
    "trace_replay": trace_replay.main,     # beyond-paper: burst tails
    "chaos": chaos.main,                   # beyond-paper: fault storm
    "roofline": roofline.main,             # §Roofline (dry-run derived)
}


def _git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="reduced-load smoke pass (CI)")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<table>.json artifacts")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    sha = _git_sha()
    failed = []
    for name, fn in TABLES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"### {name}")
        common.reset_capture()
        err = None
        try:
            fn(quick=args.quick)
        except Exception as e:  # keep the harness running
            failed.append(name)
            err = f"{type(e).__name__}: {e}"
            print(f"{name},ERROR,{err}")
        wall = time.time() - t0
        art = {"table": name, "passed": err is None, "error": err,
               "git_sha": sha, "wall_s": round(wall, 3),
               "config": {"quick": args.quick, "argv": sys.argv[1:]},
               "tables": common.captured()}
        path = os.path.join(args.out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(art, f, indent=1)
        print(f"### {name} done in {wall:.1f}s -> {path}\n", flush=True)
    if failed:
        sys.exit(f"benchmarks failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
