"""Seeded fault-storm chaos gate (DESIGN.md §9, beyond-paper).

The heterogeneous burst workload (trace_replay's recipe at gate scale)
runs three times through the full paged/prefix/session serving stack:
once fault-free (the reference) and twice under an IDENTICAL seeded
:class:`FaultPlan` arming every injection site — transient decode-step
device errors, prefill-chunk failures, restore-channel stalls and hard
errors, host-slot bit-rot, maintain-tick hiccups.

CI gates (the harness, benchmarks/run.py, exits nonzero on any
AssertionError):
  (1) zero lost / zero duplicated requests: every submitted request
      ends terminal (finished or dropped), rids stay unique, and every
      finished request generated exactly ``max_new_tokens``;
  (2) invariants survive the storm: every latency ledger closes and
      conserves to 1e-6 (``fault_retry`` included) and the block
      allocator balances exactly (free + unique-live == n_pages,
      free-host + spilled == host_pages);
  (3) the storm is deterministic: both faulted runs produce
      bit-identical final request states AND bit-identical injector
      fire logs — chaos replays;
  (4) recovery is work-preserving, not merely survivable: storm
      goodput (output tok/s) stays within a bounded factor of the
      fault-free reference.
"""
from __future__ import annotations

import time

from repro.core.batcher import MemoryBudget
from repro.core.faults import FaultPlan
from repro.core.scheduler import BucketServeScheduler, SchedulerConfig
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.workload import DEFAULT_CLASS_MIX, WorkloadSpec, generate

from .common import CFG, emit

PAGE = 128

# every site armed; rates hot enough that each recovery path fires at
# gate scale yet most requests still complete (the goodput gate needs a
# serving system, not a crash loop)
STORM = FaultPlan(seed=11, rates={
    "decode_step": 0.03, "prefill_chunk": 0.08, "restore_stall": 0.3,
    "restore_error": 0.3, "host_corrupt": 0.15, "maintain_tick": 0.05},
    stall_s=0.4)

# gate (4): recovery overhead bound.  Retries, backoff, restart
# penalties and quarantines cost real throughput; losing more than
# 60% of fault-free goodput at these rates means recovery is burning
# work it should preserve.
MIN_GOODPUT_RATIO = 0.4


def _run(plan, *, n, slots):
    budget = MemoryBudget(hbm_bytes_per_device=40 * 2 ** 30, n_devices=3,
                          weight_bytes=CFG.param_count() * 2)
    sched = BucketServeScheduler(CFG, budget, SchedulerConfig(
        max_batch=8, memory_model="paged", page_size=PAGE))
    sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                    decode_slot_cap=slots, paged=True, page_size=PAGE,
                    kv_pool_tokens=16 * 1024, prefix_cache=True,
                    session_ttl=600.0, host_pool_tokens=64 * 1024,
                    fault_plan=plan)
    spec = WorkloadSpec(rps=6.0, n_requests=n,
                        max_model_len=CFG.max_seq_len,
                        vocab_size=CFG.vocab_size,
                        class_mix=DEFAULT_CLASS_MIX, burst_factor=4.0,
                        diurnal_period_s=40.0, burst_every_s=15.0,
                        burst_duration_s=4.0, prefix_groups=4,
                        prefix_tokens=2 * PAGE, sessions=8, turns=3,
                        think_time_s=2.0, seed=7)
    reqs = generate(spec)
    t0 = time.perf_counter()
    res = sim.run(reqs, time_limit=40000.0)
    return res, sim, len(reqs), time.perf_counter() - t0


def _states(res):
    return sorted((r.rid, r.finished, r.first_token, r.generated,
                   r.dropped, r.quarantined) for r in res.requests)


def _gate_terminal_conserved(res, n_submitted, name):
    rids = [r.rid for r in res.requests]
    assert len(rids) == len(set(rids)) == n_submitted, \
        f"{name}: {len(rids)} results for {n_submitted} submitted"
    for r in res.requests:
        assert r.finished >= 0 or r.dropped, \
            f"{name}: rid {r.rid} lost (neither finished nor dropped)"
        if r.finished >= 0 and not r.dropped:
            assert r.generated == r.max_new_tokens, \
                f"{name}: rid {r.rid} finished short/long"
        led = r.ledger
        assert led is not None and led.closed, \
            f"{name}: rid {r.rid} ledger left open"
        assert led.conserved(), \
            f"{name}: rid {r.rid} ledger residual {led.residual()}"


def _gate_alloc(sim, name):
    a = sim.loop.backend.alloc
    assert a.free_pages() + a.live_pages() == a.n_pages, \
        f"{name}: device pages leaked"
    assert a.free_host_slots() + a.spilled_slots() == a.host_pages, \
        f"{name}: host slots leaked"


def main(quick: bool = False) -> None:
    n = 48 if quick else 120
    slots = 64
    runs = [("reference", None), ("storm", STORM), ("storm-replay", STORM)]
    rows, by_name, sims, counts = [], {}, {}, {}
    for name, plan in runs:
        res, sim, n_sub, wall = _run(plan, n=n, slots=slots)
        by_name[name], sims[name], counts[name] = res, sim, n_sub
        rows.append([
            "chaos", name, n_sub,
            sum(1 for r in res.requests if r.finished >= 0),
            sum(1 for r in res.requests if r.dropped),
            res.fault_events, res.fault_retries, res.fault_kills,
            res.quarantined, res.restore_stalls, res.restore_retries,
            res.restore_failures, res.restore_sheds, res.restore_timeouts,
            res.corruptions,
            f"{res.output_tok_s():.1f}", f"{res.slo_attainment():.3f}",
            f"{res.makespan:.2f}", f"{wall:.1f}"])
    emit(rows, ["table", "run", "submitted", "finished", "dropped",
                "faults", "retries", "kills", "quarantined", "stalls",
                "rst_retries", "rst_failures", "sheds", "timeouts",
                "corruptions", "out_tok_s", "slo_att", "makespan_s",
                "wall_s"])

    ref, storm = by_name["reference"], by_name["storm"]
    # gates (1) + (2) on every run, faulted or not
    for name in by_name:
        _gate_terminal_conserved(by_name[name], counts[name], name)
        _gate_alloc(sims[name], name)
    # the reference is actually fault-free and the storm actually stormed
    assert ref.fault_events == 0 and ref.quarantined == 0
    assert storm.fault_events > 0 and storm.fault_retries > 0, \
        "storm fired no faults — the plan is dead, the gate is vacuous"
    # gate (3): bit-identical replay
    assert _states(by_name["storm"]) == _states(by_name["storm-replay"]), \
        "storm replay diverged — fault decisions are not deterministic"
    assert sims["storm"].faults.log == sims["storm-replay"].faults.log, \
        "injector fire logs diverged between identical storm runs"
    # gate (4): bounded goodput degradation
    ratio = storm.output_tok_s() / max(ref.output_tok_s(), 1e-9)
    assert ratio >= MIN_GOODPUT_RATIO, \
        (f"storm goodput {storm.output_tok_s():.1f} tok/s is "
         f"{ratio:.2f}x the fault-free {ref.output_tok_s():.1f} — "
         f"recovery burned more than {1 - MIN_GOODPUT_RATIO:.0%} of "
         "the machine")
    print(f"claim,storm_goodput_ratio,{ratio:.3f}")
    print(f"claim,storm_slo_attainment,{storm.slo_attainment():.3f}")
    print(f"claim,storm_fault_events,{storm.fault_events}")
    print(f"claim,storm_quarantined,{storm.quarantined}")
    print()
