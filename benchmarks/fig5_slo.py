"""Fig. 5c/5d recast: SLO attainment vs load, goodput scheduler edition.

Paper claim (Fig. 5): at 80% attainment BucketServe sustains 1.37x /
1.93x the RPS of DistServe.  This table runs the SAME shape of
experiment one level up the stack (PR 9, DESIGN.md §8): arrival-order
BucketServe vs the deadline-slack GoodputScheduler, both forming
size-homogeneous bucket batches on the identical disagg + paged +
retention deployment, driven by the PR 7 heterogeneous burst trace
(chat 2s-TTFT / longctx 10s / batch 120s class SLOs, 4x burst
windows).  Arrival order is blind to the 60x spread in TTFT budgets;
deadline-slack scoring spends the queue on the requests that can
still earn goodput.

CI gates (benchmarks/run.py exits nonzero on any AssertionError):
  (1) equal offered load, literally: the head-to-head replays ONE
      recorded trace (data/trace.py, the PR 7 machinery) through both
      schedulers — the goodput scheduler must achieve strictly higher
      goodput (SLO-met requests per second) than arrival-order
      BucketServe on that trace;
  (2) load sweep: the goodput scheduler sustains >= 1.5x the offered
      load of FCFS arrival order at 80% SLO attainment (the paper's
      capacity metric, applied to the class-SLO mix).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time

from repro.core.batcher import MemoryBudget
from repro.core.scheduler import (BucketServeScheduler, GoodputScheduler,
                                  SchedulerConfig)
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.trace import TraceRecorder, TraceWorkload
from repro.data.workload import DEFAULT_CLASS_MIX, WorkloadSpec, generate

from .common import CFG, emit

# Deployment identical to benchmarks/trace_replay.py: decode-heavy 1:3
# chip split, tight paged pool + host spill tier, prefix cache +
# session retention all active — every sacrifice point the slack-aware
# orderings touch is live.
PAGE = 128
MAX_BATCH = 8
SLOT_CAP = 64
POOL_TOKENS = 16 * 1024
HOST_TOKENS = 64 * 1024
BUCKET_HW = dataclasses.replace(A100X4, prefill_chips=1, decode_chips=3)

#: offered load for the equal-load head-to-head (gate 1) — deep in the
#: contended regime (arrival order is ~50% attainment here).
GATE_RPS = 1.0
RPS_GRID = [0.25, 0.5, 0.75, 1.0, 1.5, 2.0]
QUICK_GRID = [0.25, 0.5, 1.0, 2.0]

SCHEDS = (("bucket", BucketServeScheduler), ("goodput", GoodputScheduler))


def _spec(rps: float, n: int) -> WorkloadSpec:
    return WorkloadSpec(rps=rps, n_requests=n,
                        max_model_len=CFG.max_seq_len,
                        vocab_size=CFG.vocab_size,
                        class_mix=DEFAULT_CLASS_MIX, burst_factor=4.0,
                        diurnal_period_s=40.0, burst_every_s=15.0,
                        burst_duration_s=4.0,
                        prefix_groups=4, prefix_tokens=2 * PAGE,
                        sessions=8, turns=3, think_time_s=2.0,
                        seed=7)


def _sim(sched_cls, recorder=None):
    budget = MemoryBudget(hbm_bytes_per_device=BUCKET_HW.hbm_bytes,
                          n_devices=BUCKET_HW.decode_chips,
                          weight_bytes=CFG.param_count() * 2)
    sched = sched_cls(CFG, budget, SchedulerConfig(
        max_batch=MAX_BATCH, memory_model="paged", page_size=PAGE))
    sim = Simulator(sched, CostModel(CFG, BUCKET_HW), mode="disagg",
                    decode_slot_cap=SLOT_CAP, paged=True, page_size=PAGE,
                    kv_pool_tokens=POOL_TOKENS, prefix_cache=True,
                    session_ttl=600.0, host_pool_tokens=HOST_TOKENS,
                    recorder=recorder)
    return sched, sim


def rps_at(curve, target: float) -> float:
    """Offered load the attainment curve SUSTAINS at `target`: the
    rightmost crossing (linear interpolation between grid points), so a
    scheduler that dips and recovers is credited with the recovery."""
    best = 0.0
    for (r0, a0), (r1, a1) in zip(curve, curve[1:]):
        if a0 >= target:
            best = max(best, r0)
        if a0 >= target > a1 and a0 > a1:
            frac = (a0 - target) / (a0 - a1)
            best = max(best, r0 + frac * (r1 - r0))
    if curve and curve[-1][1] >= target:
        best = max(best, curve[-1][0])
    return best


def main(quick: bool = False) -> None:
    n = 80 if quick else 120
    t0 = time.perf_counter()

    # ---- gate (1): head-to-head on ONE recorded trace ----------------
    rec = TraceRecorder()
    _, sim_b = _sim(BucketServeScheduler, recorder=rec)
    res = {"bucket": sim_b.run(generate(_spec(GATE_RPS, n)))}
    path = os.path.join(tempfile.mkdtemp(prefix="fig5_goodput_"),
                        "gate.jsonl")
    rec.save(path, meta={"spec": "fig5-goodput-gate", "rps": GATE_RPS})
    tw = TraceWorkload(path)
    _, sim_g = _sim(GoodputScheduler)
    res["goodput"] = sim_g.run(tw.requests())

    rows = []
    for name, r in res.items():
        row = [name, f"{GATE_RPS:.2f}", len(r.finished()), r.incomplete(),
               f"{r.goodput():.3f}", f"{r.slo_attainment():.3f}"]
        for cls in ("chat", "longctx", "batch"):
            row += [f"{r.slo_attainment(cls):.3f}",
                    f"{r.p50('ttft', cls):.2f}", f"{r.p99('ttft', cls):.2f}",
                    f"{r.p99('tpot', cls) * 1e3:.1f}"]
        rows.append(row)
    hdr = ["system", "client_rps", "finished", "incomplete",
           "goodput_rps", "slo_all"]
    for cls in ("chat", "longctx", "batch"):
        hdr += [f"slo_{cls}", f"{cls}_p50_ttft_s", f"{cls}_p99_ttft_s",
                f"{cls}_p99_tpot_ms"]
    emit(rows, hdr)

    gp_b, gp_g = res["bucket"].goodput(), res["goodput"].goodput()
    assert gp_g > gp_b, \
        f"goodput scheduler must beat arrival order: {gp_g:.3f} <= {gp_b:.3f}"
    # no gaming by shedding: the win is on finished-in-budget work AND
    # nothing is left unserved that arrival order served
    assert res["goodput"].incomplete() <= res["bucket"].incomplete()

    # ---- gate (2): attainment-vs-load sweep --------------------------
    grid = QUICK_GRID if quick else RPS_GRID
    rows, curves = [], {}
    for name, cls_ in SCHEDS:
        curve = []
        for rps in grid:
            _, sim = _sim(cls_)
            r = sim.run(generate(_spec(rps, n)))
            curve.append((rps, r.slo_attainment()))
            rows.append(["fig5_goodput_sweep", name, rps,
                         round(r.slo_attainment(), 3),
                         round(r.goodput(), 3),
                         round(r.slo_attainment("chat"), 3)])
        curves[name] = curve
    emit(rows, ["table", "system", "client_rps", "slo_attainment",
                "goodput_rps", "slo_chat"])

    cap_b = rps_at(curves["bucket"], 0.8)
    cap_g = rps_at(curves["goodput"], 0.8)
    ratio = cap_g / max(cap_b, 1e-9)
    assert cap_g > 0.0, "goodput scheduler never reached 80% attainment"
    assert ratio >= 1.5, \
        f"need >=1.5x FCFS load at 80% attainment, got {ratio:.2f} " \
        f"(goodput {cap_g:.2f} vs fcfs {cap_b:.2f})"

    print(f"fig5_goodput_ratio,rps_at_80pct,goodput={cap_g:.2f},"
          f"fcfs={cap_b:.2f},ratio={ratio:.2f},"
          f"gate_goodput_edge={gp_g / max(gp_b, 1e-9):.2f}x,"
          f"wall,{time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
