"""Fig. 5c/5d: SLO attainment vs. server RPS (Alpaca and Mixed).

Paper claim: at 80% attainment BucketServe sustains 1.37x (Alpaca) and
1.93x (Mixed) the RPS of DistServe.
"""
from __future__ import annotations

import numpy as np

from .common import PAPER_SYSTEMS, emit, online_spec, run_system

RPS_GRID = [0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 5.0, 6.0]
QUICK_GRID = [0.5, 2.0, 4.0]


def attainment_curve(name: str, dataset: str, grid=RPS_GRID, n: int = 300):
    out = []
    for rps in grid:
        res, _, _ = run_system(name, online_spec(dataset, rps, n=n))
        out.append((rps, res.slo_attainment(), res.server_rps()))
    return out


def rps_at(curve, target: float) -> float:
    """Server RPS where the attainment curve crosses `target`
    (linear interpolation between grid points)."""
    best = 0.0
    for (r0, a0, s0), (r1, a1, s1) in zip(curve, curve[1:]):
        if a0 >= target:
            best = max(best, s0)
        if a0 >= target > a1 and a0 > a1:
            frac = (a0 - target) / (a0 - a1)
            best = max(best, s0 + frac * (s1 - s0))
    if curve and curve[-1][1] >= target:
        best = max(best, curve[-1][2])
    return best


def main(quick: bool = False):
    grid = QUICK_GRID if quick else RPS_GRID
    n = 60 if quick else 300
    rows = []
    capacity = {}
    for dataset in ("alpaca", "mixed"):
        for name in PAPER_SYSTEMS:
            curve = attainment_curve(name, dataset, grid=grid, n=n)
            for rps, att, srv in curve:
                rows.append(["fig5cd_slo", dataset, name, rps,
                             round(att, 3), round(srv, 3)])
            capacity[(dataset, name)] = rps_at(curve, 0.8)
    emit(rows, ["table", "dataset", "system", "client_rps", "slo_attainment",
                "server_rps"])
    for dataset, paper in (("alpaca", 1.37), ("mixed", 1.93)):
        ours = capacity[(dataset, "bucketserve")]
        dist = capacity[(dataset, "distserve")]
        ratio = ours / max(dist, 1e-9)
        print(f"fig5cd_ratio,rps_at_80pct_{dataset},"
              f"bucketserve={ours:.2f},distserve={dist:.2f},"
              f"ratio={ratio:.2f},paper={paper}")
        # past-knee robustness: attainment at 1.4x the knee load — where
        # bucketing is active (deep queues) the systems separate sharply
        knee = max(grid[0],
                   min(grid[-1], 1.4 * max(dist, grid[0])))
        for name in PAPER_SYSTEMS:
            res, _, _ = run_system(name, online_spec(dataset, knee, n=n))
            print(f"fig5cd_pastknee,{dataset},{name},client_rps={knee:.2f},"
                  f"attainment={res.slo_attainment():.3f},"
                  f"server_rps={res.server_rps():.2f}")
    print()


if __name__ == "__main__":
    main()
