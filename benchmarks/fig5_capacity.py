"""Fig. 5e/5f: server RPS vs. client RPS (system load capacity).

Paper claims: BucketServe tracks the ideal y=x line furthest; 1.975x
UELLM capacity on Alpaca, 1.4x DistServe / 3.47x UELLM on Mixed.
"""
from __future__ import annotations

from .common import PAPER_SYSTEMS, emit, online_spec, run_system

CLIENT_RPS = [0.5, 1, 2, 3, 4, 6, 8]
QUICK_RPS = [1, 4]


def main(quick: bool = False):
    client_rps = QUICK_RPS if quick else CLIENT_RPS
    n = 60 if quick else 150
    rows = []
    peak = {}
    for dataset in ("alpaca", "mixed"):
        for name in PAPER_SYSTEMS:
            best = 0.0
            for rps in client_rps:
                res, _, _ = run_system(name, online_spec(dataset, rps, n=n))
                srv = res.server_rps()
                best = max(best, srv)
                rows.append(["fig5ef_capacity", dataset, name, rps,
                             round(srv, 3)])
            peak[(dataset, name)] = best
    emit(rows, ["table", "dataset", "system", "client_rps", "server_rps"])
    for dataset, base, paper in (("alpaca", "uellm", 1.975),
                                 ("mixed", "distserve", 1.4),
                                 ("mixed", "uellm", 3.47)):
        ratio = peak[(dataset, "bucketserve")] / max(peak[(dataset, base)],
                                                     1e-9)
        print(f"fig5ef_ratio,{dataset}_vs_{base},{ratio:.2f},paper={paper}")
    print()


if __name__ == "__main__":
    main()
