"""Roofline table from the dry-run sweep (results/dryrun.json).

Per (arch x shape) on the single-pod mesh: the three terms in seconds,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the kernel-fused
variant.  Falls back to a note when the sweep JSON is absent.
"""
from __future__ import annotations

import json
import os

RESULTS = os.environ.get("REPRO_DRYRUN_JSON", "results/dryrun.json")


def load():
    try:
        with open(RESULTS) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def main(quick: bool = False):
    data = load()
    if not data:
        print("roofline,NO_DATA,run `python -m repro.launch.dryrun --all`")
        return
    hdr = ["table", "arch", "shape", "mesh", "variant", "compute_s",
           "memory_s", "collective_s", "dominant", "useful_ratio",
           "fused_memory_s", "fused_dominant", "temp_GiB_per_dev", "status"]
    print(",".join(hdr))
    for key in sorted(data):
        rec = data[key]
        arch, shape, mesh = key.split("|")
        if "skipped" in rec:
            print(f"roofline,{arch},{shape},{mesh},,,,,,,,,SKIP:"
                  f"{rec['skipped'][:40].replace(',', ';')}")
            continue
        if "error" in rec:
            print(f"roofline,{arch},{shape},{mesh},,,,,,,,,"
                  f"ERROR:{rec['error'][:40].replace(',', ';')}")
            continue
        r, rf = rec["roofline"], rec["roofline_fused"]
        print(",".join(str(x) for x in [
            "roofline", arch, shape, mesh, rec.get("variant", ""),
            f"{r['compute_s']:.4f}", f"{r['memory_s']:.4f}",
            f"{r['collective_s']:.4f}", r["dominant"],
            f"{r['useful_ratio']:.3f}", f"{rf['memory_s']:.4f}",
            rf["dominant"],
            f"{rec['memory']['temp_bytes'] / 2**30:.2f}", "ok"]))
    print()


if __name__ == "__main__":
    main()
