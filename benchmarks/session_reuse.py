"""Multi-turn session retention: prefix-only vs session-resumed run.

Beyond-paper table (PR 4, DESIGN.md §3 "Session retention"): the paged
cost model serves the SAME multi-turn conversation workload
(sessions x turns transcript growth, data/workload.py) twice — with
the PR 3 radix prefix cache alone (turn N+1 reuses only its PROMPT-
prefix pages), then with session retention on top (generated pages
extend the radix path and the pinned tail hands over, so turn N+1
resumes past the whole transcript) — and reports prompt tokens
actually prefilled, session hit rate, tails reused and throughput.

CI gate: the session-resumed run must prefill STRICTLY FEWER total
prompt tokens than the prefix-only run — the delta is exactly what
SESSION retention adds, so a dead session-resume path cannot hide
behind radix savings (a regression here means release-time
registration, the session lookup/claim or the tail hand-over rotted);
the harness (benchmarks/run.py) exits nonzero on the raised
AssertionError.
"""
from __future__ import annotations

import time

from repro.core.batcher import MemoryBudget
from repro.core.request import TaskType
from repro.core.scheduler import BucketServeScheduler, SchedulerConfig
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.workload import WorkloadSpec, generate

from .common import CFG, emit

PAGE = 128


def _run(spec: WorkloadSpec, *, session_ttl, slots: int):
    reqs = generate(spec)
    budget = MemoryBudget(hbm_bytes_per_device=A100X4.hbm_bytes,
                          n_devices=A100X4.decode_chips,
                          weight_bytes=CFG.param_count() * 2)
    sched = BucketServeScheduler(CFG, budget, SchedulerConfig(
        max_batch=slots, memory_model="paged", page_size=PAGE))
    # the PR 3 radix stays ON in both runs: the gate must isolate what
    # SESSION retention adds (generated-page paths + pinned tails) over
    # plain prompt-prefix sharing, or a dead session-resume path would
    # hide behind radix savings
    sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                    decode_slot_cap=slots, paged=True, page_size=PAGE,
                    prefix_cache=True, session_ttl=session_ttl)
    t0 = time.perf_counter()
    res = sim.run(reqs, time_limit=7200.0)
    return res, time.perf_counter() - t0


def main(quick: bool = False) -> None:
    sessions = 8 if quick else 32
    turns = 3 if quick else 5
    spec = WorkloadSpec(dataset="alpaca", rps=4.0, sessions=sessions,
                        turns=turns, utterance_tokens=512,
                        max_new_tokens=64 if quick else 128,
                        think_time_s=2.0, task_type=TaskType.OFFLINE,
                        max_model_len=CFG.max_seq_len, seed=0,
                        vocab_size=CFG.vocab_size)
    rows = []
    by_mode = {}
    for ttl in (None, 600.0):
        res, wall = _run(spec, session_ttl=ttl, slots=32)
        by_mode[ttl] = res
        rows.append([
            "session_reuse", "resumed" if ttl is not None else "prefix-only",
            sessions, turns, res.prefill_tokens_processed,
            res.prefill_tokens_skipped,
            f"{res.session_hits}/{res.session_lookups}",
            res.session_hit_tokens, res.tail_pages_reused,
            res.sessions_expired + res.sessions_evicted,
            f"{res.output_tok_s():.1f}", f"{res.makespan:.2f}",
            f"{wall:.1f}"])
    emit(rows, ["table", "mode", "sessions", "turns", "prefill_tokens",
                "tokens_skipped", "session_hits", "hit_tokens",
                "tails_reused", "unpinned", "out_tok_s", "makespan_s",
                "wall_s"])
    cold = by_mode[None]
    warm = by_mode[600.0]
    assert warm.prefill_tokens_processed < cold.prefill_tokens_processed, \
        (f"session-resumed run prefilled {warm.prefill_tokens_processed} "
         f">= the prefix-only run's {cold.prefill_tokens_processed} prompt "
         "tokens — session retention added nothing over the radix")
    red = 1 - warm.prefill_tokens_processed / max(
        cold.prefill_tokens_processed, 1)
    print(f"claim,prefill_token_reduction,{red:.3f}")
    print(f"claim,session_hit_rate,{warm.session_hit_rate():.3f}")
    print(f"claim,throughput_ratio,"
          f"{warm.output_tok_s() / max(cold.output_tok_s(), 1e-9):.3f}")
    print()
