"""Host-RAM KV spill tier: cold vs unpin vs spill, across spill dtypes.

Beyond-paper table (PR 5 + quantized tiers, DESIGN.md §3 "Host spill
tier" / "Tier precision"): the paged cost model serves the SAME
multi-turn conversation workload under an HBM pool deliberately too
small to retain every session —

* ``cold``  — paged pool only, no retention: every turn re-prefills its
  whole transcript (the pre-PR-3 floor);
* ``unpin`` — PR 4 retention: radix + session tails, but eviction under
  pressure DESTROYS retained pages, so squeezed-out sessions pay a full
  re-prefill on their next turn;
* ``spill-bf16/int8/int4`` — the host tier at each spill precision,
  all under the SAME ``host_pool_tokens`` budget.  The budget is a
  byte quantity (``host_tier_geometry``), so a compressed tier holds
  ~2x (int8) / ~3.5x (int4) more transcript pages AND each restore
  moves proportionally fewer PCIe bytes.

The host budget is deliberately TIGHT (a small multiple of the device
pool): the bf16 tier saturates and drops warm transcripts to its host
LRU, which is exactly the regime where compression pays.

CI gates (the harness, benchmarks/run.py, exits nonzero on any
AssertionError):
  (1) every run's composed prompts are BIT-IDENTICAL across all modes
      — a restore that corrupted or clamped transcripts shows up here;
  (2) the bf16 spill run re-prefills STRICTLY FEWER prompt tokens than
      the unpin run — the tier buys real work, not PR 4 savings;
  (3) int8/int4 spill moves STRICTLY FEWER bytes per spilled page than
      bf16 (compression actually happened on the wire);
  (4) at the same host budget, the int4 tier ends the run retaining
      >= 2x the bf16 tier's host pages (or, if saturation patterns
      differ, strictly fewer ``spill_drops``) AND spends strictly less
      total restore time — the quantized-tiers acceptance claim.
"""
from __future__ import annotations

import time

from repro.core.batcher import MemoryBudget
from repro.core.request import TaskType
from repro.core.scheduler import BucketServeScheduler, SchedulerConfig
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.workload import WorkloadSpec, generate

from .common import CFG, emit

PAGE = 128


def _run(spec: WorkloadSpec, *, session_ttl, host_pool_tokens,
         pool_tokens: int, slots: int, prefix_cache: bool = True,
         spill_dtype: str = "bf16"):
    reqs = generate(spec)
    budget = MemoryBudget(hbm_bytes_per_device=A100X4.hbm_bytes,
                          n_devices=A100X4.decode_chips,
                          weight_bytes=CFG.param_count() * 2)
    sched = BucketServeScheduler(CFG, budget, SchedulerConfig(
        max_batch=slots, memory_model="paged", page_size=PAGE))
    sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                    decode_slot_cap=slots, paged=True, page_size=PAGE,
                    kv_pool_tokens=pool_tokens, prefix_cache=prefix_cache,
                    session_ttl=session_ttl,
                    host_pool_tokens=host_pool_tokens,
                    spill_dtype=spill_dtype)
    t0 = time.perf_counter()
    res = sim.run(reqs, time_limit=14400.0)
    ids = {}
    for r in res.requests:
        ids[r.rid] = None if r.tokens is None else r.tokens.tolist()
    return res, ids, sim.backend, time.perf_counter() - t0


def main(quick: bool = False) -> None:
    sessions = 12 if quick else 24
    turns = 3
    utter = 384 if quick else 512
    slots = 8 if quick else 16
    # the pool holds one max-length request plus a few transcripts:
    # retention pressure is structural, not incidental
    pool_tokens = (40 if quick else 128) * PAGE
    # TIGHT host budget — host_tokens is a bf16-reference byte budget,
    # so this buys exactly 32 (96) bf16 slots but ~3.8x that many int4
    # slots.  Sized at roughly a third of the workload's spill demand so
    # the bf16 tier saturates and drops warm transcripts (the regime
    # compression rescues) while the int4 tier still holds everything
    host_tokens = (32 if quick else 96) * PAGE
    spec = WorkloadSpec(dataset="alpaca", rps=4.0, sessions=sessions,
                        turns=turns, utterance_tokens=utter,
                        max_new_tokens=32 if quick else 64,
                        think_time_s=2.0, task_type=TaskType.OFFLINE,
                        max_model_len=CFG.max_seq_len, seed=0,
                        vocab_size=CFG.vocab_size)
    modes = [("cold", dict(session_ttl=None, host_pool_tokens=None,
                           prefix_cache=False)),
             ("unpin", dict(session_ttl=600.0, host_pool_tokens=None))]
    for dt in ("bf16", "int8", "int4"):
        modes.append((f"spill-{dt}",
                      dict(session_ttl=600.0, host_pool_tokens=host_tokens,
                           spill_dtype=dt)))
    rows, by_mode, ids_by_mode, alloc_by_mode = [], {}, {}, {}
    for name, kw in modes:
        res, ids, backend, wall = _run(spec, pool_tokens=pool_tokens,
                                       slots=slots, **kw)
        by_mode[name] = res
        ids_by_mode[name] = ids
        alloc_by_mode[name] = backend.alloc
        rows.append([
            "kv_spill", name, sessions, turns,
            res.prefill_tokens_processed, res.prefill_tokens_skipped,
            f"{res.session_hits}/{res.session_lookups}",
            backend.alloc.host_pages, backend.alloc.spilled_slots(),
            res.spilled_pages, res.restored_pages,
            res.spilled_bytes, res.restored_bytes,
            res.spill_drops, res.spill_hold_events,
            f"{res.restore_time_total:.3f}",
            f"{res.output_tok_s():.1f}", f"{res.makespan:.2f}",
            f"{wall:.1f}"])
    emit(rows, ["table", "mode", "sessions", "turns", "prefill_tokens",
                "tokens_skipped", "session_hits", "host_slots",
                "retained_pages", "spilled_pages", "restored_pages",
                "spilled_bytes", "restored_bytes", "spill_drops",
                "holds", "restore_s", "out_tok_s", "makespan_s",
                "wall_s"])
    # gate 1: token ids identical across all modes (the cost model
    # composes transcripts from deterministic per-rid synthetic
    # generated ids, so any divergence means a run clamped/corrupted a
    # transcript)
    for name in list(by_mode):
        if name == "cold":
            continue
        assert ids_by_mode[name] == ids_by_mode["cold"], \
            f"{name} run changed token ids vs the cold run"
    # gate 2: the host tier must buy real re-prefill work beyond unpin
    unpin = by_mode["unpin"]
    bf16 = by_mode["spill-bf16"]
    int8 = by_mode["spill-int8"]
    int4 = by_mode["spill-int4"]
    assert bf16.spilled_pages > 0 and bf16.restored_pages > 0, \
        "spill run moved no pages — the tier is dead under pressure"
    assert bf16.prefill_tokens_processed < unpin.prefill_tokens_processed, \
        (f"spill run prefilled {bf16.prefill_tokens_processed} >= the "
         f"unpin run's {unpin.prefill_tokens_processed} prompt tokens — "
         "the host tier added nothing over destructive eviction")
    # gate 3: compression actually happened on the wire
    bytes_per_page = {
        n: by_mode[n].spilled_bytes / max(by_mode[n].spilled_pages, 1)
        for n in ("spill-bf16", "spill-int8", "spill-int4")}
    assert bytes_per_page["spill-int8"] < bytes_per_page["spill-bf16"], \
        f"int8 spill moved {bytes_per_page} bytes/page — not compressed"
    assert bytes_per_page["spill-int4"] < bytes_per_page["spill-int8"], \
        f"int4 spill moved {bytes_per_page} bytes/page — not compressed"
    # gate 4: the quantized-tiers acceptance claim — same host budget,
    # >= 2x retained host pages (or strictly fewer drops when the
    # saturation patterns differ) AND strictly less restore time
    ret4 = alloc_by_mode["spill-int4"].spilled_slots()
    retb = alloc_by_mode["spill-bf16"].spilled_slots()
    assert ret4 >= 2 * retb or int4.spill_drops < bf16.spill_drops, \
        (f"int4 tier retained {ret4} host pages vs bf16's {retb} and "
         f"dropped {int4.spill_drops} vs {bf16.spill_drops} — the "
         "compressed tier bought no extra retention")
    assert int4.restore_time_total < bf16.restore_time_total, \
        (f"int4 restore time {int4.restore_time_total:.3f}s >= bf16's "
         f"{bf16.restore_time_total:.3f}s — compressed restores moved "
         "no fewer PCIe bytes")
    red = 1 - bf16.prefill_tokens_processed / max(
        unpin.prefill_tokens_processed, 1)
    print(f"claim,prefill_token_reduction_vs_unpin,{red:.3f}")
    print(f"claim,session_hit_rate_spill,{bf16.session_hit_rate():.3f}")
    print(f"claim,session_hit_rate_unpin,{unpin.session_hit_rate():.3f}")
    print(f"claim,int4_retained_pages_ratio_vs_bf16,"
          f"{ret4 / max(retb, 1):.2f}")
    print(f"claim,int4_restore_time_ratio_vs_bf16,"
          f"{int4.restore_time_total / max(bf16.restore_time_total, 1e-9):.3f}")
    print(f"claim,int8_session_hit_rate,{int8.session_hit_rate():.3f}")
    print()
