"""Host-RAM KV spill tier: cold vs unpin vs spill under one HBM budget.

Beyond-paper table (PR 5, DESIGN.md §3 "Host spill tier"): the paged
cost model serves the SAME multi-turn conversation workload three times
under an HBM pool deliberately too small to retain every session —

* ``cold``  — paged pool only, no retention: every turn re-prefills its
  whole transcript (the pre-PR-3 floor);
* ``unpin`` — PR 4 retention: radix + session tails, but eviction under
  pressure DESTROYS retained pages, so squeezed-out sessions pay a full
  re-prefill on their next turn;
* ``spill`` — the host tier: the same eviction pressure COPIES cold
  retained pages to host RAM and the next turn restores them over the
  modeled PCIe link instead of re-prefilling.

CI gates: (1) the spill run must re-prefill STRICTLY FEWER prompt
tokens than the unpin run — the delta is exactly what the host tier
buys, so a dead spill/restore path cannot hide behind PR 4 savings;
(2) every run's composed prompts (transcripts are built from each
run's own generated ids) must be BIT-IDENTICAL across the three modes
— a restore that corrupted or clamped transcripts would show up here.
The harness (benchmarks/run.py) exits nonzero on the AssertionError.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.batcher import MemoryBudget
from repro.core.request import TaskType
from repro.core.scheduler import BucketServeScheduler, SchedulerConfig
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.data.workload import WorkloadSpec, generate

from .common import CFG, emit

PAGE = 128


def _run(spec: WorkloadSpec, *, session_ttl, host_pool_tokens,
         pool_tokens: int, slots: int, prefix_cache: bool = True):
    reqs = generate(spec)
    budget = MemoryBudget(hbm_bytes_per_device=A100X4.hbm_bytes,
                          n_devices=A100X4.decode_chips,
                          weight_bytes=CFG.param_count() * 2)
    sched = BucketServeScheduler(CFG, budget, SchedulerConfig(
        max_batch=slots, memory_model="paged", page_size=PAGE))
    sim = Simulator(sched, CostModel(CFG, A100X4), mode="disagg",
                    decode_slot_cap=slots, paged=True, page_size=PAGE,
                    kv_pool_tokens=pool_tokens, prefix_cache=prefix_cache,
                    session_ttl=session_ttl,
                    host_pool_tokens=host_pool_tokens)
    t0 = time.perf_counter()
    res = sim.run(reqs, time_limit=14400.0)
    ids = {}
    for r in res.requests:
        ids[r.rid] = None if r.tokens is None else r.tokens.tolist()
    return res, ids, time.perf_counter() - t0


def main(quick: bool = False) -> None:
    sessions = 6 if quick else 24
    turns = 3 if quick else 4
    utter = 384 if quick else 512
    slots = 8 if quick else 16
    # the pool holds one max-length request plus a few transcripts:
    # retention pressure is structural, not incidental
    pool_tokens = (40 if quick else 128) * PAGE
    host_tokens = 8 * pool_tokens
    spec = WorkloadSpec(dataset="alpaca", rps=4.0, sessions=sessions,
                        turns=turns, utterance_tokens=utter,
                        max_new_tokens=32 if quick else 64,
                        think_time_s=2.0, task_type=TaskType.OFFLINE,
                        max_model_len=CFG.max_seq_len, seed=0,
                        vocab_size=CFG.vocab_size)
    modes = [("cold", dict(session_ttl=None, host_pool_tokens=None,
                           prefix_cache=False)),
             ("unpin", dict(session_ttl=600.0, host_pool_tokens=None)),
             ("spill", dict(session_ttl=600.0,
                            host_pool_tokens=host_tokens))]
    rows, by_mode, ids_by_mode = [], {}, {}
    for name, kw in modes:
        res, ids, wall = _run(spec, pool_tokens=pool_tokens, slots=slots,
                              **kw)
        by_mode[name] = res
        ids_by_mode[name] = ids
        rows.append([
            "kv_spill", name, sessions, turns,
            res.prefill_tokens_processed, res.prefill_tokens_skipped,
            f"{res.session_hits}/{res.session_lookups}",
            res.spilled_pages, res.restored_pages, res.restored_tokens,
            res.spill_drops, res.spill_hold_events,
            f"{res.restore_time_total:.3f}",
            f"{res.output_tok_s():.1f}", f"{res.makespan:.2f}",
            f"{wall:.1f}"])
    emit(rows, ["table", "mode", "sessions", "turns", "prefill_tokens",
                "tokens_skipped", "session_hits", "spilled_pages",
                "restored_pages", "restored_tokens", "spill_drops",
                "holds", "restore_s", "out_tok_s", "makespan_s",
                "wall_s"])
    # gate 2: token ids identical across all three modes (the cost
    # model composes transcripts from deterministic per-rid synthetic
    # generated ids, so any divergence means a run clamped/corrupted a
    # transcript)
    for name in ("unpin", "spill"):
        assert ids_by_mode[name] == ids_by_mode["cold"], \
            f"{name} run changed token ids vs the cold run"
    # gate 1: the host tier must buy real re-prefill work beyond unpin
    unpin = by_mode["unpin"]
    spill = by_mode["spill"]
    assert spill.spilled_pages > 0 and spill.restored_pages > 0, \
        "spill run moved no pages — the tier is dead under pressure"
    assert spill.prefill_tokens_processed < unpin.prefill_tokens_processed, \
        (f"spill run prefilled {spill.prefill_tokens_processed} >= the "
         f"unpin run's {unpin.prefill_tokens_processed} prompt tokens — "
         "the host tier added nothing over destructive eviction")
    red = 1 - spill.prefill_tokens_processed / max(
        unpin.prefill_tokens_processed, 1)
    print(f"claim,prefill_token_reduction_vs_unpin,{red:.3f}")
    print(f"claim,session_hit_rate_spill,{spill.session_hit_rate():.3f}")
    print(f"claim,session_hit_rate_unpin,{unpin.session_hit_rate():.3f}")
    print(f"claim,throughput_ratio_vs_unpin,"
          f"{spill.output_tok_s() / max(unpin.output_tok_s(), 1e-9):.3f}")
    print()
