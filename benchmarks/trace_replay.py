"""Tail latency under bursty heterogeneous traffic + trace replay.

Beyond-paper table (PR 7, DESIGN.md §6): the heterogeneous trace
family — a chat/longctx/batch class mix under diurnal arrivals with
Poisson burst windows peaking at 4x the steady rate, composed with
shared prefixes AND multi-turn sessions over a deliberately tight
paged pool + host spill tier — served by BucketServe (disagg, paged,
retention) vs the static-batching baseline on the SAME recorded trace.
Gates are on P99 TTFT/TPOT, not means: the paper's SLO-attainment
claims are about the burst tail, and a mean hides exactly the convoy
effect static batching suffers there.

CI gates (the harness, benchmarks/run.py, exits nonzero on any
AssertionError):
  (1) record -> replay is BIT-IDENTICAL on the cost-model backend:
      same formed-batch log, same prompt token ids, same prefix- and
      session-hit counts, same finish times (the data/trace.py
      determinism contract, end to end);
  (2) the 4x burst demonstrably exercises the adaptive machinery:
      bucket splits AND merges > 0, spill AND restore pages > 0 —
      a burst that nothing reacts to gates nothing;
  (3) BucketServe beats static batching at the tail: strictly lower
      P99 TTFT and P99 TPOT on the same trace;
  (4) latency-ledger conservation (PR 8, core/telemetry.py): every
      retired request's phase durations sum to its end-to-end latency
      to 1e-6 on BOTH the recorded and the replayed run — and the
      blame-breakdown table shows WHY static loses the tail: raw
      queue-wait (not compute) dominates its P99 TTFT, and BucketServe
      removes most of that queue time in absolute seconds.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time

from repro.core.batcher import MemoryBudget
from repro.core.baselines import SIM_MODE, hardware_for, make_scheduler
from repro.core.scheduler import BucketServeScheduler, SchedulerConfig
from repro.core.simulator import A100X4, CostModel, Simulator
from repro.core.telemetry import PHASES, WAIT_PHASES
from repro.data.trace import TraceRecorder, TraceWorkload
from repro.data.workload import DEFAULT_CLASS_MIX, WorkloadSpec, generate

from .common import CFG, emit

PAGE = 128
MAX_BATCH = 8          # prefill batch cap (matches the static baseline)
SLOT_CAP = 64          # decode pool slots: page budget is the real limit
POOL_TOKENS = 16 * 1024    # tight: bursts overflow into the host tier
HOST_TOKENS = 64 * 1024
# Disaggregated systems tune the prefill:decode chip split per workload
# (the DistServe/BucketServe placement knob).  The fused static baseline
# gets ALL 4 chips for its single executor (hardware_for), which halves
# its per-iteration weight read — this decode-heavy 1:3 split is how a
# disagg deployment answers a decode-bound heterogeneous mix.
BUCKET_HW = dataclasses.replace(A100X4, prefill_chips=1, decode_chips=3)


def _spec(n: int) -> WorkloadSpec:
    return WorkloadSpec(rps=6.0, n_requests=n,
                        max_model_len=CFG.max_seq_len,
                        vocab_size=CFG.vocab_size,
                        class_mix=DEFAULT_CLASS_MIX, burst_factor=4.0,
                        diurnal_period_s=40.0, burst_every_s=15.0,
                        burst_duration_s=4.0,
                        prefix_groups=4, prefix_tokens=2 * PAGE,
                        sessions=8, turns=3, think_time_s=2.0,
                        seed=7)


def _bucket_sim(recorder=None):
    budget = MemoryBudget(hbm_bytes_per_device=BUCKET_HW.hbm_bytes,
                          n_devices=BUCKET_HW.decode_chips,
                          weight_bytes=CFG.param_count() * 2)
    sched = BucketServeScheduler(CFG, budget, SchedulerConfig(
        max_batch=MAX_BATCH, memory_model="paged", page_size=PAGE))
    sim = Simulator(sched, CostModel(CFG, BUCKET_HW), mode="disagg",
                    decode_slot_cap=SLOT_CAP, paged=True, page_size=PAGE,
                    kv_pool_tokens=POOL_TOKENS, prefix_cache=True,
                    session_ttl=600.0, host_pool_tokens=HOST_TOKENS,
                    recorder=recorder)
    return sched, sim


def _static_sim():
    hw, nd, _ = hardware_for("static", A100X4)
    budget = MemoryBudget(hbm_bytes_per_device=hw.hbm_bytes, n_devices=nd,
                          weight_bytes=CFG.param_count() * 2)
    sched = make_scheduler("static", CFG, budget)
    return sched, Simulator(sched, CostModel(CFG, hw),
                            mode=SIM_MODE["static"])


def _final_states(res):
    return sorted((r.rid, r.finished, r.first_token, r.generated,
                   r.prefix_hit_tokens, r.session_hit_tokens)
                  for r in res.requests)


def _prompt_ids(res):
    return {r.rid: (None if r.tokens is None else r.tokens.tobytes())
            for r in res.requests}


def main(quick: bool = False) -> None:
    n = 80 if quick else 200
    t0 = time.perf_counter()
    spec = _spec(n)
    reqs = generate(spec)
    n_total = len(reqs)          # > n: session heads expand into turns

    # ---- original BucketServe run, recorder attached -----------------
    rec = TraceRecorder()
    sched_b, sim_b = _bucket_sim(recorder=rec)
    res_b = sim_b.run(reqs)
    path = os.path.join(tempfile.mkdtemp(prefix="bucketserve_trace_"),
                        "burst.jsonl")
    rec.save(path, meta={"spec": "heterogeneous-4x-burst", "n": n_total})

    # ---- gate (1): replay the written trace, assert bit-identity -----
    tw = TraceWorkload(path)
    assert len(tw) == n_total, (len(tw), n_total)
    rec2 = TraceRecorder()
    sched_r, sim_r = _bucket_sim(recorder=rec2)
    res_r = sim_r.run(tw.requests())
    assert rec2.batch_log == rec.batch_log, \
        "replayed formed-batch log diverged from the recorded run"
    assert _prompt_ids(res_r) == _prompt_ids(res_b), \
        "replayed prompt token ids diverged"
    assert (res_r.prefix_hits, res_r.prefix_hit_tokens,
            res_r.session_hits, res_r.session_hit_tokens) == \
           (res_b.prefix_hits, res_b.prefix_hit_tokens,
            res_b.session_hits, res_b.session_hit_tokens), \
        "replayed cache-hit counters diverged"
    assert _final_states(res_r) == _final_states(res_b), \
        "replayed per-request timings diverged"

    # ---- gate (2): the burst exercises the adaptive machinery --------
    assert sched_b.buckets.n_splits > 0, "burst never split a bucket"
    assert sched_b.buckets.n_merges > 0, "burst never merged buckets"
    assert res_b.spilled_pages > 0, "pool pressure never spilled"
    assert res_b.restored_pages > 0, "no spilled session was resumed"

    # ---- static baseline on the SAME trace ---------------------------
    sched_s, sim_s = _static_sim()
    res_s = sim_s.run(tw.requests())

    rows = []
    for name, res in (("bucketserve", res_b), ("static", res_s)):
        rows.append([
            name, len(res.finished()), res.incomplete(),
            f"{res.p50('ttft'):.3f}", f"{res.p95('ttft'):.3f}",
            f"{res.p99('ttft'):.3f}", f"{res.p99('tpot') * 1e3:.1f}",
            f"{res.slo_attainment():.3f}",
            f"{res.slo_attainment('chat'):.3f}",
            f"{res.slo_attainment('longctx'):.3f}",
            f"{res.slo_attainment('batch'):.3f}",
            f"{res.goodput():.3f}"])
    emit(rows, ["system", "finished", "incomplete", "p50_ttft_s",
                "p95_ttft_s", "p99_ttft_s", "p99_tpot_ms", "slo_all",
                "slo_chat", "slo_longctx", "slo_batch", "goodput_rps"])

    # ---- gate (3): BucketServe beats static at the tail --------------
    assert res_b.incomplete() == 0, "bucketserve shed requests"
    assert res_b.p99("ttft") < res_s.p99("ttft"), \
        (res_b.p99("ttft"), res_s.p99("ttft"))
    assert res_b.p99("tpot") < res_s.p99("tpot"), \
        (res_b.p99("tpot"), res_s.p99("tpot"))

    # ---- gate (4): ledger conservation + latency blame (PR 8) --------
    for name, res in (("bucketserve", res_b), ("replay", res_r),
                      ("static", res_s)):
        n_closed = 0
        for r in res.requests:
            led = r.ledger
            assert led is not None and led.started, (name, r.rid)
            if led.closed:
                n_closed += 1
                assert led.conserved(), \
                    (name, r.rid, led.residual(), led.phases)
        assert n_closed > 0, name

    # blame-breakdown: seconds per phase of the time up to first token,
    # over all requests and over the P99 TTFT tail only — static's
    # convoy tail is QUEUE time, not compute
    rows = []
    for name, res in (("bucketserve", res_b), ("static", res_s)):
        for scope, tail in (("all", None), ("p99_tail", 99.0)):
            b = res.ttft_blame(tail_q=tail)
            rows.append([name, scope]
                        + [f"{b.get(p, 0.0):.3f}" for p in PHASES]
                        + [f"{res.ttft_wait_share(tail_q=tail):.3f}"])
    emit(rows, ["system", "scope"] + [f"{p}_s" for p in PHASES]
         + ["wait_share"])

    # Static's burst tail is a CONVOY: queue-wait, not compute,
    # dominates its P99 TTFT — and that queue time is precisely what
    # BucketServe removes (what little tail wait it keeps is mostly the
    # deliberate N_max admission clamp protecting TPOT, and is a small
    # fraction of static's convoy in absolute seconds).
    blame_b = res_b.ttft_blame(tail_q=99.0)
    blame_s = res_s.ttft_blame(tail_q=99.0)
    q_s = blame_s.get("queue", 0.0) / max(sum(blame_s.values()), 1e-12)
    assert q_s > 0.5, \
        f"static P99 tail should be queue-dominated, got {q_s:.3f}"
    compute_s = sum(blame_s.values()) - sum(
        blame_s.get(p, 0.0) for p in WAIT_PHASES)
    assert blame_s.get("queue", 0.0) > compute_s, \
        "static tail: queue should exceed compute"
    q_ratio = blame_b.get("queue", 0.0) / max(blame_s["queue"], 1e-12)
    assert q_ratio < 0.5, \
        f"bucketserve should remove most tail queue time, ratio {q_ratio:.3f}"
    assert sum(blame_b.values()) < sum(blame_s.values())

    print(f"claim,replay_identical,splits,{sched_b.buckets.n_splits},"
          f"merges,{sched_b.buckets.n_merges},"
          f"spilled,{res_b.spilled_pages},restored,{res_b.restored_pages},"
          f"p99_ttft_edge,{res_s.p99('ttft') / res_b.p99('ttft'):.2f}x,"
          f"p99_tpot_edge,{res_s.p99('tpot') / res_b.p99('tpot'):.2f}x,"
          f"tail_queue,static_share,{q_s:.2f},bucket_ratio,{q_ratio:.2f},"
          f"wall,{time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
