"""Analytic waste model (paper Eqs. 2-4): does midpoint bisection approach
the Eq.-(4) optimum, and how much padding does bucketing remove?"""
from __future__ import annotations

import numpy as np

from repro.core import analysis
from repro.core.bucket import BucketManager
from repro.core.request import Request, TaskType
from repro.data.workload import WorkloadSpec, generate

from .common import CFG, emit


def main(quick: bool = False):
    rows = []
    n = 512 if quick else 4096
    for dataset in ("alpaca", "longbench", "mixed"):
        spec = WorkloadSpec(dataset=dataset, rps=1e6, n_requests=n,
                            max_model_len=CFG.max_seq_len)
        lens = np.array([r.prompt_len for r in generate(spec)])

        single = analysis.expected_waste(lens, [0, CFG.max_seq_len])

        bm = BucketManager(CFG.max_seq_len)          # paper: bisection
        for i, s in enumerate(lens):
            bm.add(Request(rid=i, prompt_len=int(s), max_new_tokens=8,
                           arrival=0.0, task_type=TaskType.OFFLINE))
        for _ in range(6):
            bm.adjust(n_max=256)
        mid = analysis.expected_waste(lens, bm.boundaries())

        bm2 = BucketManager(CFG.max_seq_len, refine="eq4",
                            trigger="waste")          # beyond-paper
        for i, s in enumerate(lens):
            bm2.add(Request(rid=i, prompt_len=int(s), max_new_tokens=8,
                            arrival=0.0, task_type=TaskType.OFFLINE))
        for _ in range(6):
            bm2.adjust(n_max=256)
        eq4 = analysis.expected_waste(lens, bm2.boundaries())

        k = max(len(bm.buckets), len(bm2.buckets), 2)
        lloyd = analysis.expected_waste(
            lens, analysis.optimal_boundaries_kmeans(lens, k))

        rows.append(["waste_model", dataset, len(bm.buckets),
                     len(bm2.buckets), round(single, 4), round(mid, 4),
                     round(eq4, 4), round(lloyd, 4)])
    emit(rows, ["table", "dataset", "n_buckets_paper", "n_buckets_beyond",
                "E_waste_single", "E_waste_midpoint_paper",
                "E_waste_beyond(eq4+waste_trigger)",
                "E_waste_lloyd_optimum"])


if __name__ == "__main__":
    main()
